"""Pinned-schema validation for ``BENCH_sim.json`` — one place, every
section.

Before this module the schema knowledge lived as scattered asserts in
``tests/test_overlap.py`` / ``test_roofline_levels.py`` / ``test_topology``;
each new benchmark section meant another ad-hoc copy.  Now the tests import
:func:`validate_section` and keep only their *numeric* pins (calibration
values stay where the reproduction story is told); structural drift is
caught here and by ``python -m repro.analysis.bench`` in CI.

The validators check shape + internal consistency (key sets, positivity,
per-level exposure caps), never calibration numbers — re-recording a
benchmark must not require touching this file unless the *schema* moved.
"""
from __future__ import annotations

import json
import pathlib
import sys

from repro.analysis import repo_root

#: the fig6 kernel set (paper Figure 6)
KERNELS = ("fmatmul", "fconv2d", "jacobi2d", "fdotproduct", "exp", "softmax")

#: coll schedule variants; ``reduce`` has no double-buffered twin (the
#: recursive-doubling allreduce is already latency-optimal)
COLL_VARIANTS = ("flat", "two-level", "xla")
COLL_DB_VARIANTS = COLL_VARIANTS + ("flat-db", "two-level-db")

#: every perf strategy record carries exactly these fields
PERF_KEYS = {"bottleneck", "collective_s", "collective_s_by_level",
             "collective_s_flat_hw", "exposed_collective_s",
             "exposed_collective_s_by_level", "mfu_upper_bound",
             "wire_bytes_by_level"}

OVERLAP_KEYS = {"baseline", "overlap", "exposed_cycles",
                "exposed_cycles_overlap", "hidden_cycles_overlap"}


def _is_num(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


def _pos(v) -> bool:
    return _is_num(v) and v > 0


def _require(mapping, keys, where: str, problems: list,
             exact: bool = False) -> bool:
    if not isinstance(mapping, dict):
        problems.append(f"{where}: expected a mapping, got "
                        f"{type(mapping).__name__}")
        return False
    missing = set(keys) - set(mapping)
    if missing:
        problems.append(f"{where}: missing keys {sorted(missing)}")
        return False
    if exact and set(mapping) != set(keys):
        problems.append(f"{where}: unexpected keys "
                        f"{sorted(set(mapping) - set(keys))}")
    return True


def _all_pos(mapping, where: str, problems: list) -> None:
    for k, v in mapping.items():
        if not _pos(v):
            problems.append(f"{where}[{k}]: expected a positive number, "
                            f"got {v!r}")


# ---------------------------------------------------------------------------
# per-section validators
# ---------------------------------------------------------------------------

def _v_coll(coll, problems):
    if not _require(coll, ("C4L2", "C2L4"), "coll", problems):
        return
    for tag, ops in coll.items():
        if not _require(ops, ("reduce", "allgather", "reduce_scatter",
                              "glsu_load"), f"coll[{tag}]", problems):
            continue
        for op, variants in ops.items():
            if op in ("allgather", "reduce_scatter"):
                need = COLL_DB_VARIANTS
            elif op == "glsu_load":              # no XLA-native twin: the
                need = ("flat", "two-level")     # GLSU load is ring-only
            else:
                need = COLL_VARIANTS
            if _require(variants, need, f"coll[{tag}][{op}]", problems):
                _all_pos(variants, f"coll[{tag}][{op}]", problems)


def _v_fig6(fig6, problems):
    if not _require(fig6, ("flat", "two-level"), "fig6", problems):
        return
    for hier, kernels in fig6.items():
        if not _require(kernels, KERNELS, f"fig6[{hier}]", problems):
            continue
        for k, by_lanes in kernels.items():
            if _require(by_lanes, ("8", "16", "32", "64"),
                        f"fig6[{hier}][{k}]", problems):
                _all_pos(by_lanes, f"fig6[{hier}][{k}]", problems)


def _v_fig6_ablation_64(abl, problems):
    if not _require(abl, KERNELS, "fig6_ablation_64", problems):
        return
    for k, row in abl.items():
        if _require(row, ("flat", "two-level"), f"fig6_ablation_64[{k}]",
                    problems):
            _all_pos(row, f"fig6_ablation_64[{k}]", problems)


def _v_fig6_grid_64(grid, problems):
    if not isinstance(grid, dict) or not grid:
        problems.append("fig6_grid_64: expected a non-empty mapping")
        return
    for tag, row in grid.items():
        if not (tag.startswith("C") and "xL" in tag):
            problems.append(f"fig6_grid_64: tag {tag!r} is not CNxLM")
        if _require(row, ("fdotproduct", "red_tree_lat", "softmax"),
                    f"fig6_grid_64[{tag}]", problems):
            _all_pos(row, f"fig6_grid_64[{tag}]", problems)


def _v_fig6_overlap_64(ov, problems):
    if not _require(ov, KERNELS, "fig6_overlap_64", problems):
        return
    for k, row in ov.items():
        where = f"fig6_overlap_64[{k}]"
        if not _require(row, OVERLAP_KEYS, where, problems, exact=True):
            continue
        if not all(_is_num(v) for v in row.values()):
            problems.append(f"{where}: non-numeric entries")
            continue
        if row["overlap"] < row["baseline"]:
            problems.append(f"{where}: overlap ({row['overlap']}) below "
                            f"baseline ({row['baseline']}) — backfilling "
                            f"bubbles can only help")
        if row["exposed_cycles_overlap"] > row["exposed_cycles"]:
            problems.append(f"{where}: overlap increased exposed cycles")


def _v_fig6_pod_64(pod, problems):
    if not isinstance(pod, dict) or not pod:
        problems.append("fig6_pod_64: expected a non-empty mapping")
        return
    for tag, row in pod.items():
        if _require(row, ("fdotproduct", "n_levels", "red_tree_lat",
                          "softmax"), f"fig6_pod_64[{tag}]", problems):
            _all_pos(row, f"fig6_pod_64[{tag}]", problems)


def _v_fig7(fig7, problems):
    if not isinstance(fig7, dict) or not fig7:
        problems.append("fig7: expected a non-empty mapping")
        return
    for variant, kernels in fig7.items():
        if not isinstance(kernels, dict) or not kernels:
            problems.append(f"fig7[{variant}]: expected kernel mapping")
            continue
        for k, v in kernels.items():             # ablation deltas: a kernel
            if not _is_num(v) or v < 0:          # insensitive to the extra
                problems.append(                 # resource records 0.0
                    f"fig7[{variant}][{k}]: expected a non-negative "
                    f"number, got {v!r}")


def _v_perf(perf, problems):
    if not isinstance(perf, dict) or not perf:
        problems.append("perf: expected a non-empty mapping")
        return
    for cell, strategies in perf.items():
        if not isinstance(strategies, dict) or not strategies:
            problems.append(f"perf[{cell}]: expected strategy mapping")
            continue
        for strat, entry in strategies.items():
            where = f"perf[{cell}][{strat}]"
            if not _require(entry, PERF_KEYS, where, problems):
                continue
            by = entry["collective_s_by_level"]
            exp = entry["exposed_collective_s_by_level"]
            wb = entry["wire_bytes_by_level"]
            for name, lv in (("collective_s_by_level", by),
                             ("exposed_collective_s_by_level", exp),
                             ("wire_bytes_by_level", wb)):
                if not isinstance(lv, dict):
                    problems.append(f"{where}.{name}: expected mapping")
                    break
            else:
                if set(exp) != set(by):
                    problems.append(
                        f"{where}: exposure labels {sorted(exp)} != "
                        f"pricing labels {sorted(by)}")
                for lab in set(exp) & set(by):
                    if not -1e-12 <= exp[lab] <= by[lab] + 1e-12:
                        problems.append(
                            f"{where}[{lab}]: exposed {exp[lab]} outside "
                            f"[0, collective {by[lab]}]")
                tot = sum(exp.values())
                if abs(entry["exposed_collective_s"] - tot) > \
                        1e-9 * max(1.0, tot):
                    problems.append(
                        f"{where}: exposed_collective_s != sum of levels")
                if entry["exposed_collective_s"] > \
                        entry["collective_s"] + 1e-12:
                    problems.append(
                        f"{where}: exposed exceeds total collective time")


def _v_red_tree_lat_64(cal, problems):
    if _require(cal, ("flat", "two-level"), "red_tree_lat_64", problems):
        _all_pos(cal, "red_tree_lat_64", problems)


def _v_ring_attention_8dev(ra, problems):
    if not _require(ra, ("flat", "hier2x2x2"), "ring_attention_8dev",
                    problems):
        return
    for case, row in ra.items():
        where = f"ring_attention_8dev[{case}]"
        if _require(row, ("seq", "db"), where, problems, exact=True):
            _all_pos(row, where, problems)


def _v_tab1(tab1, problems):
    if not isinstance(tab1, dict) or not tab1:
        problems.append("tab1: expected a non-empty mapping")
        return
    for k, row in tab1.items():
        if _require(row, ("flop_per_cycle", "peak"), f"tab1[{k}]",
                    problems):
            _all_pos(row, f"tab1[{k}]", problems)


def _v_tab2(tab2, problems):
    if not _require(tab2, ("16", "32", "64"), "tab2", problems):
        return
    for lanes, row in tab2.items():
        if _require(row, ("err_pct", "model_kge", "paper_kge"),
                    f"tab2[{lanes}]", problems):
            for k, v in row.items():
                if not _is_num(v):
                    problems.append(f"tab2[{lanes}][{k}]: non-numeric")


def _v_tab3(tab3, problems):
    if not _require(tab3, ("16", "32", "64"), "tab3", problems):
        return
    for lanes, row in tab3.items():
        where = f"tab3[{lanes}]"
        if not _require(row, ("area_eff", "energy_eff", "paper",
                              "perf_gflops"), where, problems):
            continue
        if not isinstance(row["paper"], list):
            problems.append(f"{where}[paper]: expected a list")
        for k in ("area_eff", "energy_eff", "perf_gflops"):
            if not _pos(row[k]):
                problems.append(f"{where}[{k}]: expected positive number")


VALIDATORS = {
    "coll": _v_coll,
    "fig6": _v_fig6,
    "fig6_ablation_64": _v_fig6_ablation_64,
    "fig6_grid_64": _v_fig6_grid_64,
    "fig6_overlap_64": _v_fig6_overlap_64,
    "fig6_pod_64": _v_fig6_pod_64,
    "fig7": _v_fig7,
    "perf": _v_perf,
    "red_tree_lat_64": _v_red_tree_lat_64,
    "ring_attention_8dev": _v_ring_attention_8dev,
    "tab1": _v_tab1,
    "tab2": _v_tab2,
    "tab3": _v_tab3,
}


# ---------------------------------------------------------------------------
# BENCH_kernels.json — the autotuner's model-vs-measured rank table
# ---------------------------------------------------------------------------

KERNELS_SCHEMA = 1
TUNED_KERNELS = ("matmul", "flash_attention", "paged_attention", "rmsnorm",
                 "reduction", "stencil")
KERNELS_RECORD_KEYS = ("kernel", "shape", "dtype", "topology", "top_k",
                       "candidates", "winner", "model_rank_of_winner",
                       "agreement_at_k")
#: the acceptance floor: the calibration table must cover at least this
#: many kernel families at this many problem shapes each
KERNELS_MIN_KERNELS = 3
KERNELS_MIN_SHAPES = 2


def _v_kernels_record(sig: str, rec, problems: list) -> None:
    where = f"records[{sig}]"
    if not _require(rec, KERNELS_RECORD_KEYS, where, problems, exact=True):
        return
    if rec["kernel"] not in TUNED_KERNELS:
        problems.append(f"{where}: unknown kernel {rec['kernel']!r}")
    parts = sig.split("|")
    if len(parts) != 4 or parts[0] != rec["kernel"]:
        problems.append(f"{where}: signature does not match kernel field")
    shape = rec["shape"]
    if not (isinstance(shape, list) and shape
            and all(isinstance(s, int) and s > 0 for s in shape)):
        problems.append(f"{where}.shape: expected positive int list")
    elif len(parts) == 4 and parts[1] != "x".join(str(s) for s in shape):
        problems.append(f"{where}: signature shape != shape field")
    if not (isinstance(rec["top_k"], int) and rec["top_k"] > 0):
        problems.append(f"{where}.top_k: expected positive int")
    cands = rec["candidates"]
    if not (isinstance(cands, list) and cands):
        problems.append(f"{where}.candidates: expected non-empty list")
        return
    measured = []
    for i, c in enumerate(cands):
        cw = f"{where}.candidates[{i}]"
        if not _require(c, ("config", "model_us", "model_rank"), cw,
                        problems):
            return
        cfg = c["config"]
        if not (isinstance(cfg, dict) and cfg
                and all(isinstance(v, int) and v > 0 for v in cfg.values())):
            problems.append(f"{cw}.config: expected positive int mapping")
        if not _pos(c["model_us"]):
            problems.append(f"{cw}.model_us: expected positive number")
        if "measured_us" in c:
            if not _pos(c["measured_us"]):
                problems.append(f"{cw}.measured_us: expected positive")
            if not (_is_num(c.get("iqr_us")) and c["iqr_us"] >= 0):
                problems.append(f"{cw}.iqr_us: expected non-negative")
            if not (isinstance(c.get("reps"), int) and c["reps"] >= 1):
                problems.append(f"{cw}.reps: expected int >= 1")
            measured.append(c)
    if sorted(c["model_rank"] for c in cands) != list(range(len(cands))):
        problems.append(f"{where}: model_rank is not a 0..n-1 permutation")
    if not measured:
        problems.append(f"{where}: no measured candidates")
        return
    if sorted(c.get("measured_rank", -1) for c in measured) != \
            list(range(len(measured))):
        problems.append(f"{where}: measured_rank is not a permutation "
                        f"over the measured shortlist")
        return
    win = min(measured, key=lambda c: c["measured_rank"])
    if rec["winner"] != win["config"]:
        problems.append(f"{where}: winner != measured_rank-0 config")
    if rec["model_rank_of_winner"] != win["model_rank"]:
        problems.append(f"{where}: model_rank_of_winner inconsistent")
    if rec["agreement_at_k"] != (win["model_rank"] < rec["top_k"]):
        problems.append(f"{where}: agreement_at_k inconsistent with "
                        f"model_rank_of_winner/top_k")


def validate_kernels_bench(doc) -> list[str]:
    """Schema problems for BENCH_kernels.json (empty when clean)."""
    problems: list[str] = []
    if not _require(doc, ("schema", "records"), "BENCH_kernels", problems,
                    exact=True):
        return problems
    if doc["schema"] != KERNELS_SCHEMA:
        problems.append(f"BENCH_kernels: schema {doc['schema']!r} != "
                        f"{KERNELS_SCHEMA}")
    records = doc["records"]
    if not isinstance(records, dict) or not records:
        problems.append("BENCH_kernels.records: expected non-empty mapping")
        return problems
    shapes: dict[str, set] = {}
    for sig, rec in sorted(records.items()):
        _v_kernels_record(sig, rec, problems)
        if isinstance(rec, dict) and isinstance(rec.get("shape"), list):
            shapes.setdefault(str(rec.get("kernel")), set()).add(
                tuple(rec["shape"]))
    covered = sum(1 for s in shapes.values()
                  if len(s) >= KERNELS_MIN_SHAPES)
    if covered < KERNELS_MIN_KERNELS:
        problems.append(
            f"BENCH_kernels: coverage {covered} kernel(s) with >= "
            f"{KERNELS_MIN_SHAPES} shapes — need {KERNELS_MIN_KERNELS}")
    return problems


def load_kernels_bench(root: pathlib.Path | None = None) -> dict | None:
    """The recorded autotune table, or None when not yet recorded."""
    root = pathlib.Path(root) if root is not None else repo_root()
    path = root / "BENCH_kernels.json"
    if not path.exists():
        return None
    return json.loads(path.read_text())


# ---------------------------------------------------------------------------
# BENCH_serve.json — the open-loop serving ablation (dense vs paged)
# ---------------------------------------------------------------------------

SERVE_SCHEMA = 1
SERVE_TAGS = ("dense", "paged", "paged_chunked")
SERVE_CONFIG_KEYS = ("max_batch", "max_seq", "block_tokens", "chunk",
                     "rate_rps")
SERVE_METRIC_KEYS = ("n_requests", "completed", "ttft_p50_ms", "ttft_p99_ms",
                     "decode_tok_s", "occupancy", "max_concurrent", "wall_s",
                     "kv_bytes_capacity", "kv_bytes_resident_peak")


def _v_serve_record(tag: str, rec, problems: list) -> None:
    where = f"open_loop[{tag}]"
    if not _require(rec, ("tag", "config") + SERVE_METRIC_KEYS, where,
                    problems):
        return
    if rec["tag"] != tag:
        problems.append(f"{where}: tag field {rec['tag']!r} != key")
    conf = rec["config"]
    if _require(conf, SERVE_CONFIG_KEYS, f"{where}.config", problems):
        for k in ("max_batch", "max_seq"):
            if not (isinstance(conf[k], int) and conf[k] > 0):
                problems.append(f"{where}.config[{k}]: expected positive int")
        for k in ("block_tokens", "chunk"):   # 0 = dense / unchunked
            if not (isinstance(conf[k], int) and conf[k] >= 0):
                problems.append(f"{where}.config[{k}]: expected int >= 0")
        if tag != "dense" and conf["block_tokens"] <= 0:
            problems.append(f"{where}.config: paged arm without "
                            f"block_tokens")
        if not _pos(conf["rate_rps"]):
            problems.append(f"{where}.config.rate_rps: expected positive")
    for k in ("ttft_p50_ms", "ttft_p99_ms", "decode_tok_s", "occupancy",
              "wall_s"):
        if not (_is_num(rec[k]) and rec[k] >= 0):
            problems.append(f"{where}[{k}]: expected non-negative number")
    for k in ("n_requests", "completed", "max_concurrent",
              "kv_bytes_capacity", "kv_bytes_resident_peak"):
        if not (isinstance(rec[k], int) and rec[k] >= 0):
            problems.append(f"{where}[{k}]: expected non-negative int")
    if _is_num(rec["ttft_p50_ms"]) and _is_num(rec["ttft_p99_ms"]) \
            and rec["ttft_p99_ms"] < rec["ttft_p50_ms"]:
        problems.append(f"{where}: p99 TTFT below p50")
    if _is_num(rec["occupancy"]) and not 0.0 <= rec["occupancy"] <= 1.0:
        problems.append(f"{where}: occupancy outside [0, 1]")
    if isinstance(rec["completed"], int) \
            and isinstance(rec["n_requests"], int) \
            and rec["completed"] > rec["n_requests"]:
        problems.append(f"{where}: completed exceeds n_requests")
    if isinstance(rec["kv_bytes_resident_peak"], int) \
            and isinstance(rec["kv_bytes_capacity"], int) \
            and rec["kv_bytes_resident_peak"] > rec["kv_bytes_capacity"]:
        problems.append(f"{where}: resident KV exceeds declared capacity")


def validate_serve_bench(doc) -> list[str]:
    """Schema problems for BENCH_serve.json (empty when clean).  Shape +
    consistency only — the >= 2x paged-concurrency acceptance pin lives in
    ``tests/test_serve_paged.py``, beside the reproduction story."""
    problems: list[str] = []
    if not _require(doc, ("schema", "open_loop"), "BENCH_serve", problems,
                    exact=True):
        return problems
    if doc["schema"] != SERVE_SCHEMA:
        problems.append(f"BENCH_serve: schema {doc['schema']!r} != "
                        f"{SERVE_SCHEMA}")
    open_loop = doc["open_loop"]
    if not _require(open_loop, SERVE_TAGS, "open_loop", problems):
        return problems
    for tag, rec in sorted(open_loop.items()):
        if tag not in SERVE_TAGS:
            problems.append(f"open_loop: unknown tag {tag!r}")
            continue
        _v_serve_record(tag, rec, problems)
    return problems


def load_serve_bench(root: pathlib.Path | None = None) -> dict | None:
    """The recorded serving ablation, or None when not yet recorded."""
    root = pathlib.Path(root) if root is not None else repo_root()
    path = root / "BENCH_serve.json"
    if not path.exists():
        return None
    return json.loads(path.read_text())


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def validate_section(name: str, value) -> list[str]:
    """Schema problems for one recorded section (empty when clean)."""
    if name not in VALIDATORS:
        return [f"{name}: unknown BENCH_sim.json section — add a pinned "
                f"validator in repro.analysis.bench"]
    problems: list[str] = []
    VALIDATORS[name](value, problems)
    return problems


def validate_bench(bench: dict) -> list[str]:
    """All sections, plus unknown-section detection; sections are allowed
    to be absent (benchmarks record incrementally) but never malformed."""
    problems: list[str] = []
    for name, value in sorted(bench.items()):
        problems += validate_section(name, value)
    return problems


def load_bench(root: pathlib.Path | None = None) -> dict:
    root = pathlib.Path(root) if root is not None else repo_root()
    return json.loads((root / "BENCH_sim.json").read_text())


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    root = pathlib.Path(argv[0]) if argv else repo_root()
    bench = load_bench(root)
    problems = [f"BENCH_sim.json: {p}" for p in validate_bench(bench)]
    kernels = load_kernels_bench(root)
    if kernels is not None:
        problems += [f"BENCH_kernels.json: {p}"
                     for p in validate_kernels_bench(kernels)]
    serve = load_serve_bench(root)
    if serve is not None:
        problems += [f"BENCH_serve.json: {p}"
                     for p in validate_serve_bench(serve)]
    for p in problems:
        print(p)
    if problems:
        print(f"repro.analysis.bench: {len(problems)} problem(s)")
        return 1
    n_rec = len(kernels["records"]) if kernels else 0
    print(f"repro.analysis.bench: {len(bench)} sections OK"
          + (f", {n_rec} autotune records OK" if kernels else "")
          + (f", {len(serve['open_loop'])} serve arms OK" if serve else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
