"""repro.analysis — the repo's load-bearing conventions, machine-checked.

AraXL's scaling argument only holds because *every* wire crossing is
accounted for by the hierarchical interconnect; the software analogue in
this repo is that every version-drifting jax call routes through
:mod:`repro.substrate` and every collective prices onto the declared
:class:`repro.topology.Topology`.  This package turns those prose rules
(ROADMAP) into a static-analysis pass with two fronts:

* **AST lint** (:mod:`repro.analysis.lint`) — stdlib-``ast``, no jax
  import, runs anywhere:

  =====  ==================================================================
  L1     substrate-only: no direct ``shard_map`` / ``lax.ppermute`` /
         ``axis_index`` / ``axis_size`` / halo-``BlockSpec`` spellings
         outside ``src/repro/substrate.py``
  L2     import hygiene: no x64 flag flips outside
         ``src/repro/testing/x64.py``; no import-time ``XLA_FLAGS`` /
         ``JAX_PLATFORMS`` mutation in test modules outside
         ``tests/conftest.py``
  L3     no ad-hoc ``BENCH_*.json`` writes outside the pinned-schema merge
         helpers in ``benchmarks/run.py``
  L4     no wall-clock timing outside ``repro.testing.timing``
  =====  ==================================================================

* **semantic analyzer** (:mod:`repro.analysis.jaxpr_check` +
  :mod:`repro.analysis.schedule_check`) — traces the public entry points
  (ring collectives, ring attention, MoE ep_a2a, Pallas kernels) to closed
  jaxprs on 8 fake CPU devices:

  =====  ==================================================================
  S1     pricing coverage: every collective's replica group must resolve
         through ``roofline.analysis.group_level_extents`` for the
         declared Topology without hitting the conservative flat fallback
  S2     ring-schedule safety: every ``ppermute`` is a full-ring uniform
         circular shift (deadlock check) and no donated / aliased Pallas
         buffer is read while in flight
  S3     Pallas budget: grid/BlockSpec divisibility and the static VRF
         budget against the RVV 64 Kibit/vreg ceiling of ``AraXLParams``
  =====  ==================================================================

Suppression: append ``# repro: noqa(RULE)`` (comma-separated rules) to the
offending line, with a comment saying why the rule is inapplicable there.

Run ``python -m repro.analysis`` (exits non-zero on any finding; gated in
``scripts/ci.sh``) and ``python -m repro.analysis.bench`` for the
``BENCH_sim.json`` pinned-schema validation.
"""
from __future__ import annotations

import dataclasses
import pathlib

#: rule id -> one-line description (the catalogue docs/ANALYSIS.md renders)
RULES = {
    "L1": "substrate-only: version-drifting jax APIs route through "
          "repro.substrate",
    "L2": "import hygiene: x64 flips only in repro.testing.x64; no "
          "import-time XLA_FLAGS/JAX_PLATFORMS mutation in test modules "
          "outside tests/conftest.py",
    "L3": "BENCH_*.json writes only through benchmarks/run.py merge helpers",
    "L4": "wall-clock timing only through repro.testing.timing",
    "S1": "collective pricing coverage: replica groups resolve on the "
          "declared Topology without the flat fallback",
    "S2": "ring-schedule safety: full-ring uniform-shift ppermutes; no "
          "aliased in-flight buffer reads",
    "S3": "Pallas VRF budget: block divisibility + 64 Kibit/vreg ceiling",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation: rule id, location, what, and how to fix it."""
    rule: str                    # "L1".."L4" / "S1".."S3"
    path: str                    # repo-relative file, or entry-point label
    line: int                    # 1-based source line; 0 for traced entries
    message: str
    hint: str = ""

    def __str__(self) -> str:
        loc = f"{self.path}:{self.line}" if self.line else self.path
        s = f"{loc}: {self.rule}: {self.message}"
        if self.hint:
            s += f"  [fix: {self.hint}]"
        return s


def repo_root() -> pathlib.Path:
    """The repo root this installation lives in (src/repro/analysis/..)."""
    return pathlib.Path(__file__).resolve().parents[3]


def run_repo_analysis(root: pathlib.Path | None = None,
                      semantic: bool = True) -> list[Finding]:
    """Both fronts over the repo.  The semantic front imports jax and needs
    >= 8 (fake) devices; set ``semantic=False`` for the lint-only pass."""
    from repro.analysis import lint
    root = pathlib.Path(root) if root is not None else repo_root()
    findings = lint.lint_repo(root)
    if semantic:
        from repro.analysis import jaxpr_check
        findings += jaxpr_check.semantic_findings()
    return findings
