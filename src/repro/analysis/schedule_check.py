"""Front 2a — S2, ring-schedule safety.

Two failure classes the RINGI discipline must never ship:

* **deadlock / partial rings** — a ``ppermute`` whose permutation is not a
  uniform circular shift covering the whole ring.  On a physical ring a
  non-bijective or partial permutation leaves some device waiting on a hop
  nobody sends (the odometer deadlock); a non-uniform shift means different
  devices cross different numbers of wires per step, so the schedule's cost
  model (hops x hop_lat) silently misprices.  Uniform shifts with
  ``gcd(shift, n) > 1`` are *legal* — recursive doubling (shift 2, 4, ...)
  decomposes into gcd-many disjoint cycles that all advance in lockstep.

* **in-flight aliasing races** — a donated Pallas buffer
  (``input_output_aliases``) that some *other* equation still reads: the
  in-place write races the read once the backend really aliases.

This module is jax-free on purpose (it only walks jaxpr data structures
handed to it), so the pure permutation check is unit-testable anywhere.
"""
from __future__ import annotations

import math
from typing import Iterator, Sequence

from repro.analysis import Finding


# ---------------------------------------------------------------------------
# jaxpr walking (duck-typed: works on Jaxpr objects without importing jax)
# ---------------------------------------------------------------------------

def _subjaxprs(v):
    vals = v if isinstance(v, (tuple, list)) else (v,)
    for x in vals:
        inner = getattr(x, "jaxpr", x)        # ClosedJaxpr -> Jaxpr
        if hasattr(inner, "eqns"):
            yield inner


def walk_jaxprs(jaxpr) -> Iterator:
    """Yield ``jaxpr`` and every sub-jaxpr reachable through eqn params
    (pjit bodies, shard_map bodies, scan/cond branches, pallas kernels)."""
    yield jaxpr
    for eqn in jaxpr.eqns:
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from walk_jaxprs(sub)


def iter_eqns(jaxpr, mesh=None) -> Iterator[tuple]:
    """Yield ``(eqn, enclosing_mesh)`` over every equation recursively; the
    mesh is the innermost ``shard_map`` mesh the equation sits under."""
    for eqn in jaxpr.eqns:
        m = eqn.params.get("mesh", mesh) \
            if eqn.primitive.name == "shard_map" else mesh
        yield eqn, m
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_eqns(sub, m)


def axis_tuple(axis_name) -> tuple[str, ...]:
    return (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)


# ---------------------------------------------------------------------------
# S2a — permutation safety
# ---------------------------------------------------------------------------

def check_ring_permutation(perm: Sequence[tuple[int, int]],
                           n: int) -> list[str]:
    """Problems with one ppermute permutation on an ``n``-ring (empty list
    when the permutation is a full-ring uniform circular shift)."""
    pairs = [tuple(p) for p in perm]
    problems = []
    srcs = [s for s, _ in pairs]
    dsts = [d for _, d in pairs]
    bad = [p for p in pairs
           if not (0 <= p[0] < n and 0 <= p[1] < n)]
    if bad:
        problems.append(f"pairs {bad} outside the {n}-ring")
        return problems
    if len(set(srcs)) != len(srcs):
        problems.append("duplicate sources (one buffer sent twice)")
    if len(set(dsts)) != len(dsts):
        problems.append("duplicate destinations (receive-side write race)")
    if problems:
        return problems
    if len(pairs) != n or set(srcs) != set(range(n)):
        idle = sorted(set(range(n)) - set(srcs))
        problems.append(
            f"partial ring: positions {idle} send nothing — their "
            f"neighbours wait forever (odometer deadlock)")
        return problems
    shifts = {(d - s) % n for s, d in pairs}
    if len(shifts) != 1:
        problems.append(
            f"non-uniform shift {sorted(shifts)}: hops differ per device, "
            f"so the ring cost model (hops x hop_lat) misprices")
    elif shifts == {0}:
        problems.append("zero shift (identity permutation moves no data)")
    return problems


def check_ppermute_schedules(closed_jaxpr, label: str) -> list[Finding]:
    """Run :func:`check_ring_permutation` on every traced ``ppermute``."""
    findings = []
    for eqn, mesh in iter_eqns(closed_jaxpr.jaxpr):
        if eqn.primitive.name != "ppermute":
            continue
        axes = axis_tuple(eqn.params["axis_name"])
        perm = eqn.params["perm"]
        if mesh is not None:
            n = math.prod(dict(mesh.shape)[a] for a in axes)
        else:                                  # no mesh in scope: best effort
            n = 1 + max(max(s, d) for s, d in perm)
        for prob in check_ring_permutation(perm, n):
            findings.append(Finding(
                "S2", label, 0,
                f"ppermute over {axes} (ring of {n}): {prob}",
                "build shifts with repro.core.ring._shift_perm so every "
                "step is a full-ring uniform circular shift"))
    return findings


# ---------------------------------------------------------------------------
# S2b — donation / input_output_aliases race detector
# ---------------------------------------------------------------------------

def check_aliasing(closed_jaxpr, label: str) -> list[Finding]:
    """A Pallas input aliased onto an output is written in place; if any
    other equation (or the jaxpr's own outputs) still reads that buffer,
    the double-buffered schedule has an in-flight race."""
    findings = []
    for jx in walk_jaxprs(closed_jaxpr.jaxpr):
        uses: dict = {}
        def _is_var(v):                      # Vars only; Literals (which
            return hasattr(v, "aval") and not hasattr(v, "val")  # are unhashable) carry .val
        for eqn in jx.eqns:
            for v in eqn.invars:
                if _is_var(v):
                    uses[v] = uses.get(v, 0) + 1
        for v in jx.outvars:
            if _is_var(v):
                uses[v] = uses.get(v, 0) + 1
        for eqn in jx.eqns:
            if eqn.primitive.name != "pallas_call":
                continue
            for in_idx, out_idx in (
                    eqn.params.get("input_output_aliases") or ()):
                if in_idx >= len(eqn.invars):
                    continue
                v = eqn.invars[in_idx]
                if _is_var(v) and uses.get(v, 0) > 1:
                    findings.append(Finding(
                        "S2", label, 0,
                        f"pallas input {in_idx} is donated to output "
                        f"{out_idx} but another op still reads the same "
                        f"buffer — in-flight aliasing race",
                        "drop input_output_aliases for buffers with other "
                        "consumers, or copy before donating"))
    return findings
