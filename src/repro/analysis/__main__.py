"""CLI: ``python -m repro.analysis`` — run both fronts, exit 1 on any
finding.  The semantic front shard_maps over 8 devices, so the fake-device
env is set *here*, before anything imports jax — safe because ``-m`` always
starts a fresh interpreter (library code must never do this; that is
exactly lint rule L2's env sub-rule)."""
from __future__ import annotations

import argparse
import os
import sys


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="substrate-hygiene lint + collective/ring/VRF "
                    "semantic analysis")
    ap.add_argument("--lint-only", action="store_true",
                    help="skip the semantic front (no jax import)")
    ap.add_argument("--root", default=None,
                    help="repo root to lint (default: this checkout)")
    args = ap.parse_args(argv)

    if not args.lint_only:
        os.environ.setdefault("JAX_PLATFORMS", "cpu")
        flag = "--xla_force_host_platform_device_count=8"
        flags = os.environ.get("XLA_FLAGS", "")
        if "--xla_force_host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = f"{flag} {flags}".strip()

    from repro.analysis import RULES, run_repo_analysis
    findings = run_repo_analysis(root=args.root,
                                 semantic=not args.lint_only)
    for f in findings:
        print(f)
    active = [r for r in RULES if args.lint_only is False or
              r.startswith("L")]
    if findings:
        print(f"repro.analysis: {len(findings)} finding(s) "
              f"({', '.join(sorted({f.rule for f in findings}))})")
        return 1
    print(f"repro.analysis: clean ({', '.join(active)} active)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
