"""Front 1 — the AST lint (rules L1-L4).

Pure stdlib ``ast``: no jax import, so the lint runs in any environment
(including ones with no fake devices).  Names are resolved through the
module's import aliases — ``from jax import lax as L; L.ppermute`` and
``from jax.lax import ppermute`` both resolve to ``jax.lax.ppermute`` —
so the rules fire on what the code *means*, not on how it spells it.

Suppression: a trailing ``# repro: noqa(L1)`` (or ``noqa(L1,L4)``) on the
offending line drops those rules for that line only.
"""
from __future__ import annotations

import ast
import pathlib
import re

from repro.analysis import Finding

#: directories swept by :func:`lint_repo`, relative to the repo root
LINT_DIRS = ("src", "tests", "benchmarks", "examples")

# --- per-rule allow-lists (repo-relative posix paths) ----------------------
L1_ALLOWED = ("src/repro/substrate.py",)
L2_ALLOWED = ("src/repro/testing/x64.py",)
L2_ENV_ALLOWED = ("tests/conftest.py",)
L3_ALLOWED = ("benchmarks/run.py",)
L4_ALLOWED = ("src/repro/testing/timing.py",)

#: L1 — version-drifting jax surface that must route through the substrate.
#: Matched by exact resolved name or dotted prefix (so the module spelling
#: ``jax.experimental.shard_map`` catches ``....shard_map.shard_map`` too).
L1_BANNED = {
    "jax.shard_map": "substrate.shard_map",
    "jax.experimental.shard_map": "substrate.shard_map",
    "jax.lax.ppermute": "substrate.ppermute",
    "jax.lax.axis_index": "substrate.axis_index",
    "jax.lax.axis_size": "substrate.axis_size",
    "jax.experimental.pallas.Element": "substrate.halo_block_spec",
    "jax.experimental.pallas.Unblocked": "substrate.halo_block_spec",
}

#: L4 — wall-clock sources (time.sleep stays legal: it waits, not measures)
L4_BANNED = {
    "time.time", "time.time_ns", "time.perf_counter", "time.perf_counter_ns",
    "time.monotonic", "time.monotonic_ns", "time.process_time",
    "time.process_time_ns", "timeit.default_timer",
}

#: L4 — the sanctioned façades.  Calls that *resolve into*
#: ``repro.testing.timing`` are the point of the rule, never findings —
#: this guards the carve-out against spellings where the alias table makes
#: the façade look raw (``from repro.testing import timing as time;
#: time.monotonic()`` resolves to ``repro.testing.timing.monotonic``).
L4_SANCTIONED_PREFIX = "repro.testing.timing"

#: L2 env sub-rule — keys a test module must not touch at import time
L2_ENV_KEYS = ("XLA_FLAGS", "JAX_PLATFORMS")

_NOQA = re.compile(r"#\s*repro:\s*noqa\(\s*([A-Z0-9,\s]+?)\s*\)")


def _noqa_map(source: str) -> dict[int, frozenset[str]]:
    out = {}
    for i, text in enumerate(source.splitlines(), start=1):
        m = _NOQA.search(text)
        if m:
            out[i] = frozenset(r.strip() for r in m.group(1).split(",")
                               if r.strip())
    return out


def _package_of(relpath: str) -> str:
    """Dotted package of a repo-relative module path (for relative imports):
    ``src/repro/core/ring.py`` -> ``repro.core``."""
    parts = pathlib.PurePosixPath(relpath).parts
    if parts and parts[0] == "src":
        parts = parts[1:]
    return ".".join(parts[:-1])


def _collect_aliases(tree: ast.AST, relpath: str) -> dict[str, str]:
    """Local name -> fully dotted import path, module-wide."""
    pkg = _package_of(relpath)
    aliases: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.asname:
                    aliases[a.asname] = a.name
                else:
                    head = a.name.split(".")[0]
                    aliases[head] = head
        elif isinstance(node, ast.ImportFrom):
            module = node.module or ""
            if node.level:                     # relative import
                base = pkg.split(".") if pkg else []
                base = base[: max(0, len(base) - (node.level - 1))]
                module = ".".join(base + ([module] if module else []))
            for a in node.names:
                if a.name == "*":
                    continue
                full = f"{module}.{a.name}" if module else a.name
                aliases[a.asname or a.name] = full
    return aliases


def _resolve(node: ast.AST, aliases: dict[str, str]) -> str | None:
    """Dotted name of an attribute chain rooted at an imported name."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    base = aliases.get(node.id)
    if base is None:
        return None
    parts.append(base)
    return ".".join(reversed(parts))


def _matches(resolved: str, banned: str) -> bool:
    return resolved == banned or resolved.startswith(banned + ".")


def _str_consts(node: ast.AST):
    for sub in ast.walk(node):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            yield sub.value


def _is_environ(node: ast.AST, aliases: dict[str, str]) -> bool:
    resolved = _resolve(node, aliases)
    return resolved in ("os.environ", "os.environb")


class _Linter:
    def __init__(self, tree: ast.AST, relpath: str, aliases: dict[str, str]):
        self.relpath = relpath
        self.aliases = aliases
        self.findings: list[Finding] = []
        self.in_tests = relpath.startswith("tests/")
        self._walk(tree, depth=0)

    def _add(self, rule: str, node: ast.AST, message: str, hint: str):
        line = getattr(node, "lineno", 0)
        for f in self.findings:           # one finding per (rule, line)
            if f.rule == rule and f.line == line:
                return
        self.findings.append(Finding(rule, self.relpath, line, message, hint))

    # -- rules --------------------------------------------------------------

    def _check_l1_name(self, node: ast.AST):
        if self.relpath in L1_ALLOWED:
            return
        resolved = _resolve(node, self.aliases)
        if resolved is None:
            return
        for banned, repl in L1_BANNED.items():
            if _matches(resolved, banned):
                self._add("L1", node,
                          f"direct use of version-drifting `{resolved}`",
                          f"route through repro.{repl} (the one "
                          f"jax-version compatibility point)")
                return

    def _check_l1_import(self, node: ast.Import | ast.ImportFrom):
        if self.relpath in L1_ALLOWED:
            return
        if isinstance(node, ast.Import):
            fulls = [a.name for a in node.names]
        else:
            if node.level:
                return                          # relative: repo-internal
            mod = node.module or ""
            fulls = [f"{mod}.{a.name}" if mod else a.name
                     for a in node.names]
            fulls.append(mod)
        for full in fulls:
            for banned, repl in L1_BANNED.items():
                if full and _matches(full, banned):
                    self._add("L1", node,
                              f"imports version-drifting `{full}`",
                              f"route through repro.{repl}")
                    return

    def _check_l2_call(self, node: ast.Call):
        if self.relpath in L2_ALLOWED:
            return
        func = node.func
        if not (isinstance(func, ast.Attribute) and func.attr == "update"):
            return
        owner = _resolve(func.value, self.aliases)
        if owner is None or not (owner == "jax.config"
                                 or owner.endswith(".config")):
            return
        if node.args and isinstance(node.args[0], ast.Constant) \
                and node.args[0].value == "jax_enable_x64":
            self._add("L2", node,
                      "x64 flag flip outside repro.testing.x64 (the PR 5 "
                      "flag-leak class)",
                      "use repro.testing.x64.x64_mode(...) as a context "
                      "manager")

    def _check_l2_env(self, node: ast.stmt, depth: int):
        """Import-time XLA_FLAGS/JAX_PLATFORMS mutation in a test module."""
        if not self.in_tests or depth > 0 or self.relpath in L2_ENV_ALLOWED:
            return
        mutating: ast.AST | None = None
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Subscript) \
                        and _is_environ(t.value, self.aliases):
                    mutating = node
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                if isinstance(t, ast.Subscript) \
                        and _is_environ(t.value, self.aliases):
                    mutating = node
        elif isinstance(node, ast.Expr) and isinstance(node.value, ast.Call):
            call = node.value
            if isinstance(call.func, ast.Attribute) \
                    and call.func.attr in ("setdefault", "update", "pop") \
                    and _is_environ(call.func.value, self.aliases):
                mutating = call
        if mutating is None:
            return
        keys = [k for k in L2_ENV_KEYS
                if any(k in s for s in _str_consts(mutating))]
        if keys:
            self._add("L2", node,
                      f"test module mutates {'/'.join(keys)} at import "
                      f"time (device-count races with the shared "
                      f"conftest bootstrap)",
                      "rely on tests/conftest.py (idempotent fake-device "
                      "env) or mutate a subprocess env copy")

    def _check_l3(self, node: ast.Call):
        if self.relpath in L3_ALLOWED:
            return
        func = node.func
        is_write = (isinstance(func, ast.Attribute)
                    and func.attr in ("write_text", "write_bytes"))
        resolved = _resolve(func, self.aliases)
        if resolved == "json.dump":
            is_write = True
        if isinstance(func, ast.Name) and func.id == "open" \
                and func.id not in self.aliases:
            mode = None
            if len(node.args) > 1 and isinstance(node.args[1], ast.Constant):
                mode = node.args[1].value
            for kw in node.keywords:
                if kw.arg == "mode" and isinstance(kw.value, ast.Constant):
                    mode = kw.value.value
            if isinstance(mode, str) and any(c in mode for c in "wa+"):
                is_write = True
        if not is_write:
            return
        if any("BENCH_" in s for s in _str_consts(node)):
            self._add("L3", node,
                      "ad-hoc BENCH_*.json write bypasses the pinned-schema "
                      "merge helpers",
                      "record through benchmarks/run.py (BENCH dict + "
                      "_deep_merge) so repro.analysis.bench can validate it")

    def _check_l4(self, node: ast.Call):
        if self.relpath in L4_ALLOWED:
            return
        resolved = _resolve(node.func, self.aliases)
        if resolved is None or _matches(resolved, L4_SANCTIONED_PREFIX):
            return
        if resolved in L4_BANNED:
            self._add("L4", node,
                      f"wall-clock timing via `{resolved}` outside "
                      f"repro.testing.timing",
                      "use repro.testing.timing.now() for intervals, "
                      "timing.monotonic() for liveness deadlines, or "
                      "median_time_us() for measurements")

    # -- walk ---------------------------------------------------------------

    def _walk(self, node: ast.AST, depth: int):
        for child in ast.iter_child_nodes(node):
            child_depth = depth
            if isinstance(child, (ast.Import, ast.ImportFrom)):
                self._check_l1_import(child)
            elif isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.Lambda)):
                child_depth = depth + 1
            elif isinstance(child, ast.Call):
                self._check_l2_call(child)
                self._check_l3(child)
                self._check_l4(child)
            elif isinstance(child, (ast.Attribute, ast.Name)) \
                    and isinstance(getattr(child, "ctx", None), ast.Load):
                self._check_l1_name(child)
            if isinstance(child, ast.stmt):
                self._check_l2_env(child, depth)
            self._walk(child, child_depth)


def lint_source(source: str, relpath: str) -> list[Finding]:
    """Lint one module given its repo-relative posix path (the path decides
    which allow-list applies)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [Finding("L1", relpath, e.lineno or 0,
                        f"syntax error: {e.msg}", "fix the parse error")]
    aliases = _collect_aliases(tree, relpath)
    findings = _Linter(tree, relpath, aliases).findings
    noqa = _noqa_map(source)
    kept = [f for f in findings if f.rule not in noqa.get(f.line, ())]
    return sorted(kept, key=lambda f: (f.path, f.line, f.rule))


def lint_file(path: pathlib.Path, root: pathlib.Path) -> list[Finding]:
    relpath = path.resolve().relative_to(root.resolve()).as_posix()
    return lint_source(path.read_text(), relpath)


def lint_repo(root: pathlib.Path,
              dirs: tuple[str, ...] = LINT_DIRS) -> list[Finding]:
    """Sweep every ``*.py`` under the linted directories."""
    findings: list[Finding] = []
    for d in dirs:
        base = root / d
        if not base.is_dir():
            continue
        for path in sorted(base.rglob("*.py")):
            if "__pycache__" in path.parts:
                continue
            findings += lint_file(path, root)
    return findings
