"""Front 2b — S1 (collective pricing coverage) and S3 (Pallas VRF budget),
plus the entry-point registry that traces the repo's public surface.

S1: a collective is *priced* when its replica group resolves onto the
declared :class:`repro.topology.Topology` as an axis-aligned subgrid —
``math.prod(group_level_extents(members, topo)) == len(members)``.  When
that fails (an axis the topology does not own, a mesh/topology size
mismatch, devices outside the topology) the roofline silently falls back
to flat outermost-wire attribution — exactly the PR 2 fig6 memo-bug class
this rule exists to catch before runtime.

S3: every Pallas buffer (operand block or scratch) must fit an LMUL=8
register group (8 x VLEN = 64 KiB at the RVV-maximum 64 Kibit/vreg of
``AraXLParams``) and all resident buffers together must fit the 32-vreg
VRF (256 KiB); blocked specs must tile their arrays exactly.

The registry traces with ``jax.make_jaxpr`` only — nothing executes — but
the ring/attention/MoE entries shard_map over an 8-device mesh, so the
semantic front needs ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
(``python -m repro.analysis`` sets it before importing jax).
"""
from __future__ import annotations

import dataclasses
import itertools
import math

from repro.analysis import Finding
from repro.analysis.schedule_check import (axis_tuple, check_aliasing,
                                           check_ppermute_schedules,
                                           iter_eqns)

#: primitives whose replica groups the roofline prices; reductions are
#: matched by prefix ("psum" traces as `psum2` on this jax)
COLLECTIVE_PRIMITIVES = {
    "ppermute", "all_gather", "all_to_all", "reduce_scatter",
    "psum_scatter",
}
COLLECTIVE_PREFIXES = ("psum", "pmax", "pmin")


def _collective_axes(eqn) -> tuple[str, ...] | None:
    """The mesh axis names a collective runs over, or None if ``eqn`` is
    not a collective (reductions carry ``axes``, the rest ``axis_name``)."""
    name = eqn.primitive.name
    if name in COLLECTIVE_PRIMITIVES:
        return axis_tuple(eqn.params["axis_name"])
    if name.startswith(COLLECTIVE_PREFIXES) and "axes" in eqn.params:
        axes = tuple(a for a in axis_tuple(eqn.params["axes"])
                     if isinstance(a, str))
        return axes or None
    return None

#: RVV 1.0 register file: 32 vregs, LMUL=8 groups of 8 vregs
VRF_VREGS = 32
LMUL_MAX = 8


# ---------------------------------------------------------------------------
# S1 — pricing coverage
# ---------------------------------------------------------------------------

def _pricing_problems(axes: tuple[str, ...], mesh_shape: dict,
                      topology) -> list[str]:
    from repro.roofline.analysis import group_level_extents
    from repro.topology import mesh_levels

    owned: set = set()
    for lvl in topology.levels:
        owned |= set(lvl.axes)
    missing = [a for a in axes if a not in owned]
    if missing:
        return [f"axes {missing} not owned by any level of the declared "
                f"topology {topology.axis_names} — the roofline would "
                f"fall back to flat outermost-wire pricing"]
    try:
        mesh_levels(topology, {a: s for a, s in mesh_shape.items()
                               if a in owned})
    except ValueError as e:
        return [f"mesh/topology mismatch: {e}"]

    # Build the replica group in topology-flat (outer-major) numbering:
    # the collective's axes vary, every other mesh axis is pinned to 0.
    axes_set = set(axes)
    level_coords = []
    for lvl in topology.levels:
        laxes = lvl.axes
        ranges = [range(mesh_shape[a]) if a in axes_set else range(1)
                  for a in laxes]
        coords = set()
        for combo in itertools.product(*ranges):
            c = 0
            for a, v in zip(laxes, combo):
                c = c * mesh_shape[a] + v
            coords.add(c)
        level_coords.append(sorted(coords))
    members = tuple(sorted(
        sum(c * s for c, s in zip(combo, topology.strides()))
        for combo in itertools.product(*level_coords)))
    extents = group_level_extents(members, topology)
    if math.prod(extents) != len(members):
        return [f"replica group of {len(members)} over {axes} is not an "
                f"axis-aligned subgrid of {topology.axis_names} (extents "
                f"{extents}) — priced by the conservative flat fallback"]
    return []


def check_collective_pricing(closed_jaxpr, topology,
                             label: str) -> list[Finding]:
    """Every collective in the trace must price as an axis-aligned subgrid
    of the declared topology (no silent flat-fallback attribution)."""
    findings = []
    seen = set()
    for eqn, mesh in iter_eqns(closed_jaxpr.jaxpr):
        name = eqn.primitive.name
        axes = _collective_axes(eqn)
        if axes is None:
            continue
        if mesh is None:
            findings.append(Finding(
                "S1", label, 0,
                f"{name} over {axes} outside any shard_map mesh — "
                f"unpriceable replica group",
                "run collectives inside the substrate shard_map wrappers"))
            continue
        key = (name, axes)
        if key in seen:                      # one finding per (prim, axes)
            continue
        seen.add(key)
        for prob in _pricing_problems(axes, dict(mesh.shape), topology):
            findings.append(Finding(
                "S1", label, 0, f"{name} over {axes}: {prob}",
                "declare every collective axis as a Topology level (the "
                "geometry the roofline prices) or move the collective "
                "onto declared axes"))
    return findings


# ---------------------------------------------------------------------------
# S3 — Pallas grid/BlockSpec divisibility + VRF budget
# ---------------------------------------------------------------------------

def _dim(d) -> int:
    try:
        return int(d)
    except TypeError:                        # pl.Element-style wrapper
        return int(getattr(d, "block_size"))


def check_pallas_budget(closed_jaxpr, params, label: str) -> list[Finding]:
    """``params`` is an :class:`repro.sim.AraXLParams` — the budget source:
    64 Kibit/vreg, 32 vregs, LMUL=8 groups."""
    vreg_bytes = params.vlen_bits // 8
    buf_budget = LMUL_MAX * vreg_bytes       # one LMUL=8 register group
    total_budget = VRF_VREGS * vreg_bytes    # the whole VRF
    findings = []
    for eqn, _ in iter_eqns(closed_jaxpr.jaxpr):
        if eqn.primitive.name != "pallas_call":
            continue
        gm = eqn.params["grid_mapping"]
        bufs = []                            # (description, nbytes)
        for i, bmap in enumerate(gm.block_mappings):
            shape = tuple(_dim(d) for d in bmap.block_shape)
            arr = bmap.array_shape_dtype
            nbytes = math.prod(shape) * arr.dtype.itemsize
            bufs.append((f"operand {i} block {shape} ({arr.dtype})", nbytes))
            if type(bmap.indexing_mode).__name__ == "Blocked" \
                    and len(shape) == len(arr.shape):
                for bd, ad in zip(shape, arr.shape):
                    if bd and ad % bd:
                        findings.append(Finding(
                            "S3", label, 0,
                            f"operand {i}: array dim {ad} not divisible "
                            f"by block dim {bd} (grid {tuple(gm.grid)}) — "
                            f"ragged trailing block",
                            "pad the array or pick a divisor block shape"))
        inner = eqn.params["jaxpr"]
        n_io = gm.num_inputs + gm.num_outputs
        for v in inner.invars[n_io:]:
            aval = getattr(v.aval, "inner_aval", v.aval)
            nbytes = math.prod(aval.shape) * aval.dtype.itemsize
            bufs.append(
                (f"scratch {tuple(aval.shape)} ({aval.dtype})", nbytes))
        for desc, nbytes in bufs:
            if nbytes > buf_budget:
                findings.append(Finding(
                    "S3", label, 0,
                    f"{desc} = {nbytes} B exceeds one LMUL={LMUL_MAX} "
                    f"register group ({buf_budget} B at "
                    f"{params.vlen_bits}-bit VLEN)",
                    "shrink the block (bm/bn/bk) so a block fits 8 vregs"))
        total = sum(nbytes for _, nbytes in bufs)
        if total > total_budget:
            findings.append(Finding(
                "S3", label, 0,
                f"resident blocks+scratch = {total} B exceed the "
                f"{VRF_VREGS}-vreg VRF ({total_budget} B)",
                "shrink block shapes — the kernel cannot keep all "
                "operands register-resident"))
    return findings


# ---------------------------------------------------------------------------
# Entry-point registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Entry:
    label: str
    closed_jaxpr: object
    topology: object | None      # declared Topology (S1) or None
    params: object | None        # AraXLParams (S3) or None


def _ring_entries():
    import jax
    import jax.numpy as jnp
    from repro.core import ring
    from repro.core.machine import make_machine
    from repro.sim import araxl_params

    p8 = araxl_params(8)                     # 2 clusters x 4 lanes
    spec = make_machine(topology=p8.topology).spec
    topo = spec.topology
    reg = jnp.zeros((16, 2, 4), jnp.float32)
    row = jnp.zeros((8, 8), jnp.float32)
    rs_in = jnp.zeros((8, 16), jnp.float32)

    for h in ("flat", "two-level"):
        yield Entry(
            f"entry:reduce_scalar[{h}]",
            jax.make_jaxpr(lambda d, h=h: ring.reduce_scalar(
                spec, d, "sum", mode="ring", hierarchy=h))(reg),
            topo, None)
        for sched in ("seq", "db"):
            yield Entry(
                f"entry:ring_allgather[{h},{sched}]",
                jax.make_jaxpr(lambda d, h=h, s=sched: ring.ring_allgather(
                    spec, d, mode="ring", hierarchy=h, schedule=s))(row),
                topo, None)
            yield Entry(
                f"entry:ring_reduce_scatter[{h},{sched}]",
                jax.make_jaxpr(
                    lambda d, h=h, s=sched: ring.ring_reduce_scatter(
                        spec, d, mode="ring", hierarchy=h,
                        schedule=s))(rs_in),
                topo, None)
    yield Entry(
        "entry:ring_allgather[xla]",
        jax.make_jaxpr(lambda d: ring.ring_allgather(
            spec, d, mode="xla"))(row),
        topo, None)
    yield Entry(
        "entry:ring_reduce_scatter[xla]",
        jax.make_jaxpr(lambda d: ring.ring_reduce_scatter(
            spec, d, mode="xla"))(rs_in),
        topo, None)


def _ring_attention_entries():
    import jax
    import jax.numpy as jnp
    from repro.parallel.ring_attention import ring_attention
    from repro.topology import Topology

    q = jnp.zeros((1, 16, 2, 8), jnp.float32)
    topo3 = Topology.from_levels([("pod", 2, 8.0), ("cluster", 2, 4.0),
                                  ("lane", 2, 2.0)])
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "cluster", "lane"))
    topo1 = Topology.from_levels([("lane", 8, 2.0)])
    mesh1 = jax.make_mesh((8,), ("lane",))
    for sched in ("seq", "db"):
        yield Entry(
            f"entry:ring_attention[hier2x2x2,{sched}]",
            jax.make_jaxpr(lambda a, b, c, s=sched: ring_attention(
                a, b, c, mesh3, topology=topo3, schedule=s))(q, q, q),
            topo3, None)
        yield Entry(
            f"entry:ring_attention[flat,{sched}]",
            jax.make_jaxpr(lambda a, b, c, s=sched: ring_attention(
                a, b, c, mesh1, axis="lane", schedule=s))(q, q, q),
            topo1, None)


def _moe_entries():
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import layers as L
    from repro.parallel.sharding import ShardingRules, init_params
    from repro.topology import Topology

    cfg = dataclasses.replace(
        get_smoke_config("qwen3-moe-235b-a22b"), n_experts=8,
        experts_per_token=2, capacity_factor=8.0, moe_impl="a2a")
    topo3 = Topology.from_levels([("pod", 2, 8.0), ("cluster", 2, 4.0),
                                  ("lane", 2, 2.0)])
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "cluster", "lane"))
    axes = ("pod", "cluster", "lane")
    rules3 = ShardingRules(mesh3, {"batch": None, "seq": None,
                                   "fsdp": None, "model": axes,
                                   "kv": None, "cache_seq": None,
                                   "act_seq": axes})
    params = init_params(L.moe_defs(cfg), jax.random.key(0))
    x = jnp.zeros((4, 16, cfg.d_model), jnp.float32)
    assert L.moe_mode(cfg, rules3) == "ep_a2a"
    with mesh3:
        for topo, tag in ((topo3, "hier2x2x2"), (None, "flat")):
            yield Entry(
                f"entry:moe_ep_a2a[{tag}]",
                jax.make_jaxpr(lambda p, x_, t=topo: L.moe_layer(
                    p, x_, cfg, rules3, topology=t))(params, x),
                topo3, None)


def _kernel_entries():
    import jax
    import jax.numpy as jnp
    from repro.kernels import flash_attention as fa
    from repro.kernels import matmul as mm
    from repro.kernels import paged_attention as pa
    from repro.kernels import reduction as red
    from repro.kernels import rmsnorm as rn
    from repro.kernels import stencil as st
    from repro.sim import araxl_params

    p64 = araxl_params(64)
    z = lambda *s: jnp.zeros(s, jnp.float32)

    cases = [
        ("fmatmul[256]", lambda: jax.make_jaxpr(
            lambda a, b: mm.matmul(a, b, interpret=True))(
                z(256, 256), z(256, 256))),
        ("flash_attention[S256,D64]", lambda: jax.make_jaxpr(
            lambda q, k, v: fa.flash_attention(q, k, v, interpret=True))(
                z(1, 4, 256, 64), z(1, 2, 256, 64), z(1, 2, 256, 64))),
        ("paged_attention[T256,bt16,D64]", lambda: jax.make_jaxpr(
            lambda q, kp, vp, tb, ln: pa.paged_attention(
                q, kp, vp, tb, ln, interpret=True))(
                z(1, 2, 2, 64), z(2, 17, 16, 64), z(2, 17, 16, 64),
                jnp.zeros((1, 16), jnp.int32), jnp.zeros((1,), jnp.int32))),
        ("rmsnorm[D4096]", lambda: jax.make_jaxpr(
            lambda x, g: rn.rmsnorm(x, g, interpret=True))(
                z(64, 4096), z(4096))),
        ("jacobi2d[64x512]", lambda: jax.make_jaxpr(
            lambda x: st.jacobi2d(x, interpret=True))(z(66, 514))),
        ("fconv2d[64x512,7x7]", lambda: jax.make_jaxpr(
            lambda x, f: st.fconv2d(x, f, interpret=True))(
                z(70, 518), z(7, 7))),
        ("fdotproduct[16Ki]", lambda: jax.make_jaxpr(
            lambda a, b: red.dotprod(a, b, interpret=True))(
                z(16384), z(16384))),
        ("exp[16Ki]", lambda: jax.make_jaxpr(
            lambda x: red.expv(x, interpret=True))(z(16384))),
        ("softmax_rows[W2048]", lambda: jax.make_jaxpr(
            lambda x: red.softmax_rows(x, interpret=True))(z(64, 2048))),
    ]
    for label, trace in cases:
        yield Entry(f"entry:{label}", trace(), None, p64)


def entries() -> list[Entry]:
    import jax
    n = len(jax.devices())
    if n < 8:
        raise RuntimeError(
            f"semantic analysis shard_maps over 8 devices but only {n} "
            f"exist — run `python -m repro.analysis` (sets "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=8 before "
            f"importing jax) or set the env yourself")
    out = []
    out += _ring_entries()
    out += _ring_attention_entries()
    out += _moe_entries()
    out += _kernel_entries()
    return out


def semantic_findings() -> list[Finding]:
    """Trace every registered entry point and run S1 + S2 + S3."""
    findings: list[Finding] = []
    for e in entries():
        if e.topology is not None:
            findings += check_collective_pricing(
                e.closed_jaxpr, e.topology, e.label)
        findings += check_ppermute_schedules(e.closed_jaxpr, e.label)
        findings += check_aliasing(e.closed_jaxpr, e.label)
        if e.params is not None:
            findings += check_pallas_budget(e.closed_jaxpr, e.params,
                                            e.label)
    return findings
