from .pipeline import (DataConfig, Pipeline, SyntheticCorpus, global_batch,
                       make_pipeline)

__all__ = ["DataConfig", "Pipeline", "SyntheticCorpus", "global_batch",
           "make_pipeline"]
