from .pipeline import DataConfig, SyntheticCorpus, make_pipeline

__all__ = ["DataConfig", "SyntheticCorpus", "make_pipeline"]
