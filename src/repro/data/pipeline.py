"""Deterministic, restartable data pipeline (the GLSU of the training side).

Design requirements at pod scale:
* every host produces exactly its shard of the global batch (no central
  dispenser) — element i of the global batch maps to host i // per_host,
  the AraXL memory->cluster byte map applied to examples;
* the stream is a pure function of (seed, step) so a restarted / rescaled
  job replays identically from a checkpointed step — no data-loader state
  to save;
* background prefetch keeps the host busy while the device computes.

The corpus is synthetic (Zipfian unigram mixture with per-document Markov
structure) but the packing/sharding path is the production one.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.2
    mean_doc_len: int = 512
    prefetch: int = 2

    @property
    def per_host(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticCorpus:
    """Zipf-distributed tokens with Markov bigram structure + EOS-packed
    documents — enough statistical texture for loss curves to be meaningful.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # stationary Zipf over the vocabulary
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self.p = ranks ** (-cfg.zipf_a)
        self.p /= self.p.sum()
        # a cheap bigram: token t prefers a band around a random permutation
        self.perm = rng.permutation(V)

    def _doc(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        n = max(8, int(rng.exponential(cfg.mean_doc_len)))
        toks = rng.choice(cfg.vocab_size, size=n, p=self.p)
        # Markov-ize: with prob .5 follow the permutation of the previous
        follow = rng.random(n) < 0.5
        toks[1:] = np.where(follow[1:],
                            self.perm[toks[:-1]] % cfg.vocab_size, toks[1:])
        toks[-1] = 0                              # EOS = 0
        return toks.astype(np.int32)

    def batch(self, step: int) -> np.ndarray:
        """The (per_host, seq_len) shard of global batch ``step`` for this
        host.  Pure function of (seed, step, host_id) — restart-safe."""
        cfg = self.cfg
        out = np.empty((cfg.per_host, cfg.seq_len), np.int32)
        for r in range(cfg.per_host):
            gidx = cfg.host_id * cfg.per_host + r      # global row id
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, gidx]))
            buf = []
            need = cfg.seq_len
            while need > 0:
                d = self._doc(rng)
                buf.append(d[:need])
                need -= len(d)
            out[r] = np.concatenate(buf)[: cfg.seq_len]
        return out


def global_batch(cfg: DataConfig, step: int) -> np.ndarray:
    """The full ``(global_batch, seq_len)`` batch at ``step``, independent
    of the host split: row ``g`` is a pure function of ``(seed, step, g)``,
    so concatenating every host's shard (in host order) is bit-identical to
    generating on one host — the property that makes a checkpoint-rescale
    restart replay the byte-exact token stream on a *different* mesh."""
    full = dataclasses.replace(cfg, n_hosts=1, host_id=0)
    return SyntheticCorpus(full).batch(step)


class Pipeline:
    """Prefetching iterator over host-sharded batches with an explicit,
    checkpointable **cursor**.

    ``cursor`` is the step of the *next* batch ``__next__`` will hand out —
    batches sitting pre-computed in the prefetch queue do not advance it, so
    the value is always safe to persist: a restarted job that rebuilds
    ``Pipeline(cfg, start_step=cursor)`` replays the stream bit-identically
    (there is no other loader state; the stream is a pure function of
    ``(seed, step)``).  The prefetch worker carries ``(step, batch)`` pairs
    and ``__next__`` asserts the pairing, so a cursor/queue desync is a
    loud failure, not silent data skew.
    """

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.cursor = start_step
        self._corpus = SyntheticCorpus(cfg)
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._worker = threading.Thread(target=self._produce,
                                        args=(start_step,), daemon=True)
        self._worker.start()

    def _produce(self, start_step: int):
        step = start_step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._corpus.batch(step)), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> "Pipeline":
        return self

    def __next__(self) -> np.ndarray:
        if self._stop.is_set():
            raise StopIteration
        step, batch = self._q.get()
        assert step == self.cursor, \
            f"pipeline desync: queued step {step} != cursor {self.cursor}"
        self.cursor += 1
        return batch

    def close(self):
        self._stop.set()


def make_pipeline(cfg: DataConfig, start_step: int = 0) -> Iterator[np.ndarray]:
    """Prefetching iterator over host-sharded batches, resumable at any step
    (the historical façade over :class:`Pipeline`)."""
    return Pipeline(cfg, start_step=start_step)
