"""Deterministic, restartable data pipeline (the GLSU of the training side).

Design requirements at pod scale:
* every host produces exactly its shard of the global batch (no central
  dispenser) — element i of the global batch maps to host i // per_host,
  the AraXL memory->cluster byte map applied to examples;
* the stream is a pure function of (seed, step) so a restarted / rescaled
  job replays identically from a checkpointed step — no data-loader state
  to save;
* background prefetch keeps the host busy while the device computes.

The corpus is synthetic (Zipfian unigram mixture with per-document Markov
structure) but the packing/sharding path is the production one.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    zipf_a: float = 1.2
    mean_doc_len: int = 512
    prefetch: int = 2

    @property
    def per_host(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts


class SyntheticCorpus:
    """Zipf-distributed tokens with Markov bigram structure + EOS-packed
    documents — enough statistical texture for loss curves to be meaningful.
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # stationary Zipf over the vocabulary
        ranks = np.arange(1, V + 1, dtype=np.float64)
        self.p = ranks ** (-cfg.zipf_a)
        self.p /= self.p.sum()
        # a cheap bigram: token t prefers a band around a random permutation
        self.perm = rng.permutation(V)

    def _doc(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        n = max(8, int(rng.exponential(cfg.mean_doc_len)))
        toks = rng.choice(cfg.vocab_size, size=n, p=self.p)
        # Markov-ize: with prob .5 follow the permutation of the previous
        follow = rng.random(n) < 0.5
        toks[1:] = np.where(follow[1:],
                            self.perm[toks[:-1]] % cfg.vocab_size, toks[1:])
        toks[-1] = 0                              # EOS = 0
        return toks.astype(np.int32)

    def batch(self, step: int) -> np.ndarray:
        """The (per_host, seq_len) shard of global batch ``step`` for this
        host.  Pure function of (seed, step, host_id) — restart-safe."""
        cfg = self.cfg
        out = np.empty((cfg.per_host, cfg.seq_len), np.int32)
        for r in range(cfg.per_host):
            gidx = cfg.host_id * cfg.per_host + r      # global row id
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, gidx]))
            buf = []
            need = cfg.seq_len
            while need > 0:
                d = self._doc(rng)
                buf.append(d[:need])
                need -= len(d)
            out[r] = np.concatenate(buf)[: cfg.seq_len]
        return out


def make_pipeline(cfg: DataConfig, start_step: int = 0) -> Iterator[np.ndarray]:
    """Prefetching iterator over host-sharded batches, resumable at any step."""
    corpus = SyntheticCorpus(cfg)
    q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
    stop = threading.Event()

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(corpus.batch(step), timeout=1.0)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()

    def gen():
        try:
            while True:
                yield q.get()
        finally:
            stop.set()

    return gen()
