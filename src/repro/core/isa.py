"""RVV-flavoured vector ISA over the distributed register file.

Two interchangeable "machines" expose the same instruction surface:

* :class:`AraXLMachine` — executes on a JAX mesh: elementwise ops are
  device-local on the striped layout, slides/reductions ride the RINGI
  (`repro.core.ring`), loads/stores ride the GLSU (`repro.core.glsu`).
  This is the REQI analogue: one SPMD program, broadcast to every cluster.

* :class:`repro.sim.trace.TraceMachine` — same surface, no data: it appends
  instruction records that the cycle-approximate simulator replays.

The six paper kernels (`repro.core.isa_kernels`) are written once against
this surface and run on either machine — the JAX run validates semantics,
the trace run reproduces the paper's cycle-level figures.

Supported at full throughput (the paper's explicit fast set): unit-stride
loads/stores, slide-by-1, reductions, basic mask ops.  Irregular RVV ops
(gathers, arbitrary slides) exist but take slow paths, as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import substrate
from . import glsu, ring
from .layout import (VReg, VectorLayout, VectorMachineSpec, global_index_grid,
                     valid_mask)


@dataclasses.dataclass
class InstrRecord:
    """One issued vector instruction (consumed by repro.sim)."""
    op: str            # mnemonic, e.g. "vfmacc.vf"
    vl: int            # element count
    unit: str          # fpu | valu | vlsu | sldu | masku | redu
    flops_per_elem: float = 0.0
    meta: dict | None = None


class AraXLMachine:
    """JAX executor for the vector ISA on a hierarchical mesh.

    ``glsu_mode`` / ``reduce_mode`` select paper-faithful staged/ring
    implementations vs flat XLA collectives (the §Perf ablation switch);
    ``hierarchy`` ("flat", or the spec's depth spelled out: "two-level",
    "three-level", ...) picks the flattened lane ring or the paper's
    per-level interconnect — one ring per topology level — for both the
    staged GLSU Align network and the RINGI reductions, defaulting to the
    hierarchy of the spec's shared :class:`repro.topology.Topology`.
    """

    #: ops counted with >1 flop/element (paper Table I: exp is a 7-term
    #: polynomial + range reduction -> 28 FLOP per element over 21 cycles).
    _EXP_FLOPS = 28.0

    def __init__(self, spec: VectorMachineSpec, *, glsu_mode: str = "staged",
                 reduce_mode: str = "ring", hierarchy: Optional[str] = None,
                 dtype=jnp.float32, trace: Optional[list] = None):
        self.spec = spec
        self.glsu_mode = glsu_mode
        self.reduce_mode = reduce_mode
        self.hierarchy = (hierarchy if hierarchy is not None
                          else spec.topology.hierarchy)
        self.dtype = dtype
        self.trace = trace

    # -- bookkeeping --------------------------------------------------------
    @property
    def vlmax(self) -> int:
        return self.spec.vlen_elems

    def _rec(self, op: str, vl: int, unit: str, fpe: float = 0.0, **meta):
        if self.trace is not None:
            self.trace.append(InstrRecord(op, vl, unit, fpe, meta or None))

    def _pad_len(self, vl: int) -> int:
        n = self.spec.n_total_lanes
        quantum = n * n if self.glsu_mode == "staged" else n
        return ((vl + quantum - 1) // quantum) * quantum

    # -- loads / stores (GLSU) ----------------------------------------------
    def vle(self, x, vl: int | None = None) -> VReg:
        x = jnp.asarray(x, self.dtype).reshape(-1)
        vl = int(x.shape[0]) if vl is None else vl
        pvl = self._pad_len(vl)
        if x.shape[0] < pvl:
            x = jnp.pad(x, (0, pvl - x.shape[0]))
        x = jax.lax.with_sharding_constraint(
            x, NamedSharding(self.spec.mesh, self.spec.mem_spec()))
        data = glsu.mem_to_reg(self.spec, x, self.glsu_mode, self.hierarchy)
        self._rec("vle64.v", vl, "vlsu")
        return VReg(data, vl)

    def vse(self, r: VReg) -> jax.Array:
        out = glsu.reg_to_mem(self.spec, r.data, self.glsu_mode,
                              self.hierarchy)
        self._rec("vse64.v", r.vl, "vlsu")
        return out[: r.vl]

    # -- register constructors ----------------------------------------------
    def vbrd(self, value, vl: int) -> VReg:
        pvl = self._pad_len(vl)
        C, L = self.spec.n_clusters, self.spec.n_lanes
        B = pvl // (C * L)
        data = jnp.full((B, C, L), value, self.dtype)
        data = jax.lax.with_sharding_constraint(data, self.spec.reg_sharding())
        r = VReg(data, vl)
        if vl < pvl:  # keep the tail architecturally zero
            data = jnp.where(valid_mask(self.spec, r), data, 0).astype(self.dtype)
            r = VReg(data, vl)
        self._rec("vmv.v.x", vl, "valu")
        return r

    def vid(self, vl: int) -> VReg:
        pvl = self._pad_len(vl)
        C, L = self.spec.n_clusters, self.spec.n_lanes
        B = pvl // (C * L)
        idx = global_index_grid(self.spec, B).astype(self.dtype)
        idx = jnp.where(idx < vl, idx, 0)
        idx = jax.lax.with_sharding_constraint(idx, self.spec.reg_sharding())
        self._rec("vid.v", vl, "valu")
        return VReg(idx, vl)

    # -- elementwise (lane-local, no communication) --------------------------
    def _ew2(self, op: str, unit: str, f, a: VReg, b, fpe=1.0) -> VReg:
        bb = b.data if isinstance(b, VReg) else jnp.asarray(b, self.dtype)
        vl = a.vl if not isinstance(b, VReg) else min(a.vl, b.vl)
        out = f(a.data, bb)
        self._rec(op, vl, unit, fpe)
        return VReg(out.astype(self.dtype), vl)

    def vadd(self, a: VReg, b) -> VReg:
        return self._ew2("vfadd" if jnp.issubdtype(self.dtype, jnp.floating) else "vadd",
                         "fpu", jnp.add, a, b)

    def vsub(self, a: VReg, b) -> VReg:
        return self._ew2("vfsub", "fpu", jnp.subtract, a, b)

    def vmul(self, a: VReg, b) -> VReg:
        return self._ew2("vfmul", "fpu", jnp.multiply, a, b)

    def vdiv(self, a: VReg, b) -> VReg:
        return self._ew2("vfdiv", "fpu", jnp.divide, a, b)

    def vmax(self, a: VReg, b) -> VReg:
        return self._ew2("vfmax", "fpu", jnp.maximum, a, b)

    def vmin(self, a: VReg, b) -> VReg:
        return self._ew2("vfmin", "fpu", jnp.minimum, a, b)

    def vfma(self, a: VReg, b, c) -> VReg:
        """a*b + c (vv or vf by b's type). One FMA = 2 FLOP."""
        bb = b.data if isinstance(b, VReg) else jnp.asarray(b, self.dtype)
        cc = c.data if isinstance(c, VReg) else jnp.asarray(c, self.dtype)
        out = a.data * bb + cc
        self._rec("vfmacc", a.vl, "fpu", 2.0)
        return VReg(out.astype(self.dtype), a.vl)

    def vfmacc_vf(self, acc: VReg, scalar, v: VReg) -> VReg:
        out = acc.data + jnp.asarray(scalar, self.dtype) * v.data
        self._rec("vfmacc.vf", v.vl, "fpu", 2.0)
        return VReg(out.astype(self.dtype), v.vl)

    def vexp(self, a: VReg) -> VReg:
        out = jnp.where(valid_mask(self.spec, a), jnp.exp(a.data), 0)
        self._rec("vexp(poly)", a.vl, "fpu", self._EXP_FLOPS)
        return VReg(out.astype(self.dtype), a.vl)

    # -- masks (MASKU: same layout as data => local) -------------------------
    def vmslt(self, a: VReg, b) -> VReg:
        return self._ew2("vmslt", "masku",
                         lambda x, y: (x < y), a, b, fpe=0.0)

    def vmsge(self, a: VReg, b) -> VReg:
        return self._ew2("vmsge", "masku", lambda x, y: (x >= y), a, b, fpe=0.0)

    def vmerge(self, mask: VReg, a: VReg, b) -> VReg:
        bb = b.data if isinstance(b, VReg) else jnp.asarray(b, self.dtype)
        out = jnp.where(mask.data.astype(bool), a.data, bb)
        self._rec("vmerge", a.vl, "masku")
        return VReg(out.astype(self.dtype), a.vl)

    def vcpop(self, mask: VReg) -> jax.Array:
        live = jnp.logical_and(mask.data.astype(bool), valid_mask(self.spec, mask))
        self._rec("vcpop", mask.vl, "masku")
        return jnp.sum(live)

    # -- slides (RINGI) -------------------------------------------------------
    def vslide1down(self, a: VReg, fill=0.0) -> VReg:
        out = ring.slide1down(self.spec, a.data, fill)
        self._rec("vfslide1down", a.vl, "sldu", meta={"hops": 1})
        return VReg(out, a.vl)

    def vslide1up(self, a: VReg, fill=0.0) -> VReg:
        out = ring.slide1up(self.spec, a.data, fill)
        self._rec("vfslide1up", a.vl, "sldu", meta={"hops": 1})
        return VReg(out, a.vl)

    def vslidedown(self, a: VReg, k: int) -> VReg:
        axes, n = self.spec.ring_axes, self.spec.n_total_lanes
        reg = self.spec.reg_spec()

        def fn(x):
            col = x.reshape(x.shape[0])
            out = ring.slidedown_local(col, axes, n, k, 0.0)
            return out.reshape(-1, 1, 1)

        out = substrate.shard_map(fn, mesh=self.spec.mesh, in_specs=(reg,),
                                  out_specs=reg)(a.data)
        self._rec("vslidedown.vx", a.vl, "sldu", meta={"hops": k % n})
        return VReg(out, a.vl)

    # -- reductions (intra-lane -> inter-lane -> inter-cluster log tree) ------
    def vredsum(self, a: VReg) -> jax.Array:
        masked = jnp.where(valid_mask(self.spec, a), a.data, 0)
        out = ring.reduce_scalar(self.spec, masked.astype(self.dtype), "sum",
                                 self.reduce_mode, self.hierarchy)
        self._rec("vfredsum", a.vl, "redu", 1.0)
        return out

    def vredmax(self, a: VReg) -> jax.Array:
        neg = jnp.asarray(-jnp.inf, self.dtype)
        masked = jnp.where(valid_mask(self.spec, a), a.data, neg)
        out = ring.reduce_scalar(self.spec, masked.astype(self.dtype), "max",
                                 self.reduce_mode, self.hierarchy)
        self._rec("vfredmax", a.vl, "redu", 1.0)
        return out

    # -- stripmining ----------------------------------------------------------
    def stripmine(self, total: int, lmul: int = 1):
        """Yield (offset, vl) chunks, RVV vsetvli-style."""
        step = self.vlmax * lmul
        off = 0
        while off < total:
            vl = min(step, total - off)
            self._rec("vsetvli", vl, "seq")
            yield off, vl
            off += vl
