"""AraXL core: distributed long-vector register file, ring interconnect,
staged GLSU, and the vector ISA — the paper's contribution as JAX modules."""
from repro.topology import Topology
from .isa import AraXLMachine, InstrRecord
from .layout import (VReg, VectorLayout, VectorMachineSpec, coords_to_element,
                     element_to_coords)
from .machine import make_machine, make_vector_mesh

__all__ = [
    "AraXLMachine", "InstrRecord", "Topology", "VReg", "VectorLayout",
    "VectorMachineSpec", "coords_to_element", "element_to_coords",
    "make_machine", "make_vector_mesh",
]
