"""RINGI — the AraXL ring interconnect, as TPU-native collectives (§III-B.4).

AraXL joins adjacent vector clusters with a ring carrying 64 bit/cycle per
direction, because the dominant permutation patterns of HPC/ML long-vector
code are slide-by-1 (stencils, shifted products) and reductions — both
neighbour-only.  On TPU the ICI torus makes ``jax.lax.ppermute`` (a physical
neighbour hop when the permutation is a ring shift) the exact analogue.

Everything here is written with ``jax.shard_map`` over the *flattened ring* of
all lanes (cluster-major, lane-minor — the same order as the element striping),
so a slide-by-1 of the architectural vector is one neighbour ppermute plus a
purely local fix-up, and a full reduction is the paper's 4-stage pipeline:

    SIMD/intra-lane  : local ``jnp`` reduce of the lane's VRF rows
    inter-lane       : log2(L) ppermute hops inside the cluster
    inter-cluster    : log2(C) ppermute hops on the ring ("log-tree fashion,
                       utilises multiple hops for later stages" — §III-B.4)
    broadcast        : free (recursive doubling leaves the total everywhere)

The functions take ``axis_names`` = the flattened ring axes and run inside an
enclosing ``shard_map``; the ``*_op`` wrappers at the bottom build the full
shard_map'd callable for a :class:`~repro.core.layout.VectorMachineSpec`.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .layout import VectorLayout, VectorMachineSpec


# ---------------------------------------------------------------------------
# In-shard_map primitives (operate on the local block, use collectives).
# ---------------------------------------------------------------------------

def ring_size(axis_names: Sequence[str]) -> int:
    return jax.lax.axis_size(tuple(axis_names))


def ring_pos(axis_names: Sequence[str]) -> jax.Array:
    return jax.lax.axis_index(tuple(axis_names))


def _shift_perm(n: int, shift: int) -> list[tuple[int, int]]:
    """Source->dest pairs for a circular shift by ``shift`` (data moves from
    ring position p to p-shift, i.e. each device receives from p+shift)."""
    return [(p, (p - shift) % n) for p in range(n)]


def ppermute_shift(x: jax.Array, axis_names: Sequence[str], shift: int,
                   n: int) -> jax.Array:
    """Receive the block of the device ``shift`` positions ahead on the ring."""
    return jax.lax.ppermute(x, tuple(axis_names), perm=_shift_perm(n, shift))


# -- slides ------------------------------------------------------------------

def slide1down_local(x: jax.Array, axis_names: Sequence[str], n: int,
                     fill: jax.Array | float = 0.0) -> jax.Array:
    """out[i] = in[i+1], out[vl-1] = fill, on the striped layout.

    Local block is the (B,) column of one lane (ring position p holds elements
    ``i = b*n + p``).  Element i+1 lives at ring position p+1 (same row), except
    for the last lane, whose successor wraps to lane 0, *next* row.  So: one
    neighbour ppermute of the whole column + a row-shift fix-up on the last
    lane only — exactly AraXL's single-hop slide. ``fill`` enters at the tail.
    """
    p = ring_pos(axis_names)
    nbr = ppermute_shift(x, axis_names, 1, n)         # column of lane p+1 (mod n)
    # Last lane got lane-0's column but needs it advanced one row.
    advanced = jnp.concatenate([nbr[1:], jnp.full_like(nbr[:1], fill)], axis=0)
    return jnp.where(p == n - 1, advanced, nbr)


def slide1up_local(x: jax.Array, axis_names: Sequence[str], n: int,
                   fill: jax.Array | float = 0.0) -> jax.Array:
    """out[i] = in[i-1], out[0] = fill (striped layout)."""
    p = ring_pos(axis_names)
    nbr = ppermute_shift(x, axis_names, -1, n)        # column of lane p-1 (mod n)
    delayed = jnp.concatenate([jnp.full_like(nbr[:1], fill), nbr[:-1]], axis=0)
    return jnp.where(p == 0, delayed, nbr)


def slidedown_local(x: jax.Array, axis_names: Sequence[str], n: int, k: int,
                    fill: jax.Array | float = 0.0) -> jax.Array:
    """out[i] = in[i+k] — decomposed into a ring hop of k mod n plus a local
    row shift of k // n (AraXL: 'slides larger than 1 are implemented using
    multiple 64-bit transfers or bypasses on the ring'). k is static."""
    hop, rows = k % n, k // n
    p = ring_pos(axis_names)
    if hop:
        y = ppermute_shift(x, axis_names, hop, n)
        wrapped = p >= n - hop          # these lanes' source crossed the ring end
    else:
        y = x
        wrapped = jnp.zeros((), dtype=bool)

    def rshift(v: jax.Array, r: int) -> jax.Array:
        if r == 0:
            return v
        r = min(r, v.shape[0])
        pad = jnp.full((r,) + v.shape[1:], fill, v.dtype)
        return jnp.concatenate([v[r:], pad], axis=0)

    return jnp.where(wrapped, rshift(y, rows + 1), rshift(y, rows))


# -- reductions ---------------------------------------------------------------

def ring_allreduce_local(x: jax.Array, axis_names: Sequence[str], n: int,
                         op: Callable[[jax.Array, jax.Array], jax.Array] = jnp.add,
                         ) -> jax.Array:
    """Recursive-doubling all-reduce built only from ring shifts.

    Step k combines with the value ``2**k`` positions away — AraXL's log-tree
    inter-lane/inter-cluster stages (later stages ride multiple ring hops).
    Works for any n (non-power-of-2 handled by a final fold of the stragglers
    via a masked extra step using a gather-style shift)."""
    total = x
    k = 1
    while k < n:
        total = op(total, ppermute_shift(total, axis_names, k, n))
        k *= 2
    if (n & (n - 1)) != 0:
        # Non-power-of-two ring: recursive doubling over-counts. Fall back to
        # an exact (n-1)-step ring accumulation for correctness.
        total = x
        acc = x
        for _ in range(n - 1):
            acc = ppermute_shift(acc, axis_names, 1, n)
            total = op(total, acc)
    return total


def reduce_to_scalar_local(col: jax.Array, axis_names: Sequence[str], n: int,
                           op: str = "sum") -> jax.Array:
    """The paper's full 4-stage reduction for one vreg column.

    op in {sum, max, min}. Returns the reduction replicated on every lane
    (cluster-0/lane-0 would forward it to the scalar core via REQI)."""
    if op == "sum":
        local = jnp.sum(col, axis=0)
        comb = jnp.add
    elif op == "max":
        local = jnp.max(col, axis=0)
        comb = jnp.maximum
    elif op == "min":
        local = jnp.min(col, axis=0)
        comb = jnp.minimum
    else:  # pragma: no cover - guarded by callers
        raise ValueError(f"unsupported reduction {op}")
    return ring_allreduce_local(local, axis_names, n, comb)


# -- ring all-gather / reduce-scatter (GLSU staging + FSDP overlap) -----------

def ring_allgather_local(x: jax.Array, axis_names: Sequence[str], n: int) -> jax.Array:
    """Classic (n-1)-step ring all-gather along axis 0: per step every device
    forwards the block it received last step to its ring neighbour.
    Bandwidth-optimal; each step is a single neighbour hop (RINGI discipline).
    Returns the global array in ring order: out[j] = block of ring position j.
    """
    chunks = [x]
    cur = x
    for _ in range(n - 1):
        cur = ppermute_shift(cur, axis_names, 1, n)   # receive from p+1
        chunks.append(cur)
    # arrival slot j holds the block of ring position (p + j) mod n;
    # rotate into global order: global slot g <- arrival slot (g - p) mod n.
    p = ring_pos(axis_names)
    stacked = jnp.stack(chunks, axis=0)               # [n, ...] arrival order
    idx = (jnp.arange(n) - p) % n
    stacked = jnp.take(stacked, idx, axis=0)
    return stacked.reshape((n * x.shape[0],) + x.shape[1:])


def ring_reduce_scatter_local(x: jax.Array, axis_names: Sequence[str], n: int) -> jax.Array:
    """(n-1)-step ring reduce-scatter along axis 0: ring position p ends up
    with ``sum_over_devices(x)[p-th chunk]``, each step one neighbour hop."""
    assert x.shape[0] % n == 0
    p = ring_pos(axis_names)
    stacked = jnp.stack(jnp.split(x, n, axis=0), axis=0)  # [n, B/n, ...]

    def pick(i):
        return jnp.take(stacked, (p + i) % n, axis=0)

    acc = pick(1)                                     # partial for chunk p+1
    for s in range(2, n + 1):
        acc = ppermute_shift(acc, axis_names, 1, n)   # now partial for chunk p+s
        acc = acc + pick(s)
    return acc                                        # fully-summed chunk p


# ---------------------------------------------------------------------------
# Whole-register ops for a VectorMachineSpec (shard_map wrappers).
# ---------------------------------------------------------------------------

def _striped_shard_map(spec: VectorMachineSpec, fn, n_out: int = 1):
    reg = spec.reg_spec(VectorLayout.STRIPED)
    return jax.shard_map(
        fn, mesh=spec.mesh,
        in_specs=(reg,),
        out_specs=reg if n_out == 1 else tuple(reg for _ in range(n_out)),
    )


def _local_col(x: jax.Array) -> jax.Array:
    # striped local block is (B, 1, 1)
    return x.reshape(x.shape[0])


def _from_col(col: jax.Array) -> jax.Array:
    return col.reshape(col.shape[0], 1, 1)


def slide1down(spec: VectorMachineSpec, data: jax.Array, fill: float = 0.0) -> jax.Array:
    axes, n = spec.ring_axes, spec.n_total_lanes

    def fn(x):
        return _from_col(slide1down_local(_local_col(x), axes, n, fill))

    return _striped_shard_map(spec, fn)(data)


def slide1up(spec: VectorMachineSpec, data: jax.Array, fill: float = 0.0) -> jax.Array:
    axes, n = spec.ring_axes, spec.n_total_lanes

    def fn(x):
        return _from_col(slide1up_local(_local_col(x), axes, n, fill))

    return _striped_shard_map(spec, fn)(data)


def reduce_scalar(spec: VectorMachineSpec, data: jax.Array, op: str = "sum",
                  mode: str = "ring") -> jax.Array:
    """Full-register reduction. mode='ring' is the paper-faithful log-tree on
    neighbour hops; mode='xla' lets XLA pick (flat all-reduce) — the §Perf
    comparison point."""
    axes, n = spec.ring_axes, spec.n_total_lanes
    reg = spec.reg_spec(VectorLayout.STRIPED)

    if mode == "xla":
        red = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min}[op]
        return red(data)

    def fn(x):
        col = _local_col(x)
        return reduce_to_scalar_local(col, axes, n, op).reshape(1, 1, 1)

    out = jax.shard_map(fn, mesh=spec.mesh, in_specs=(reg,),
                        out_specs=P(None, spec.cluster_axis, spec.lane_axis))(data)
    return out.reshape(-1)[0]
