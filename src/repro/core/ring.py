"""RINGI — the AraXL ring interconnect, as TPU-native collectives (§III-B.4).

AraXL joins adjacent vector clusters with a ring carrying 64 bit/cycle per
direction, because the dominant permutation patterns of HPC/ML long-vector
code are slide-by-1 (stencils, shifted products) and reductions — both
neighbour-only.  On TPU the ICI torus makes ``ppermute`` (a physical
neighbour hop when the permutation is a ring shift) the exact analogue.

Two interconnect models coexist, selected by ``hierarchy=`` (defaulting to
the hierarchy of the spec's shared :class:`repro.topology.Topology` — the
same geometry type ``repro.sim.AraXLParams`` composes, so the emulator and
the analytical cost model always describe the same interconnect):

``"flat"``       the flattened ring of all n lanes (outer-major — the same
                 order as the element striping): every collective is
                 log2(n) or n-1 hops on one ring.

``"two-level"``  the paper's hierarchy (§III-B.4): collectives run first over
(and deeper)     the *lane* axis inside each cluster (log2(L) short hops on
                 the intra-cluster interconnect), then over the *cluster*
                 axis on the inter-cluster ring (log2(C) hops).  This is the
                 structure AraXL argues makes the design physically scalable:
                 the long wires only ever carry the per-cluster stage — and
                 it recurses: the ``*_hier`` walkers run one ring per
                 topology level, so a (pod, cluster, lane) machine adds a
                 log2(P) pod stage whose wires never see cluster traffic
                 (``"three-level"`` and beyond, named by depth).

Either way a full reduction is the paper's 4-stage pipeline:

    SIMD/intra-lane  : local ``jnp`` reduce of the lane's VRF rows
    inter-lane       : log2(L) hops inside the cluster
    inter-cluster    : log2(C) hops on the ring ("log-tree fashion,
                       utilises multiple hops for later stages" — §III-B.4)
    broadcast        : free (recursive doubling leaves the total everywhere)

The ``*_local`` functions take axis names and run inside an enclosing
``shard_map`` (resolved portably via :mod:`repro.substrate`); the wrappers at
the bottom build the full shard_map'd callable for a
:class:`~repro.core.layout.VectorMachineSpec`.
"""
from __future__ import annotations

import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import substrate
from repro.topology import HIERARCHIES, check_hierarchy as _check_hierarchy
from .layout import VectorLayout, VectorMachineSpec

MODES = ("ring", "xla")
SCHEDULES = ("seq", "db")


def _check_schedule(schedule: str) -> None:
    if schedule not in SCHEDULES:
        raise ValueError(f"schedule must be one of {SCHEDULES}, "
                         f"got {schedule!r}")


def _resolve_hierarchy(spec: VectorMachineSpec, hierarchy: str | None) -> str:
    """None -> the hierarchy of the spec's shared Topology; explicit strings
    must be "flat" or spell the spec's own depth (e.g. "two-level" on a
    (C, L) spec, "three-level" on (P, C, L))."""
    if hierarchy is None:
        return spec.topology.hierarchy
    _check_hierarchy(hierarchy, spec.topology.n_levels)
    return hierarchy


def _levels_inner_first(spec: VectorMachineSpec) -> list:
    """The spec's topology levels as (mesh-axes, size) pairs, innermost
    first — the walk order of the hierarchical collectives."""
    return list(reversed(spec.topology_levels()))


def _check_mode(mode: str) -> None:
    if mode not in MODES:
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")


# ---------------------------------------------------------------------------
# In-shard_map primitives (operate on the local block, use collectives).
# ---------------------------------------------------------------------------

def ring_size(axis_names: Sequence[str]) -> int:
    """Ring size derived from the mesh axes (portable: no jax.lax.axis_size)."""
    return substrate.axis_size(tuple(axis_names))


def ring_pos(axis_names: Sequence[str]) -> jax.Array:
    return substrate.axis_index(tuple(axis_names))


def _shift_perm(n: int, shift: int) -> list[tuple[int, int]]:
    """Source->dest pairs for a circular shift by ``shift`` (data moves from
    ring position p to p-shift, i.e. each device receives from p+shift)."""
    return [(p, (p - shift) % n) for p in range(n)]


def ppermute_shift(x: jax.Array, axis_names: Sequence[str], shift: int,
                   n: int) -> jax.Array:
    """Receive the block of the device ``shift`` positions ahead on the ring."""
    return substrate.ppermute(x, tuple(axis_names), _shift_perm(n, shift))


# -- slides ------------------------------------------------------------------

def slide1down_local(x: jax.Array, axis_names: Sequence[str], n: int,
                     fill: jax.Array | float = 0.0) -> jax.Array:
    """out[i] = in[i+1], out[vl-1] = fill, on the striped layout.

    Local block is the (B,) column of one lane (ring position p holds elements
    ``i = b*n + p``).  Element i+1 lives at ring position p+1 (same row), except
    for the last lane, whose successor wraps to lane 0, *next* row.  So: one
    neighbour ppermute of the whole column + a row-shift fix-up on the last
    lane only — exactly AraXL's single-hop slide. ``fill`` enters at the tail.
    """
    p = ring_pos(axis_names)
    nbr = ppermute_shift(x, axis_names, 1, n)         # column of lane p+1 (mod n)
    # Last lane got lane-0's column but needs it advanced one row.
    advanced = jnp.concatenate([nbr[1:], jnp.full_like(nbr[:1], fill)], axis=0)
    return jnp.where(p == n - 1, advanced, nbr)


def slide1up_local(x: jax.Array, axis_names: Sequence[str], n: int,
                   fill: jax.Array | float = 0.0) -> jax.Array:
    """out[i] = in[i-1], out[0] = fill (striped layout)."""
    p = ring_pos(axis_names)
    nbr = ppermute_shift(x, axis_names, -1, n)        # column of lane p-1 (mod n)
    delayed = jnp.concatenate([jnp.full_like(nbr[:1], fill), nbr[:-1]], axis=0)
    return jnp.where(p == 0, delayed, nbr)


def slidedown_local(x: jax.Array, axis_names: Sequence[str], n: int, k: int,
                    fill: jax.Array | float = 0.0) -> jax.Array:
    """out[i] = in[i+k] — decomposed into a ring hop of k mod n plus a local
    row shift of k // n (AraXL: 'slides larger than 1 are implemented using
    multiple 64-bit transfers or bypasses on the ring'). k is static."""
    hop, rows = k % n, k // n
    p = ring_pos(axis_names)
    if hop:
        y = ppermute_shift(x, axis_names, hop, n)
        wrapped = p >= n - hop          # these lanes' source crossed the ring end
    else:
        y = x
        wrapped = jnp.zeros((), dtype=bool)

    def rshift(v: jax.Array, r: int) -> jax.Array:
        if r == 0:
            return v
        r = min(r, v.shape[0])
        pad = jnp.full((r,) + v.shape[1:], fill, v.dtype)
        return jnp.concatenate([v[r:], pad], axis=0)

    return jnp.where(wrapped, rshift(y, rows + 1), rshift(y, rows))


# -- reductions ---------------------------------------------------------------

def ring_allreduce_local(x: jax.Array, axis_names: Sequence[str], n: int,
                         op: Callable[[jax.Array, jax.Array], jax.Array] = jnp.add,
                         ) -> jax.Array:
    """Recursive-doubling all-reduce built only from ring shifts.

    Step k combines with the value ``2**k`` positions away — AraXL's log-tree
    inter-lane/inter-cluster stages (later stages ride multiple ring hops).
    Works for any n (non-power-of-2 handled by a final fold of the stragglers
    via a masked extra step using a gather-style shift)."""
    total = x
    k = 1
    while k < n:
        total = op(total, ppermute_shift(total, axis_names, k, n))
        k *= 2
    if (n & (n - 1)) != 0:
        # Non-power-of-two ring: recursive doubling over-counts. Fall back to
        # an exact (n-1)-step ring accumulation for correctness.
        total = x
        acc = x
        for _ in range(n - 1):
            acc = ppermute_shift(acc, axis_names, 1, n)
            total = op(total, acc)
    return total


def _reduce_fns(op: str):
    if op == "sum":
        return functools.partial(jnp.sum, axis=0), jnp.add
    if op == "max":
        return functools.partial(jnp.max, axis=0), jnp.maximum
    if op == "min":
        return functools.partial(jnp.min, axis=0), jnp.minimum
    raise ValueError(f"unsupported reduction {op}")


def reduce_to_scalar_local(col: jax.Array, axis_names: Sequence[str], n: int,
                           op: str = "sum") -> jax.Array:
    """The paper's full 4-stage reduction for one vreg column, on the
    flattened ring.

    op in {sum, max, min}. Returns the reduction replicated on every lane
    (cluster-0/lane-0 would forward it to the scalar core via REQI)."""
    local_red, comb = _reduce_fns(op)
    return ring_allreduce_local(local_red(col), axis_names, n, comb)


def reduce_to_scalar_local_hier(col: jax.Array, levels: Sequence,
                                op: str = "sum") -> jax.Array:
    """§III-B.4 hierarchical reduction, one ring per topology level:
    intra-lane first, then log-tree all-reduces walking ``levels``
    (innermost-first (axes, size) pairs) outward — log2(L) short hops, then
    log2(C) ring hops, then log2(P) pod hops, ...

    Same result as the flat reduction, but no stage ever spans more than one
    hierarchy level — the wires that scale with the machine never see the
    inner levels' traffic.
    """
    local_red, comb = _reduce_fns(op)
    total = local_red(col)
    for axes, size in levels:
        total = ring_allreduce_local(total, axes, size, comb)
    return total


def reduce_to_scalar_local_two_level(col: jax.Array,
                                     cluster_axes: Sequence[str], C: int,
                                     lane_axes: Sequence[str], L: int,
                                     op: str = "sum") -> jax.Array:
    """The two-level special case of :func:`reduce_to_scalar_local_hier`."""
    return reduce_to_scalar_local_hier(
        col, [(tuple(lane_axes), L), (tuple(cluster_axes), C)], op)


# -- ring all-gather / reduce-scatter (GLSU staging + FSDP overlap) -----------

def _ring_order(chunks: list, axis_names: Sequence[str], n: int,
                blk0: int) -> jax.Array:
    """Rotate per-step arrival chunks into global ring order and flatten:
    arrival slot j holds the block of ring position (p + j) mod n, so global
    slot g <- arrival slot (g - p) mod n."""
    p = ring_pos(axis_names)
    stacked = jnp.stack(chunks, axis=0)               # [n, ...] arrival order
    idx = (jnp.arange(n) - p) % n
    stacked = jnp.take(stacked, idx, axis=0)
    return stacked.reshape((n * blk0,) + stacked.shape[2:])


def ring_allgather_local(x: jax.Array, axis_names: Sequence[str], n: int) -> jax.Array:
    """Classic (n-1)-step ring all-gather along axis 0: per step every device
    forwards the block it received last step to its ring neighbour.
    Bandwidth-optimal; each step is a single neighbour hop (RINGI discipline).
    Returns the global array in ring order: out[j] = block of ring position j.
    """
    chunks = [x]
    cur = x
    for _ in range(n - 1):
        cur = ppermute_shift(cur, axis_names, 1, n)   # receive from p+1
        chunks.append(cur)
    return _ring_order(chunks, axis_names, n, x.shape[0])


def ring_allgather_local_db(x: jax.Array, axis_names: Sequence[str], n: int,
                            consume: Callable | None = None) -> jax.Array:
    """Double-buffered ring all-gather: the hop that fetches block ``j+1``
    is issued *before* block ``j`` is consumed, so the shift rides the wires
    while the consumer computes — AraXL's slide-behind-compute discipline
    applied to the whole gather.

    ``consume(block, j)`` (``j`` the arrival step; the block belongs to ring
    position ``(p + j) mod n``) is applied to every block as it lands; its
    outputs are returned stacked in global ring order.  Without a consumer
    the result is **bit-identical** to :func:`ring_allgather_local` — the
    same blocks arrive in the same order, only the issue order interleaves.
    """
    chunks = []
    cur = x
    for j in range(n):
        nxt = ppermute_shift(cur, axis_names, 1, n) if j < n - 1 else None
        chunks.append(consume(cur, j) if consume is not None else cur)
        cur = nxt
    return _ring_order(chunks, axis_names, n, chunks[0].shape[0])


def ring_allgather_local_hier(x: jax.Array, levels: Sequence,
                              schedule: str = "seq") -> jax.Array:
    """Hierarchical all-gather walking ``levels`` (innermost-first (axes,
    size) pairs): L-1 intra-cluster hops assemble each cluster's lane blocks
    (lane-minor order), then C-1 ring hops exchange whole cluster blocks,
    then P-1 pod hops exchange whole pod blocks, ... — together exactly the
    flattened outer-major ring order, with only aggregated payloads on each
    level's longer wires.  ``schedule="db"`` double-buffers every level's
    ring (bit-identical blocks, next hop issued before the current block is
    consumed)."""
    local = (ring_allgather_local_db if schedule == "db"
             else ring_allgather_local)
    for axes, size in levels:
        x = local(x, axes, size)
    return x


def ring_allgather_local_two_level(x: jax.Array,
                                   cluster_axes: Sequence[str], C: int,
                                   lane_axes: Sequence[str], L: int) -> jax.Array:
    """The two-level special case of :func:`ring_allgather_local_hier`."""
    return ring_allgather_local_hier(
        x, [(tuple(lane_axes), L), (tuple(cluster_axes), C)])


def ring_reduce_scatter_local(x: jax.Array, axis_names: Sequence[str], n: int) -> jax.Array:
    """(n-1)-step ring reduce-scatter along axis 0: ring position p ends up
    with ``sum_over_devices(x)[p-th chunk]``, each step one neighbour hop."""
    assert x.shape[0] % n == 0
    p = ring_pos(axis_names)
    stacked = jnp.stack(jnp.split(x, n, axis=0), axis=0)  # [n, B/n, ...]

    def pick(i):
        return jnp.take(stacked, (p + i) % n, axis=0)

    acc = pick(1)                                     # partial for chunk p+1
    for s in range(2, n + 1):
        acc = ppermute_shift(acc, axis_names, 1, n)   # now partial for chunk p+s
        acc = acc + pick(s)
    return acc                                        # fully-summed chunk p


def ring_reduce_scatter_local_db(x: jax.Array, axis_names: Sequence[str],
                                 n: int, n_chunks: int = 2) -> jax.Array:
    """Chunked double-buffered ring reduce-scatter: the payload is split
    into ``n_chunks`` interleaved pipelines so that while one sub-chunk's
    partial sum is on the wires, another's local add is streaming — per
    ring step every shift is issued before any add consumes its arrival.
    Falls back to a single pipeline when the payload doesn't split.

    Each element sees exactly the same additions in the same order as
    :func:`ring_reduce_scatter_local`, so the result is **bit-identical**
    to the sequential schedule."""
    assert x.shape[0] % n == 0
    p = ring_pos(axis_names)
    stacked = jnp.stack(jnp.split(x, n, axis=0), axis=0)  # [n, B/n, ...]
    if stacked.shape[-1] % n_chunks:
        n_chunks = 1
    parts = jnp.split(stacked, n_chunks, axis=-1)

    def pick(part, i):
        return jnp.take(part, (p + i) % n, axis=0)

    accs = [pick(part, 1) for part in parts]          # partials for chunk p+1
    for s in range(2, n + 1):
        # issue every sub-chunk's hop first, then run the adds behind them
        shifted = [ppermute_shift(a, axis_names, 1, n) for a in accs]
        accs = [sh + pick(part, s) for sh, part in zip(shifted, parts)]
    return jnp.concatenate(accs, axis=-1) if n_chunks > 1 else accs[0]


def ring_reduce_scatter_local_hier(x: jax.Array, levels: Sequence,
                                   schedule: str = "seq") -> jax.Array:
    """Hierarchical reduce-scatter walking ``levels`` (innermost-first
    (axes, size) pairs) from the *outside in*: first the outermost ring
    reduce-scatters its superchunks (each device keeps its outer-coordinate
    superchunk, partially summed at fixed inner coordinates), then each
    inner level splits its level's chunk further.  Device p ends with chunk
    p of the total — identical placement to the flat schedule.
    ``schedule="db"`` runs each level's ring chunk-pipelined
    (:func:`ring_reduce_scatter_local_db`, bit-identical sums)."""
    local = (ring_reduce_scatter_local_db if schedule == "db"
             else ring_reduce_scatter_local)
    for axes, size in reversed(list(levels)):
        x = local(x, axes, size)
    return x


def ring_reduce_scatter_local_two_level(x: jax.Array,
                                        cluster_axes: Sequence[str], C: int,
                                        lane_axes: Sequence[str], L: int
                                        ) -> jax.Array:
    """The two-level special case of :func:`ring_reduce_scatter_local_hier`."""
    return ring_reduce_scatter_local_hier(
        x, [(tuple(lane_axes), L), (tuple(cluster_axes), C)])


# ---------------------------------------------------------------------------
# Whole-register ops for a VectorMachineSpec (shard_map wrappers).
# ---------------------------------------------------------------------------

def _striped_shard_map(spec: VectorMachineSpec, fn, n_out: int = 1):
    reg = spec.reg_spec(VectorLayout.STRIPED)
    return substrate.shard_map(
        fn, mesh=spec.mesh,
        in_specs=(reg,),
        out_specs=reg if n_out == 1 else tuple(reg for _ in range(n_out)),
    )


def _local_col(x: jax.Array) -> jax.Array:
    # striped local block is (B, 1, 1)
    return x.reshape(x.shape[0])


def _from_col(col: jax.Array) -> jax.Array:
    return col.reshape(col.shape[0], 1, 1)


def slide1down(spec: VectorMachineSpec, data: jax.Array, fill: float = 0.0) -> jax.Array:
    axes, n = spec.ring_axes, spec.n_total_lanes

    def fn(x):
        return _from_col(slide1down_local(_local_col(x), axes, n, fill))

    return _striped_shard_map(spec, fn)(data)


def slide1up(spec: VectorMachineSpec, data: jax.Array, fill: float = 0.0) -> jax.Array:
    axes, n = spec.ring_axes, spec.n_total_lanes

    def fn(x):
        return _from_col(slide1up_local(_local_col(x), axes, n, fill))

    return _striped_shard_map(spec, fn)(data)


def reduce_scalar(spec: VectorMachineSpec, data: jax.Array, op: str = "sum",
                  mode: str = "ring", hierarchy: str | None = None) -> jax.Array:
    """Full-register reduction. mode='ring' is the paper-faithful log-tree on
    neighbour hops; mode='xla' lets XLA pick (flat all-reduce) — the §Perf
    comparison point.  With mode='ring', ``hierarchy`` selects the flattened
    ring or the paper's per-level pipeline walking every topology level from
    the lanes outward (default: the spec's Topology hierarchy)."""
    _check_mode(mode)
    hierarchy = _resolve_hierarchy(spec, hierarchy)
    axes, n = spec.ring_axes, spec.n_total_lanes
    reg = spec.reg_spec(VectorLayout.STRIPED)

    if mode == "xla":
        red = {"sum": jnp.sum, "max": jnp.max, "min": jnp.min}[op]
        return red(data)

    def fn(x):
        col = _local_col(x)
        if hierarchy == "flat":
            r = reduce_to_scalar_local(col, axes, n, op)
        else:
            r = reduce_to_scalar_local_hier(col, _levels_inner_first(spec),
                                            op)
        return r.reshape(1, 1, 1)

    out = substrate.shard_map(fn, mesh=spec.mesh, in_specs=(reg,),
                              out_specs=P(None, spec.cluster_axis,
                                          spec.lane_axis))(data)
    return out.reshape(-1)[0]


def ring_allgather(spec: VectorMachineSpec, data: jax.Array,
                   mode: str = "ring", hierarchy: str | None = None,
                   schedule: str = "seq") -> jax.Array:
    """All-gather over the lane ring.

    ``data`` is (n_total, B): row p is ring position p's shard (sharded
    ``P(ring_axes, None)``).  Returns (n_total, n_total*B): every row the
    full ring-order concatenation (replicated along the ring).  mode='xla'
    is the XLA-native all-gather baseline.  schedule='db' double-buffers
    the ring (hop k+1 issued before block k is consumed; bit-identical
    result)."""
    _check_mode(mode)
    _check_schedule(schedule)
    hierarchy = _resolve_hierarchy(spec, hierarchy)
    axes, n = spec.ring_axes, spec.n_total_lanes
    assert data.ndim == 2 and data.shape[0] == n, data.shape
    in_spec = P(axes, None)

    def fn(x):                                        # x (1, B)
        col = x[0]
        if mode == "xla":
            full = substrate.all_gather(col, axes, axis=0, tiled=True)
        elif hierarchy == "flat":
            full = (ring_allgather_local_db if schedule == "db"
                    else ring_allgather_local)(col, axes, n)
        else:
            full = ring_allgather_local_hier(col, _levels_inner_first(spec),
                                             schedule)
        return full[None]

    return substrate.shard_map(fn, mesh=spec.mesh, in_specs=(in_spec,),
                               out_specs=in_spec)(data)


def ring_reduce_scatter(spec: VectorMachineSpec, data: jax.Array,
                        mode: str = "ring", hierarchy: str | None = None,
                        schedule: str = "seq") -> jax.Array:
    """Reduce-scatter over the lane ring.

    ``data`` is (n_total, M) with M % n_total == 0: row p is ring position
    p's full-length contribution.  Returns (n_total, M // n_total): row p =
    chunk p of the elementwise sum of all rows.  mode='xla' is the XLA-native
    reduce-scatter baseline.  schedule='db' chunk-pipelines each ring so a
    shift is always in flight behind the adds (bit-identical sums)."""
    _check_mode(mode)
    _check_schedule(schedule)
    hierarchy = _resolve_hierarchy(spec, hierarchy)
    axes, n = spec.ring_axes, spec.n_total_lanes
    assert data.ndim == 2 and data.shape[0] == n, data.shape
    assert data.shape[1] % n == 0, data.shape
    in_spec = P(axes, None)

    def fn(x):                                        # x (1, M)
        col = x[0]
        if mode == "xla":
            out = substrate.psum_scatter(col, axes, scatter_dimension=0,
                                         tiled=True)
        elif hierarchy == "flat":
            out = (ring_reduce_scatter_local_db if schedule == "db"
                   else ring_reduce_scatter_local)(col, axes, n)
        else:
            out = ring_reduce_scatter_local_hier(col,
                                                 _levels_inner_first(spec),
                                                 schedule)
        return out[None]

    return substrate.shard_map(fn, mesh=spec.mesh, in_specs=(in_spec,),
                               out_specs=in_spec)(data)
