"""Distributed long-vector register layouts (AraXL §III-B.2).

AraXL maps memory element ``i`` of a vector register to

    cluster  (i // L) mod C        (C clusters)
    lane      i mod L              (L lanes per cluster)
    row       i // (C*L)           (depth inside the lane's VRF chunk)

i.e. a *striped* (block-cyclic with block 1 over lanes, block L over clusters)
layout.  This keeps mixed-width operations lane-local and feeds all FPUs from
unit-stride memory streams.  We reproduce it exactly as ``VectorLayout.STRIPED``:
a logical vector of ``vl`` elements is carried as a global array of shape
``(B, C, L)`` sharded ``P(None, cluster_axis, lane_axis)`` so that device
``(c, l)`` holds rows ``b`` of elements ``i = b*C*L + c*L + l``.

``VectorLayout.BLOCKED`` is the beyond-paper TPU-native alternative (element
``i`` lives on flat device ``i // B``): slides touch only boundary elements,
at the cost of the paper's unit-stride DMA striping.  §Perf compares the two.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.topology import Topology

Axis = str | tuple[str, ...]


class VectorLayout(enum.Enum):
    STRIPED = "striped"   # paper-faithful AraXL byte map
    BLOCKED = "blocked"   # contiguous per-device blocks (TPU-native)


def _axis_size(mesh: Mesh, axis: Axis) -> int:
    if isinstance(axis, str):
        return mesh.shape[axis]
    return math.prod(mesh.shape[a] for a in axis)


def _axis_tuple(axis: Axis) -> tuple[str, ...]:
    return (axis,) if isinstance(axis, str) else tuple(axis)


@dataclasses.dataclass(frozen=True)
class VectorMachineSpec:
    """Static geometry of the distributed vector machine.

    ``cluster_axis`` plays AraXL's inter-cluster role (RINGI/GLSU hierarchy
    level), ``lane_axis`` the intra-cluster lanes.  On the production mesh
    these are ("pod","data") and "model" respectively.

    ``topology`` is the shared :class:`repro.topology.Topology` — the same
    value ``repro.sim.AraXLParams.topology`` exposes.  When omitted it is
    derived from the mesh (flat hierarchy, the emulator's historical
    default); when given, its grid must match the mesh axis sizes, and
    ``repro.core.ring`` / ``repro.core.glsu`` take their default hierarchy
    from it.  For topologies deeper than two levels (pod / cluster / lane)
    ``cluster_axis`` carries every non-lane level as a tuple and
    :meth:`topology_levels` exposes the per-level (axes, size) split the
    hierarchical collectives walk.
    """

    mesh: Mesh
    cluster_axis: Axis = "cluster"
    lane_axis: Axis = "lane"
    vlen_bits: int = 65536          # RVV-maximum 64 Kibit / vreg (the paper's flagship)
    sew_bits: int = 64              # DP elements, as evaluated in the paper
    topology: Topology | None = None

    def __post_init__(self):
        if self.topology is None:
            object.__setattr__(self, "topology", Topology(
                self.n_clusters, self.n_lanes, hierarchy="flat",
                cluster_axis=self.cluster_axis, lane_axis=self.lane_axis))
        elif self.topology.grid != (self.n_clusters, self.n_lanes):
            raise ValueError(
                f"topology grid {self.topology.grid} does not match the mesh "
                f"axis sizes ({self.n_clusters}, {self.n_lanes})")

    @property
    def n_clusters(self) -> int:
        return _axis_size(self.mesh, self.cluster_axis)

    @property
    def n_lanes(self) -> int:
        """Lanes per cluster."""
        return _axis_size(self.mesh, self.lane_axis)

    @property
    def n_total_lanes(self) -> int:
        return self.n_clusters * self.n_lanes

    @property
    def vlen_elems(self) -> int:
        return self.vlen_bits // self.sew_bits

    @property
    def cluster_axes(self) -> tuple[str, ...]:
        """The inter-cluster ring axes (hierarchy level 2) as a tuple."""
        return _axis_tuple(self.cluster_axis)

    @property
    def lane_axes(self) -> tuple[str, ...]:
        """The intra-cluster lane axes (hierarchy level 1) as a tuple."""
        return _axis_tuple(self.lane_axis)

    def topology_levels(self) -> tuple:
        """Per-level (mesh-axes tuple, size) pairs, outermost first, from
        the shared Topology — what the N-level collectives in
        ``repro.core.ring`` / ``repro.core.glsu`` walk."""
        return tuple((_axis_tuple(l.axis), l.size)
                     for l in self.topology.levels)

    @property
    def ring_axes(self) -> tuple[str, ...]:
        """Flattened (cluster-major, lane-minor) ring over every lane.

        Ring position of device (c, l) is ``p = c * L + l`` which matches the
        element striping, so slide-by-1 is a single neighbour hop.
        """
        return _axis_tuple(self.cluster_axis) + _axis_tuple(self.lane_axis)

    def reg_spec(self, layout: VectorLayout = VectorLayout.STRIPED) -> P:
        if layout is VectorLayout.STRIPED:
            return P(None, self.cluster_axis, self.lane_axis)
        return P(self.ring_axes, None)

    def reg_sharding(self, layout: VectorLayout = VectorLayout.STRIPED) -> NamedSharding:
        return NamedSharding(self.mesh, self.reg_spec(layout))

    def mem_spec(self) -> P:
        """Memory-order layout: contiguous shards across the flattened ring.

        This is how a DMA burst arrives from L2/HBM before the GLSU maps it
        into the striped register file."""
        return P(self.ring_axes)

    def padded_vl(self, vl: int) -> int:
        lanes = self.n_total_lanes
        return ((vl + lanes - 1) // lanes) * lanes


# ---------------------------------------------------------------------------
# Pure index maps (the paper's byte-mapping equations) — used by tests and by
# the GLSU reference implementation.
# ---------------------------------------------------------------------------

def element_to_coords(i: int | np.ndarray, C: int, L: int):
    """AraXL: element-i -> (row b, cluster c, lane l)."""
    b = i // (C * L)
    c = (i // L) % C
    l = i % L
    return b, c, l


def coords_to_element(b, c, l, C: int, L: int):
    return b * (C * L) + c * L + l


def mem_to_striped_host(x: np.ndarray, C: int, L: int) -> np.ndarray:
    """Reference (host) GLSU mapping: 1-D memory vector -> (B, C, L)."""
    assert x.ndim == 1 and x.shape[0] % (C * L) == 0
    return x.reshape(-1, C, L)


def striped_to_mem_host(reg: np.ndarray) -> np.ndarray:
    return reg.reshape(-1)


# ---------------------------------------------------------------------------
# Register-file containers
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VReg:
    """A distributed vector register: ``data`` is the (B, C, L) striped global
    array (or (P, B) blocked), ``vl`` the live vector length (<= B*C*L), the
    tail is architectural zero (RVV tail-agnostic, we pick tail-zero)."""

    data: jax.Array
    vl: int
    layout: VectorLayout = VectorLayout.STRIPED

    @property
    def capacity(self) -> int:
        return int(np.prod(self.data.shape))

    def astype(self, dtype) -> "VReg":
        return VReg(self.data.astype(dtype), self.vl, self.layout)


def vreg_zeros(spec: VectorMachineSpec, vl: int, dtype=jnp.float32,
               layout: VectorLayout = VectorLayout.STRIPED) -> VReg:
    C, L = spec.n_clusters, spec.n_lanes
    pvl = spec.padded_vl(vl)
    B = pvl // (C * L)
    shape = (B, C, L) if layout is VectorLayout.STRIPED else (C * L, B)
    data = jnp.zeros(shape, dtype=dtype)
    data = jax.device_put(data, spec.reg_sharding(layout))
    return VReg(data, vl, layout)


def valid_mask(spec: VectorMachineSpec, vreg: VReg) -> jax.Array:
    """Boolean mask over the (padded) register marking i < vl, in-layout.

    Carried in the *same* layout as the data (the MASKU byte-encoding insight:
    masks never need cross-lane movement to be consumed)."""
    C, L = spec.n_clusters, spec.n_lanes
    B = vreg.capacity // (C * L)
    if vreg.layout is VectorLayout.STRIPED:
        b = jnp.arange(B)[:, None, None]
        c = jnp.arange(C)[None, :, None]
        l = jnp.arange(L)[None, None, :]
        idx = b * (C * L) + c * L + l
    else:
        p = jnp.arange(C * L)[:, None]
        b = jnp.arange(B)[None, :]
        idx = p * B + b
    return idx < vreg.vl


def global_index_grid(spec: VectorMachineSpec, B: int,
                      layout: VectorLayout = VectorLayout.STRIPED) -> jax.Array:
    """The logical element index held at each physical slot."""
    C, L = spec.n_clusters, spec.n_lanes
    if layout is VectorLayout.STRIPED:
        b = jnp.arange(B)[:, None, None]
        c = jnp.arange(C)[None, :, None]
        l = jnp.arange(L)[None, None, :]
        return b * (C * L) + c * L + l
    p = jnp.arange(C * L)[:, None]
    b = jnp.arange(B)[None, :]
    return p * B + b
