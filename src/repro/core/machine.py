"""Machine construction helpers (the REQI view: one program, many clusters).

``make_machine`` is topology-first: pass a :class:`repro.topology.Topology`
(e.g. ``repro.sim.araxl_params(8).topology``) and the mesh axes, level grid,
and interconnect hierarchy are all derived from it — the emulator and the
analytical cost model then provably share one geometry value
(``machine.spec.topology == params.topology``).  The mesh gets **one axis
per topology level** (outermost first), so a three-level (pod, cluster,
lane) topology builds a (P, C, L) mesh whose non-lane axes ride the spec's
``cluster_axis`` tuple.  The legacy ``make_machine(C, L, hierarchy=...)``
form still works and builds the equivalent two-level Topology internally.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro.topology import Topology
from .isa import AraXLMachine
from .layout import VectorMachineSpec


def make_vector_mesh(n_clusters: int, n_lanes: int,
                     cluster_axis: str = "cluster",
                     lane_axis: str = "lane") -> Mesh:
    """A (C, L) mesh over however many devices exist (C*L must divide in)."""
    return jax.make_mesh((n_clusters, n_lanes), (cluster_axis, lane_axis))


def make_topology_mesh(topology: Topology) -> Mesh:
    """One mesh axis per topology level, outermost first."""
    names = []
    for l in topology.levels:
        if not isinstance(l.axis, str):
            raise ValueError(f"make_machine needs single-name level axes, "
                             f"got {l.axis!r}")
        names.append(l.axis)
    return jax.make_mesh(topology.shape, tuple(names))


def make_machine(n_clusters: int | None = None, n_lanes: int | None = None,
                 *, topology: Topology | None = None, vlen_bits: int = 65536,
                 sew_bits: int = 64, glsu_mode: str = "staged",
                 reduce_mode: str = "ring", hierarchy: str | None = None,
                 dtype=None, trace: list | None = None) -> AraXLMachine:
    import jax.numpy as jnp
    if topology is None:
        if n_clusters is None or n_lanes is None:
            raise ValueError("pass either topology= or (n_clusters, n_lanes)")
        # Historical default: the flattened ring unless asked otherwise.
        topology = Topology(n_clusters, n_lanes,
                            hierarchy=hierarchy or "flat")
    else:
        if (n_clusters, n_lanes) != (None, None) and \
                (n_clusters, n_lanes) != topology.grid:
            raise ValueError(f"(n_clusters, n_lanes)=({n_clusters}, "
                             f"{n_lanes}) conflicts with topology grid "
                             f"{topology.grid}")
        if hierarchy is not None:
            topology = topology.with_hierarchy(hierarchy)
    mesh = make_topology_mesh(topology)
    spec = VectorMachineSpec(mesh, topology.cluster_axis, topology.lane_axis,
                             vlen_bits, sew_bits, topology=topology)
    return AraXLMachine(spec, glsu_mode=glsu_mode, reduce_mode=reduce_mode,
                        dtype=dtype or jnp.float32, trace=trace)
