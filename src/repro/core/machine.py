"""Machine construction helpers (the REQI view: one program, many clusters)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh

from .isa import AraXLMachine
from .layout import VectorMachineSpec


def make_vector_mesh(n_clusters: int, n_lanes: int,
                     cluster_axis: str = "cluster",
                     lane_axis: str = "lane") -> Mesh:
    """A (C, L) mesh over however many devices exist (C*L must divide in)."""
    return jax.make_mesh((n_clusters, n_lanes), (cluster_axis, lane_axis))


def make_machine(n_clusters: int, n_lanes: int, *, vlen_bits: int = 65536,
                 sew_bits: int = 64, glsu_mode: str = "staged",
                 reduce_mode: str = "ring", hierarchy: str = "flat",
                 dtype=None, trace: list | None = None) -> AraXLMachine:
    import jax.numpy as jnp
    mesh = make_vector_mesh(n_clusters, n_lanes)
    spec = VectorMachineSpec(mesh, "cluster", "lane", vlen_bits, sew_bits)
    return AraXLMachine(spec, glsu_mode=glsu_mode, reduce_mode=reduce_mode,
                        hierarchy=hierarchy, dtype=dtype or jnp.float32,
                        trace=trace)
