"""GLSU — the Global Load/Store Unit (AraXL §III-B.3), as staged collectives.

AraXL's scalability bottleneck (inherited from Ara2) was the O(L²) all-to-all
byte-mapping network between the memory bus and the lanes' VRF chunks.  The
paper replaces it with a *multi-level pipeline of power-of-2 shifts* (Align
stage) followed by an EW-aware Shuffle stage, trading latency (more pipeline
levels, each cuttable with registers) for physical scalability — affordable
because long vectors tolerate latency.

Mapped to a TPU mesh, the byte-mapping network is the redistribution between

    memory layout    x[p*B : (p+1)*B] on ring position p      (how a DMA burst /
                                                               data-pipeline shard arrives)
    register layout  x[b*n + p] row b of ring position p      (the striped VRF map)

which is a transpose-flavoured all-to-all.  Two implementations:

``mode="staged"`` — the paper-faithful network: log2(n) rounds; in round k a
    bucket moves 2**k ring positions forward iff bit k of its remaining
    distance is set.  Every round is a single neighbour-distance-2**k
    ``ppermute`` (a pipelined shift register chain in hardware, a short-range
    ICI hop on TPU).  This is exactly the Align/Shuffle decomposition.

    With ``hierarchy="two-level"`` the Align stage is split along the paper's
    hierarchy: the low log2(L) rounds are *cluster-local* lane rotations (the
    short-hop shift registers of §III-B.3), and only the remaining log2(C)
    rounds — plus a per-lane carry for buckets that wrapped past the cluster
    boundary — ride the inter-cluster ring.  Same round count, but the
    physically long wires never carry intra-cluster traffic.

``mode="direct"`` — one XLA resharding (reshape + sharding constraint): the
    flat all-to-all AraXL argues *against* in hardware; on TPU the XLA
    all-to-all is the baseline the staged version is compared with in §Perf.

Regularity requirement for the staged network: ``B % n == 0`` (each ring
position exchanges exactly B/n elements with every other position) — the
analogue of the paper's "Addrgen handles request splitting and bandwidth
conversion"; callers pad vectors to n² granularity first (``vle`` does).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import substrate
from .layout import VectorLayout, VectorMachineSpec
from .ring import _resolve_hierarchy, ppermute_shift, ring_pos


# ---------------------------------------------------------------------------
# Host reference (pure numpy) — the oracle for tests.
# ---------------------------------------------------------------------------

def mem_to_reg_host(x: np.ndarray, C: int, L: int) -> np.ndarray:
    """(n*B,) memory order -> (B, C, L) striped."""
    return np.asarray(x).reshape(-1, C, L)


def reg_to_mem_host(reg: np.ndarray) -> np.ndarray:
    return np.asarray(reg).reshape(-1)


# ---------------------------------------------------------------------------
# The staged routing core (runs inside shard_map; static schedule).
# ---------------------------------------------------------------------------

def _route_buckets(buf: jax.Array, axis_names: Sequence[str], n: int) -> jax.Array:
    """Route bucket o of ``buf`` (shape (n, m)) exactly o ring positions
    forward, via log2(n) power-of-2 shift rounds.

    Movement schedule is static: bucket o moves in round k iff bit k of o is
    set (its remaining distance after earlier rounds has low bits cleared).
    After routing, slot o on device d holds the bucket that *originated* at
    device (d - o) mod n.
    """
    assert n & (n - 1) == 0, "staged GLSU requires power-of-2 ring size"
    o = jnp.arange(n)
    k = 0
    while (1 << k) < n:
        step = 1 << k
        moved = ppermute_shift(buf, axis_names, -step, n)   # receive from p-step
        take_moved = ((o >> k) & 1).astype(bool)
        buf = jnp.where(take_moved.reshape((n,) + (1,) * (buf.ndim - 1)), moved, buf)
        k += 1
    return buf


def _route_buckets_two_level(buf: jax.Array, cluster_axes: Sequence[str],
                             C: int, lane_axes: Sequence[str], L: int
                             ) -> jax.Array:
    """Two-level Align: route bucket o exactly o flattened-ring positions
    forward using log2(L) cluster-local lane rotations followed by log2(C)
    inter-cluster ring rotations.

    A bucket with offset o lands on lane (l + o) mod L of cluster
    c + o//L + carry, where carry = 1 iff the lane rotation wrapped past the
    cluster boundary (detectable at the *destination* lane l' as
    l' < o mod L).  Same post-condition as the flat schedule: slot o on
    device d holds the bucket that originated at device (d - o) mod n.
    """
    n = C * L
    assert C & (C - 1) == 0 and L & (L - 1) == 0, \
        "two-level staged GLSU requires power-of-2 cluster and lane counts"
    o = jnp.arange(n)
    bshape = (n,) + (1,) * (buf.ndim - 1)

    # Align short-hops: intra-cluster lane rotation by o mod L.
    o_lane = o % L
    k = 0
    while (1 << k) < L:
        step = 1 << k
        moved = ppermute_shift(buf, lane_axes, -step, L)
        take = ((o_lane >> k) & 1).astype(bool)
        buf = jnp.where(take.reshape(bshape), moved, buf)
        k += 1

    # Inter-cluster rounds: o//L hops, +1 for buckets whose lane rotation
    # wrapped (their current lane l' satisfies l' < o mod L).
    lane_here = ring_pos(lane_axes)
    carry = (lane_here < o_lane).astype(o.dtype)
    hops = (o // L + carry) % C
    k = 0
    while (1 << k) < C:
        step = 1 << k
        moved = ppermute_shift(buf, cluster_axes, -step, C)
        take = ((hops >> k) & 1).astype(bool)
        buf = jnp.where(take.reshape(bshape), moved, buf)
        k += 1
    return buf


def n_staged_rounds(n: int) -> int:
    """Rounds the staged Align network runs for an n-position ring.

    log2(n) power-of-2 shift rounds; a 1-lane machine routes nothing (the
    ``_route_buckets`` loop body never executes), so n=1 is 0 rounds."""
    if n <= 1:
        return 0
    return int(math.log2(n))


# ---------------------------------------------------------------------------
# mem -> reg (vector load through the GLSU)
# ---------------------------------------------------------------------------

def _make_router(spec: VectorMachineSpec, hierarchy: str | None):
    """The Align-stage routing schedule for ``spec`` (flat or two-level;
    None takes the hierarchy of the spec's shared Topology)."""
    hierarchy = _resolve_hierarchy(spec, hierarchy)
    if hierarchy == "two-level":
        return lambda buf: _route_buckets_two_level(
            buf, spec.cluster_axes, spec.n_clusters,
            spec.lane_axes, spec.n_lanes)
    return lambda buf: _route_buckets(buf, spec.ring_axes, spec.n_total_lanes)


def _mem_to_reg_local(xloc: jax.Array, axis_names: Sequence[str], n: int,
                      route) -> jax.Array:
    """Local body: (B,) memory shard -> (B, 1, 1)-flattened striped column."""
    B = xloc.shape[0]
    assert B % n == 0, f"staged GLSU needs B % n == 0 (B={B}, n={n})"
    m = B // n
    p = ring_pos(axis_names)
    # --- bucketing (the Shuffle-stage table): destination of element j is
    # (p*B + j) mod n; with B % n == 0 that is j mod n. Bucket o=(d-p) mod n
    # holds elements destined for device d = p+o, i.e. j ≡ d (mod n).
    j = jnp.arange(B)
    d_of_j = j % n                                     # destination device of elem j
    # bucket index o = (d - p) mod n ; inside bucket ordered by t = j // n
    order = jnp.argsort((d_of_j - p) % n * B + j)      # group by o, then t
    buckets = xloc[order].reshape(n, m)
    # --- Align: power-of-2 shift rounds
    routed = route(buckets)
    # --- assembly: on device d, slot o originated at q=(d-o) mod n and fills
    # rows [q*m, (q+1)*m). Order slots by source id and concatenate.
    dpos = ring_pos(axis_names)
    src_of_slot = (dpos - jnp.arange(n)) % n
    slot_of_src = jnp.argsort(src_of_slot)             # src q -> slot index
    col = routed[slot_of_src].reshape(B)
    return col.reshape(B, 1, 1)


def mem_to_reg(spec: VectorMachineSpec, x: jax.Array, mode: str = "staged",
               hierarchy: str | None = None) -> jax.Array:
    """Vector load: 1-D memory-layout array (length B*n, blocked-sharded over
    the ring) -> (B, C, L) striped register."""
    n = spec.n_total_lanes
    C, L = spec.n_clusters, spec.n_lanes
    assert x.ndim == 1 and x.shape[0] % n == 0
    B = x.shape[0] // n

    if mode == "direct":
        reg = x.reshape(B, C, L)
        return jax.lax.with_sharding_constraint(reg, spec.reg_sharding())

    axes = spec.ring_axes
    route = _make_router(spec, hierarchy)
    fn = lambda xloc: _mem_to_reg_local(xloc.reshape(-1), axes, n, route)
    out = substrate.shard_map(fn, mesh=spec.mesh,
                              in_specs=(spec.mem_spec(),),
                              out_specs=spec.reg_spec())(x)
    return out


# ---------------------------------------------------------------------------
# reg -> mem (vector store through the GLSU)
# ---------------------------------------------------------------------------

def _reg_to_mem_local(col: jax.Array, axis_names: Sequence[str], n: int,
                      route) -> jax.Array:
    B = col.shape[0]
    assert B % n == 0
    m = B // n
    d = ring_pos(axis_names)
    # device d holds elements i = b*n + d; destination memory device q = b // m.
    # bucket for q is rows [q*m, (q+1)*m) with offset o = (q - d) mod n.
    b = jnp.arange(B)
    q_of_b = b // m
    order = jnp.argsort(((q_of_b - d) % n) * B + b)    # group by o, then row
    buckets = col[order].reshape(n, m)
    routed = route(buckets)
    # assembly on memory device q: slot o came from source dsrc=(q-o) mod n,
    # carrying elements with local j = t*n + dsrc.
    qpos = ring_pos(axis_names)
    o = jnp.arange(n)
    jj = jnp.arange(B)
    slot_of_j = (qpos - (jj % n)) % n                  # o' for each local j
    t_of_j = jj // n
    out = routed[slot_of_j, t_of_j]
    return out


def reg_to_mem(spec: VectorMachineSpec, reg: jax.Array, mode: str = "staged",
               hierarchy: str | None = None) -> jax.Array:
    n = spec.n_total_lanes
    B = reg.shape[0]
    if mode == "direct":
        x = reg.reshape(-1)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(spec.mesh, spec.mem_spec()))

    axes = spec.ring_axes
    route = _make_router(spec, hierarchy)
    fn = lambda c: _reg_to_mem_local(c.reshape(-1), axes, n, route)
    out = substrate.shard_map(fn, mesh=spec.mesh,
                              in_specs=(spec.reg_spec(),),
                              out_specs=spec.mem_spec())(reg)
    return out
