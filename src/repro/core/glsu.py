"""GLSU — the Global Load/Store Unit (AraXL §III-B.3), as staged collectives.

AraXL's scalability bottleneck (inherited from Ara2) was the O(L²) all-to-all
byte-mapping network between the memory bus and the lanes' VRF chunks.  The
paper replaces it with a *multi-level pipeline of power-of-2 shifts* (Align
stage) followed by an EW-aware Shuffle stage, trading latency (more pipeline
levels, each cuttable with registers) for physical scalability — affordable
because long vectors tolerate latency.

Mapped to a TPU mesh, the byte-mapping network is the redistribution between

    memory layout    x[p*B : (p+1)*B] on ring position p      (how a DMA burst /
                                                               data-pipeline shard arrives)
    register layout  x[b*n + p] row b of ring position p      (the striped VRF map)

which is a transpose-flavoured all-to-all.  Two implementations:

``mode="staged"`` — the paper-faithful network: log2(n) rounds; in round k a
    bucket moves 2**k ring positions forward iff bit k of its remaining
    distance is set.  Every round is a single neighbour-distance-2**k
    ``ppermute`` (a pipelined shift register chain in hardware, a short-range
    ICI hop on TPU).  This is exactly the Align/Shuffle decomposition.

    With a hierarchical interconnect (``hierarchy="two-level"`` and deeper)
    the Align stage is split along the topology: the low log2(L) rounds are
    *cluster-local* lane rotations (the short-hop shift registers of
    §III-B.3), and only the remaining rounds — plus a per-level carry for
    buckets that wrapped past a boundary, exactly multi-digit addition —
    ride the outer rings (log2(C) cluster rounds, then log2(P) pod rounds,
    ...).  Same total round count, but each level's physically long wires
    never carry inner-level traffic.

``mode="direct"`` — one XLA resharding (reshape + sharding constraint): the
    flat all-to-all AraXL argues *against* in hardware; on TPU the XLA
    all-to-all is the baseline the staged version is compared with in §Perf.

Regularity requirement for the staged network: ``B % n == 0`` (each ring
position exchanges exactly B/n elements with every other position) — the
analogue of the paper's "Addrgen handles request splitting and bandwidth
conversion"; callers pad vectors to n² granularity first (``vle`` does).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import substrate
from .layout import VectorLayout, VectorMachineSpec
from .ring import (_levels_inner_first, _resolve_hierarchy, ppermute_shift,
                   ring_pos)


# ---------------------------------------------------------------------------
# Host reference (pure numpy) — the oracle for tests.
# ---------------------------------------------------------------------------

def mem_to_reg_host(x: np.ndarray, C: int, L: int) -> np.ndarray:
    """(n*B,) memory order -> (B, C, L) striped."""
    return np.asarray(x).reshape(-1, C, L)


def reg_to_mem_host(reg: np.ndarray) -> np.ndarray:
    return np.asarray(reg).reshape(-1)


# ---------------------------------------------------------------------------
# The staged routing core (runs inside shard_map; static schedule).
# ---------------------------------------------------------------------------

def _route_buckets(buf: jax.Array, axis_names: Sequence[str], n: int) -> jax.Array:
    """Route bucket o of ``buf`` (shape (n, m)) exactly o ring positions
    forward, via log2(n) power-of-2 shift rounds.

    Movement schedule is static: bucket o moves in round k iff bit k of o is
    set (its remaining distance after earlier rounds has low bits cleared).
    After routing, slot o on device d holds the bucket that *originated* at
    device (d - o) mod n.
    """
    assert n & (n - 1) == 0, "staged GLSU requires power-of-2 ring size"
    o = jnp.arange(n)
    k = 0
    while (1 << k) < n:
        step = 1 << k
        moved = ppermute_shift(buf, axis_names, -step, n)   # receive from p-step
        take_moved = ((o >> k) & 1).astype(bool)
        buf = jnp.where(take_moved.reshape((n,) + (1,) * (buf.ndim - 1)), moved, buf)
        k += 1
    return buf


def _route_buckets_hier(buf: jax.Array, levels: Sequence, n: int) -> jax.Array:
    """N-level Align: route bucket o exactly o flattened-ring positions
    forward, walking ``levels`` (innermost-first (axes, size) pairs) with
    per-level power-of-2 rotations — exactly multi-digit addition of the
    offset o to the device coordinate, carries included.

    At each level the bucket rotates by its offset digit plus the carry
    from the level below; a bucket wrapped past this level's boundary
    (detectable at the *destination* coordinate x' as x' < rot, or as a
    full-cycle rotation) carries +1 into the level above.  Same
    post-condition as the flat schedule: slot o on device d holds the
    bucket that originated at device (d - o) mod n.
    """
    o = jnp.arange(n)
    bshape = (n,) + (1,) * (buf.ndim - 1)
    carry = jnp.zeros(n, o.dtype)
    stride = 1
    for j, (axes, size) in enumerate(levels):
        assert size & (size - 1) == 0, \
            "hierarchical staged GLSU requires power-of-2 level sizes"
        digit = (o // stride) % size
        hops = digit + carry                          # in [0, size]
        rot = hops % size
        k = 0
        while (1 << k) < size:
            step = 1 << k
            moved = ppermute_shift(buf, axes, -step, size)
            take = ((rot >> k) & 1).astype(bool)
            buf = jnp.where(take.reshape(bshape), moved, buf)
            k += 1
        if j < len(levels) - 1:
            here = ring_pos(axes)
            carry = ((here < rot) | (hops >= size)).astype(o.dtype)
        stride *= size
    return buf


def _route_buckets_two_level(buf: jax.Array, cluster_axes: Sequence[str],
                             C: int, lane_axes: Sequence[str], L: int
                             ) -> jax.Array:
    """The two-level special case of :func:`_route_buckets_hier`: log2(L)
    cluster-local lane rotations, then log2(C) inter-cluster ring rotations
    (+1 hop for buckets whose lane rotation wrapped the cluster boundary)."""
    return _route_buckets_hier(
        buf, [(tuple(lane_axes), L), (tuple(cluster_axes), C)], C * L)


def n_staged_rounds(n: int) -> int:
    """Rounds the staged Align network runs for an n-position ring.

    log2(n) power-of-2 shift rounds; a 1-lane machine routes nothing (the
    ``_route_buckets`` loop body never executes), so n=1 is 0 rounds."""
    if n <= 1:
        return 0
    return int(math.log2(n))


# ---------------------------------------------------------------------------
# mem -> reg (vector load through the GLSU)
# ---------------------------------------------------------------------------

def _make_router(spec: VectorMachineSpec, hierarchy: str | None):
    """The Align-stage routing schedule for ``spec`` (flat, or hierarchical
    walking every topology level; None takes the hierarchy of the spec's
    shared Topology)."""
    hierarchy = _resolve_hierarchy(spec, hierarchy)
    if hierarchy == "flat":
        return lambda buf: _route_buckets(buf, spec.ring_axes,
                                          spec.n_total_lanes)
    return lambda buf: _route_buckets_hier(buf, _levels_inner_first(spec),
                                           spec.n_total_lanes)


def _mem_to_reg_local(xloc: jax.Array, axis_names: Sequence[str], n: int,
                      route) -> jax.Array:
    """Local body: (B,) memory shard -> (B, 1, 1)-flattened striped column."""
    B = xloc.shape[0]
    assert B % n == 0, f"staged GLSU needs B % n == 0 (B={B}, n={n})"
    m = B // n
    p = ring_pos(axis_names)
    # --- bucketing (the Shuffle-stage table): destination of element j is
    # (p*B + j) mod n; with B % n == 0 that is j mod n. Bucket o=(d-p) mod n
    # holds elements destined for device d = p+o, i.e. j ≡ d (mod n).
    j = jnp.arange(B)
    d_of_j = j % n                                     # destination device of elem j
    # bucket index o = (d - p) mod n ; inside bucket ordered by t = j // n
    order = jnp.argsort((d_of_j - p) % n * B + j)      # group by o, then t
    buckets = xloc[order].reshape(n, m)
    # --- Align: power-of-2 shift rounds
    routed = route(buckets)
    # --- assembly: on device d, slot o originated at q=(d-o) mod n and fills
    # rows [q*m, (q+1)*m). Order slots by source id and concatenate.
    dpos = ring_pos(axis_names)
    src_of_slot = (dpos - jnp.arange(n)) % n
    slot_of_src = jnp.argsort(src_of_slot)             # src q -> slot index
    col = routed[slot_of_src].reshape(B)
    return col.reshape(B, 1, 1)


def mem_to_reg(spec: VectorMachineSpec, x: jax.Array, mode: str = "staged",
               hierarchy: str | None = None) -> jax.Array:
    """Vector load: 1-D memory-layout array (length B*n, blocked-sharded over
    the ring) -> (B, C, L) striped register."""
    n = spec.n_total_lanes
    C, L = spec.n_clusters, spec.n_lanes
    assert x.ndim == 1 and x.shape[0] % n == 0
    B = x.shape[0] // n

    if mode == "direct":
        reg = x.reshape(B, C, L)
        return jax.lax.with_sharding_constraint(reg, spec.reg_sharding())

    axes = spec.ring_axes
    route = _make_router(spec, hierarchy)
    fn = lambda xloc: _mem_to_reg_local(xloc.reshape(-1), axes, n, route)
    out = substrate.shard_map(fn, mesh=spec.mesh,
                              in_specs=(spec.mem_spec(),),
                              out_specs=spec.reg_spec())(x)
    return out


# ---------------------------------------------------------------------------
# reg -> mem (vector store through the GLSU)
# ---------------------------------------------------------------------------

def _reg_to_mem_local(col: jax.Array, axis_names: Sequence[str], n: int,
                      route) -> jax.Array:
    B = col.shape[0]
    assert B % n == 0
    m = B // n
    d = ring_pos(axis_names)
    # device d holds elements i = b*n + d; destination memory device q = b // m.
    # bucket for q is rows [q*m, (q+1)*m) with offset o = (q - d) mod n.
    b = jnp.arange(B)
    q_of_b = b // m
    order = jnp.argsort(((q_of_b - d) % n) * B + b)    # group by o, then row
    buckets = col[order].reshape(n, m)
    routed = route(buckets)
    # assembly on memory device q: slot o came from source dsrc=(q-o) mod n,
    # carrying elements with local j = t*n + dsrc.
    qpos = ring_pos(axis_names)
    o = jnp.arange(n)
    jj = jnp.arange(B)
    slot_of_j = (qpos - (jj % n)) % n                  # o' for each local j
    t_of_j = jj // n
    out = routed[slot_of_j, t_of_j]
    return out


def reg_to_mem(spec: VectorMachineSpec, reg: jax.Array, mode: str = "staged",
               hierarchy: str | None = None) -> jax.Array:
    n = spec.n_total_lanes
    B = reg.shape[0]
    if mode == "direct":
        x = reg.reshape(-1)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(spec.mesh, spec.mem_spec()))

    axes = spec.ring_axes
    route = _make_router(spec, hierarchy)
    fn = lambda c: _reg_to_mem_local(c.reshape(-1), axes, n, route)
    out = substrate.shard_map(fn, mesh=spec.mesh,
                              in_specs=(spec.reg_spec(),),
                              out_specs=spec.mem_spec())(reg)
    return out
