"""The paper's benchmark kernels (Table I), written against the vector ISA.

Each kernel is one function taking a machine (AraXLMachine for execution,
TraceMachine for the cycle model) and numpy-ish operands.  They use exactly
the instruction mix the paper attributes to them:

    fmatmul      unit-stride loads + vfmacc.vf           2*LC  FLOP/cycle peak
    fconv2d      7x7, slide-by-1 + vfmacc.vf             2*LC
    jacobi2d     5-point stencil, slide-by-1 + add/mul   LC
    fdotproduct  vfmul + vfredsum                        LC
    exp          polynomial, basic masks                 28/21 * LC
    softmax      vfredmax + exp + vfredsum + vfdiv       32/25 * LC

Matrices are row-major; a matrix row (length N = n*L*C) is one long vector,
the regime the paper evaluates (Table I problem sizes).
"""
from __future__ import annotations

import numpy as np

from .isa import AraXLMachine
from .layout import VReg


def fmatmul(v, A, B):
    """C = A @ B with A (M,K) scalar-side, B (K,N) vector-side.

    The classic long-vector matmul: each C row is accumulated with K
    vfmacc.vf instructions over B's rows, which stay resident in the VRF
    across output rows (LMUL-sized register groups in the paper)."""
    A = np.asarray(A)
    M, K = A.shape
    N = B.shape[1]
    b_regs = [v.vle(B[k]) for k in range(K)]
    out = []
    for i in range(M):
        acc = v.vbrd(0.0, N)
        for k in range(K):
            acc = v.vfmacc_vf(acc, float(A[i, k]), b_regs[k])
        out.append(v.vse(acc))
    if out[0] is None:                      # data-free trace run
        return None
    return np.stack([np.asarray(r) for r in out])


def fdotproduct(v, a, b):
    """sum(a*b): vfmul + the 4-stage reduction."""
    total = 0.0
    for off, vl in v.stripmine(len(a)):
        ra = v.vle(a[off:off + vl])
        rb = v.vle(b[off:off + vl])
        prod = v.vmul(ra, rb)
        total = total + v.vredsum(prod)
    return total


def jacobi2d(v, A):
    """One Jacobi sweep over the interior of each row (1-D 3-point + the
    vertical neighbours): out[i,j] = 0.25*(A[i-1,j]+A[i+1,j]+A[i,j-1]+A[i,j+1]).
    Horizontal neighbours come from slide-by-1 (the RINGI pattern)."""
    A = np.asarray(A)
    R, N = A.shape
    rows = [v.vle(A[i]) for i in range(R)]
    out = []
    for i in range(1, R - 1):
        left = v.vslide1up(rows[i], fill=0.0)    # A[i, j-1]
        right = v.vslide1down(rows[i], fill=0.0)  # A[i, j+1]
        s = v.vadd(rows[i - 1], rows[i + 1])
        s = v.vadd(s, left)
        s = v.vadd(s, right)
        res = v.vmul(s, 0.25)
        st = v.vse(res)
        out.append(np.asarray(st) if st is not None else None)
    # interior columns only are meaningful (boundary via slide fill=0)
    return np.stack(out) if out[0] is not None else None


def fconv2d(v, A, F):
    """2-D convolution with a small (paper: 7x7) filter, rows as long vectors.

    Column offsets of the filter are realised with repeated slide-by-1 of the
    input row (each slid copy reused across the filter column), row offsets by
    indexing neighbouring input rows; everything else is vfmacc.vf."""
    A = np.asarray(A)
    F = np.asarray(F)
    R, N = A.shape
    fr, fc = F.shape
    out_rows = R - fr + 1
    outs = []
    row_regs = [v.vle(A[i]) for i in range(R)]
    for i in range(out_rows):
        acc = v.vbrd(0.0, N)
        for r in range(fr):
            shifted = row_regs[i + r]
            for c in range(fc):
                if c > 0:
                    shifted = v.vslide1down(shifted, fill=0.0)
                acc = v.vfmacc_vf(acc, float(F[r, c]), shifted)
        st = v.vse(acc)
        outs.append(np.asarray(st)[: N - fc + 1] if st is not None else None)
    return np.stack(outs) if outs[0] is not None else None


def vexp(v, a):
    """Elementwise exp with the paper's range-reduction polynomial shape:
    a masked clamp (basic mask ops) + polynomial evaluation (the 28-FLOP
    budget is recorded by the machine's vexp)."""
    outs = []
    for off, vl in v.stripmine(len(a)):
        r = v.vle(a[off:off + vl])
        big = v.vmsge(r, 80.0)             # overflow guard (mask op)
        r = v.vmerge(big, v.vbrd(80.0, vl), r)
        e = v.vexp(r)
        st = v.vse(e)
        outs.append(np.asarray(st) if st is not None else None)
    return np.concatenate(outs) if outs[0] is not None else None


def softmax(v, A):
    """Row-wise softmax: vfredmax -> subtract -> exp -> vfredsum -> vfdiv."""
    A = np.asarray(A)
    outs = []
    for i in range(A.shape[0]):
        r = v.vle(A[i])
        m = v.vredmax(r)
        shifted = v.vsub(r, m)
        e = v.vexp(shifted)
        denom = v.vredsum(e)
        res = v.vdiv(e, denom)
        st = v.vse(res)
        outs.append(np.asarray(st) if st is not None else None)
    return np.stack(outs) if outs[0] is not None else None


KERNELS = {
    "fmatmul": fmatmul,
    "fconv2d": fconv2d,
    "jacobi2d": jacobi2d,
    "fdotproduct": fdotproduct,
    "exp": vexp,
    "softmax": softmax,
}
