from .analysis import (HW, collective_bytes, parse_collectives,
                       roofline_terms, wire_seconds)

__all__ = ["HW", "collective_bytes", "parse_collectives", "roofline_terms",
           "wire_seconds"]
