"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

    compute    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)      [per-chip FLOPs:
                 cost_analysis() of the SPMD-partitioned module is per-device]
    memory     = HLO_bytes / (chips x 819 GB/s)
    collective = wire_bytes / 50 GB/s per link (ring factors below)

collective_bytes is NOT in cost_analysis: we parse the compiled HLO text and
sum operand/result sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, with ring-algorithm wire factors:

    all-gather      (n-1)/n x result_bytes      received per device
    reduce-scatter  (n-1)/n x operand_bytes
    all-reduce      2(n-1)/n x operand_bytes    (RS + AG)
    all-to-all      (n-1)/n x operand_bytes
    collective-perm operand_bytes               (one neighbour hop)

`scan` caveat (DESIGN.md §8): XLA cost analysis counts a while body ONCE.
The dry-run therefore compiles 1-period and 2-period model variants and
extrapolates: total(L) = f(1) + (L-1) x (f(2) - f(1)).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

HW = {
    "peak_flops": 197e12,      # bf16 per chip
    "hbm_bw": 819e9,           # bytes/s per chip
    "ici_bw": 50e9,            # bytes/s per link (~ring direction)
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?(?:\.\d+)?\(")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> list[dict]:
    """Every collective op in the module: kind, result bytes, group size."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=")[1][:60]:
            continue                        # counted at -start
        kind = m.group(2)
        rbytes = _shape_bytes(m.group(1))
        g = _GROUPS_IOTA_RE.search(line)
        if g:
            group = int(g.group(2))
        else:
            g2 = _GROUPS_RE.search(line)
            group = len(g2.group(1).split(",")) if g2 else 1
        out.append({"kind": kind, "bytes": rbytes, "group": group,
                    "line": line.strip()[:160]})
    return out


_WIRE_FACTOR = {
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: (n - 1) / max(n, 1),
    "all-reduce": lambda n: 2 * (n - 1) / max(n, 1),
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


def collective_bytes(colls: list[dict]) -> dict:
    """Aggregate wire bytes per device, by kind and total."""
    by_kind: dict[str, float] = {}
    total = 0.0
    for c in colls:
        wire = c["bytes"] * _WIRE_FACTOR[c["kind"]](max(1, c["group"]))
        by_kind[c["kind"]] = by_kind.get(c["kind"], 0.0) + wire
        total += wire
    by_kind["total"] = total
    by_kind["count"] = len(colls)
    return by_kind


def wire_seconds(wire_bytes: float) -> float:
    return wire_bytes / HW["ici_bw"]


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float) -> dict:
    compute = flops_per_dev / HW["peak_flops"]
    memory = bytes_per_dev / HW["hbm_bw"]
    coll = wire_seconds(wire_bytes_per_dev)
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": coll}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]
                              if k.endswith("_s") else -1)
    terms["step_s_lower_bound"] = max(compute, memory, coll)
    return terms


def extrapolate(f1: float, f2: float, n_periods: int) -> float:
    """total(L) from 1- and 2-period compiles (scan body counted once)."""
    return f1 + (n_periods - 1) * (f2 - f1)


def resident_model_bytes(cfg, shape, n_dev: int, nm: int,
                         args_bytes: float) -> float:
    """Analytic per-device HBM *residency* (TPU buffer-reuse semantics).

    The CPU backend's temp arena double-buffers where a TPU executable
    aliases (donated params/opt updated in place, grad buffers reused), so
    the measured arena is an upper bound.  Analytic residency =

        args (exact, from the compile)
      + grads (one param-sized buffer, acc dtype)
      + grad accumulator (if microbatched)
      + layer-boundary activation saves (seq-sharded residual x L)
      + transient workspace (attention chunk + MoE dispatch + CE chunk),
        bounded by the largest single layer's working set x2.
    """
    bpe = 2
    P = cfg.n_params()
    dp = max(1, n_dev // 16)
    grads = P * bpe / n_dev
    acc = grads if (shape.kind == "train" and nm > 1) else 0.0
    if shape.kind != "train":
        return args_bytes + 2**30            # caches are args; +1GiB workspace
    B_mb_loc = max(1, shape.global_batch // nm // dp)
    msize = min(16, n_dev)
    x_save = cfg.n_layers * B_mb_loc * shape.seq_len * cfg.d_model * bpe \
        / msize                              # act_seq-sharded residual saves
    # largest layer working set (recompute live set), x2 safety
    ffe = cfg.d_ff_expert or cfg.d_ff or cfg.d_inner_ssm
    work = 2 * (B_mb_loc * shape.seq_len
                * max(cfg.d_model, ffe // msize * 4) * 4)
    ce = 2 * B_mb_loc * max(1, cfg.loss_chunk or 512) \
        * cfg.vocab_size // msize * 4
    return args_bytes + grads + acc + x_save + work + ce


def memory_model_bytes(cfg, shape, n_dev: int, nm: int) -> float:
    """Analytic per-device HBM traffic (fusion-aware second opinion).

    The CPU backend's cost_analysis counts every unfused op's operands, a
    ~5x overestimate of TPU HBM traffic; this model counts only the
    traffic a fused TPU program must pay:

      weights   3x local bf16 params per microbatch (fwd + bwd + remat re-read)
      optimizer 16 B/param local (m, v, master read+write, grad, param)
      acts      c_act x tokens_loc x d x 2 B per layer (c_act ~= 12:
                residual save+load, qkv/mlp intermediates, f32 upcasts)
      scores    2 x B_loc x H_loc x S x T x 4 B per attention layer (chunked)
      caches    decode: full KV/state cache read per step
    """
    bpe = 2
    P_loc = cfg.n_params() * bpe / n_dev
    d = cfg.d_model
    if shape.kind == "train":
        B_loc_mb = max(1, shape.global_batch // nm
                       // max(1, n_dev // 16))         # dp shards ~ n_dev/16
        dp = max(1, n_dev // 16)
        B_loc_mb = max(1, shape.global_batch // nm // dp)
        toks = B_loc_mb * shape.seq_len
        c_act = 12.0
        act = nm * cfg.n_layers * c_act * toks * d * bpe
        n_attn = sum(1 for layer in cfg.layer_period
                     for k in layer if k in ("attn", "xattn")) * cfg.n_periods
        H_loc = max(1, cfg.n_heads // 16)
        scores = nm * n_attn * 2 * B_loc_mb * H_loc * shape.seq_len \
            * shape.seq_len * 4
        weights = nm * 3 * P_loc
        opt = 16 * cfg.n_params() / n_dev
        return act + scores + weights + opt
    if shape.kind == "prefill":
        dp = max(1, n_dev // 16)
        B_loc = max(1, shape.global_batch // dp)
        toks = B_loc * shape.seq_len
        act = cfg.n_layers * 6.0 * toks * d * bpe
        H_loc = max(1, cfg.n_heads // 16)
        n_attn = sum(1 for layer in cfg.layer_period
                     for k in layer if k in ("attn", "xattn")) * cfg.n_periods
        scores = n_attn * B_loc * H_loc * shape.seq_len * shape.seq_len * 4
        return act + P_loc + scores
    # decode: weights + cache residency read once per token
    W = min(shape.seq_len, cfg.window) if cfg.window else shape.seq_len
    n_attn = sum(1 for layer in cfg.layer_period
                 for k in layer if k == "attn") * cfg.n_periods
    cache = n_attn * 2 * shape.global_batch * W * cfg.n_kv_heads \
        * cfg.head_dim * bpe / n_dev
    return P_loc + cache
