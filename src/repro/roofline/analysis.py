"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

    compute    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)      [per-chip FLOPs:
                 cost_analysis() of the SPMD-partitioned module is per-device]
    memory     = HLO_bytes / (chips x 819 GB/s)
    collective = per-level wire seconds (see below); flat fallback
                 wire_bytes / 50 GB/s per link

collective_bytes is NOT in cost_analysis: we parse the compiled HLO text and
sum operand/result sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, with ring-algorithm wire factors:

    all-gather      (n-1)/n x result_bytes      received per device
    reduce-scatter  (n-1)/n x operand_bytes
    all-reduce      2(n-1)/n x operand_bytes    (RS + AG)
    all-to-all      (n-1)/n x operand_bytes
    collective-perm operand_bytes               (one neighbour hop)

Per-level pricing (the AraXL claim carried to the launch layer): a
collective's ``replica_groups`` name the devices it spans; because the
production mesh has one axis per :class:`repro.topology.Topology` level and
XLA partition ids are mesh-flat (outer-major) positions, the group maps
back onto the level(s) it crosses (:func:`group_level_extents`).  A ring
schedule run hierarchically then carries, on level *i*'s wires (extent
``e_i``, outer-extent product ``O_i``),

    factor_i = wire_factor(e_i) / O_i          (AG / RS / AR / A2A)

of the payload — the outer rings only ever see already-aggregated
superchunks (this telescopes back to the flat ``(n-1)/n`` total, so bytes
are conserved; only their wire class changes).  Each level's bytes are
priced by its ``Level.wire_bw``; the flat model (``hierarchy="flat"``)
prices everything at the outermost wire class and is bit-identical to the
historical ``wire_seconds()`` for single-level topologies.

`scan` caveat (DESIGN.md §8): XLA cost analysis counts a while body ONCE.
The dry-run therefore compiles 1-period and 2-period model variants and
extrapolates: total(L) = f(1) + (L-1) x (f(2) - f(1)).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Any

import numpy as np

from repro.topology import Topology

HW = {
    "peak_flops": 197e12,      # bf16 per chip
    "hbm_bw": 819e9,           # bytes/s per chip
    "ici_bw": 50e9,            # bytes/s per link (~ring direction)
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?(?:\.\d+)?\(")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
_PAIRS_RE = re.compile(r"source_target_pairs=\{([0-9,{} ]*)\}")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _iota_first_group(n_groups: int, group_size: int, dims: str,
                      perm: str | None) -> tuple[int, ...]:
    """Expand the first group of an iota replica-group spec
    ``[N,S]<=[d0,d1,...]T(p...)``: reshape 0..N*S-1 to ``dims``, transpose
    by ``perm``, flatten, split into N rows of S."""
    shape = tuple(int(d) for d in dims.split(","))
    ids = np.arange(n_groups * group_size).reshape(shape)
    if perm:
        ids = ids.transpose(tuple(int(p) for p in perm.split(",")))
    return tuple(int(i) for i in ids.reshape(-1)[:group_size])


def parse_collectives(hlo_text: str) -> list[dict]:
    """Every collective op in the module: kind, result bytes, group size,
    plus the structure needed to map it onto topology levels — ``members``
    (the first replica group's device ids, groups are level-congruent) for
    the grouped collectives and ``pairs`` (source→target device pairs) for
    collective-permute."""
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=")[1][:60]:
            continue                        # counted at -start
        kind = m.group(2)
        rbytes = _shape_bytes(m.group(1))
        members = pairs = None
        g = _GROUPS_IOTA_RE.search(line)
        if g:
            group = int(g.group(2))
            members = _iota_first_group(int(g.group(1)), group,
                                        g.group(3), g.group(4))
        else:
            g2 = _GROUPS_RE.search(line)
            if g2:
                members = tuple(int(x) for x in g2.group(1).split(",") if x)
                group = len(members)
            else:
                group = 1
        p = _PAIRS_RE.search(line)
        if p and p.group(1).strip():
            flat = [int(x) for x in re.findall(r"\d+", p.group(1))]
            pairs = tuple(zip(flat[0::2], flat[1::2]))
        rec = {"kind": kind, "bytes": rbytes, "group": group,
               "line": line.strip()[:160]}
        if members is not None:
            rec["members"] = members
        if pairs is not None:
            rec["pairs"] = pairs
        out.append(rec)
    return out


_WIRE_FACTOR = {
    "all-gather": lambda n: (n - 1) / max(n, 1),
    "reduce-scatter": lambda n: (n - 1) / max(n, 1),
    "all-reduce": lambda n: 2 * (n - 1) / max(n, 1),
    "all-to-all": lambda n: (n - 1) / max(n, 1),
    "collective-permute": lambda n: 1.0,
}


def collective_bytes(colls: list[dict]) -> dict:
    """Aggregate wire bytes per device, by kind and total."""
    by_kind: dict[str, float] = {}
    total = 0.0
    for c in colls:
        wire = c["bytes"] * _WIRE_FACTOR[c["kind"]](max(1, c["group"]))
        by_kind[c["kind"]] = by_kind.get(c["kind"], 0.0) + wire
        total += wire
    by_kind["total"] = total
    by_kind["count"] = len(colls)
    return by_kind


def wire_seconds(wire_bytes: float) -> float:
    """Flat pricing: every byte rides the historical single-class link."""
    return wire_bytes / HW["ici_bw"]


# ---------------------------------------------------------------------------
# HLO replica-group -> topology-level mapping (per-level pricing)
# ---------------------------------------------------------------------------

def group_level_extents(members, topology: Topology) -> tuple[int, ...]:
    """Per-level extents (distinct level coordinates) one replica group
    spans, outermost first.

    XLA partition ids are mesh-flat outer-major positions, i.e. exactly the
    flattened ring positions :meth:`Topology.coords` decodes (the production
    mesh has one axis per level).  A mesh-axis-aligned group is a subgrid,
    so ``prod(extents) == len(members)``; a group that is not axis-aligned
    (or references devices outside the topology) falls back to a flat ring
    over the whole group at the outermost spanned level — the conservative
    long-wire attribution.
    """
    n = topology.n_lanes
    if not members or max(members) >= n:
        return (len(members or ()),) + (1,) * (topology.n_levels - 1)
    coords = [topology.coords(m) for m in members]
    extents = tuple(len({c[i] for c in coords})
                    for i in range(topology.n_levels))
    if math.prod(extents) != len(members):
        # degenerate duplicates (all extents 1) land on the outermost level
        outermost = next((i for i, e in enumerate(extents) if e > 1), 0)
        extents = tuple(len(members) if i == outermost else 1
                        for i in range(topology.n_levels))
    return extents


def _ring_level_factors(kind: str, extents) -> list[float]:
    """Per-level wire factors (fraction of payload bytes on each level's
    wires, outermost first) of the hierarchical ring schedule.

    Level i moves ``wire_factor(e_i) / O_i`` of the payload, where ``O_i``
    is the product of the *outer* extents: the outer rings exchange whole
    superchunks ((e-1)/e of the payload), each inner ring only its level's
    1/O_i-sized slice.  Telescopes to the flat ``(n-1)/n`` (2(n-1)/n for
    all-reduce), so total wire bytes are conserved — only their class moves.
    """
    f = _WIRE_FACTOR[kind]
    out, outer = [], 1
    for e in extents:
        out.append(f(max(1, e)) / outer if e > 1 else 0.0)
        outer *= max(1, e)
    return out


def _permute_level_factors(pairs, topology: Topology) -> list[float]:
    """Per-level factors for collective-permute: the fraction of pairs whose
    source→target path crosses each level (outermost differing coordinate).
    The factors always sum to exactly 1.0 — matching the flat _WIRE_FACTOR
    convention that a permute charges the full operand once per op — so
    per-level attribution only reclassifies those bytes, never rescales
    them."""
    counts = [0] * topology.n_levels
    n = topology.n_lanes
    if not pairs:
        # no pair structure parsed: a neighbour hop rides the innermost ring
        out = [0.0] * topology.n_levels
        out[-1] = 1.0
        return out
    for s, d in pairs:
        if max(s, d) >= n:
            # pair references devices outside this topology (mesh mismatch):
            # charge the outermost (long) wires, like group_level_extents
            counts[0] += 1
            continue
        cs, cd = topology.coords(s), topology.coords(d)
        lvl = next((i for i in range(topology.n_levels) if cs[i] != cd[i]),
                   topology.n_levels - 1)
        counts[lvl] += 1
    return [c / len(pairs) for c in counts]


def collective_level_bytes(colls: list[dict], topology: Topology) -> dict:
    """Aggregate per-device wire bytes by topology wire-class label
    (:meth:`Topology.wire_labels`, outermost first), plus ``total``.

    Under ``hierarchy="flat"`` every byte is attributed to the outermost
    label — the flattened-ring model the paper argues against.
    """
    labels = topology.wire_labels()
    by_level = {lab: 0.0 for lab in labels}
    total = 0.0
    for c in colls:
        kind = c["kind"]
        if topology.hierarchy == "flat":
            wire = c["bytes"] * _WIRE_FACTOR[kind](max(1, c["group"]))
            by_level[labels[0]] += wire
            total += wire
            continue
        if kind == "collective-permute":
            factors = _permute_level_factors(c.get("pairs"), topology)
        elif "members" in c:
            ext = group_level_extents(c["members"], topology)
            factors = _ring_level_factors(kind, ext)
        else:
            # size-only parse: attribute to the outermost (long) wires
            factors = [0.0] * topology.n_levels
            factors[0] = _WIRE_FACTOR[kind](max(1, c["group"]))
        for lab, f in zip(labels, factors):
            by_level[lab] += c["bytes"] * f
            total += c["bytes"] * f
    by_level["total"] = total
    return by_level


def level_wire_seconds(level_bytes: dict, topology: Topology) -> dict:
    """Price per-level wire bytes (a :func:`collective_level_bytes` dict) by
    each level's ``wire_bw``: {label: seconds, "total": sum}.  The flat
    hierarchy prices its (all-outermost) bytes at the outermost wire class;
    for a single-level topology that is the historical
    ``wire_seconds()`` bit-identically (innermost default bw == ici_bw)."""
    labels = topology.wire_labels()
    out = {}
    for lab in labels:
        out[lab] = level_bytes.get(lab, 0.0) / topology.wire_bw(lab)
    out["total"] = sum(out[lab] for lab in labels)
    return out


def exposed_level_seconds(level_secs: dict, compute_s: float,
                          topology: Topology) -> dict:
    """Overlap-aware exposure: how much of each level's collective seconds
    cannot hide behind the step's compute.

    The additive roofline assumes communicate-then-compute; the double-
    buffered schedules (ring attention ``schedule="db"``, the bucketed
    gradient sync) let a collective ride the wires while the FPUs stream.
    An ideally-overlapped schedule therefore only *exposes*

        exposed_i = max(0, collective_s_i - overlappable compute)

    where the compute budget is claimed innermost level first — the short
    intra-ring hops interleave tightest with the consuming compute (one
    hop per microbatch / block), while the outermost (pod) ring only has
    whatever compute the inner levels left unclaimed to hide behind.
    Always ``exposed_i <= collective_s_i`` per level; with zero compute it
    degenerates to the additive pricing.  Returns {label: seconds,
    "total": sum}.
    """
    labels = topology.wire_labels()
    budget = max(0.0, compute_s)
    out = {}
    for lab in reversed(labels):                      # innermost first
        c = level_secs.get(lab, 0.0)
        out[lab] = max(0.0, c - budget)
        budget = max(0.0, budget - c)
    out = {lab: out[lab] for lab in labels}           # outermost-first order
    out["total"] = sum(out[lab] for lab in labels)
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   wire_bytes_per_dev: float,
                   collective_s: float | None = None) -> dict:
    """Three-term roofline.  ``collective_s`` overrides the flat wire price
    (the dry-run passes the per-level total from
    :func:`level_wire_seconds`); default is the historical flat pricing."""
    compute = flops_per_dev / HW["peak_flops"]
    memory = bytes_per_dev / HW["hbm_bw"]
    coll = (wire_seconds(wire_bytes_per_dev) if collective_s is None
            else collective_s)
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": coll}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]
                              if k.endswith("_s") else -1)
    terms["step_s_lower_bound"] = max(compute, memory, coll)
    return terms


def extrapolate(f1: float, f2: float, n_periods: int) -> float:
    """total(L) from 1- and 2-period compiles (scan body counted once)."""
    return f1 + (n_periods - 1) * (f2 - f1)


def mesh_factors(n_dev: int, topology: Topology | None = None
                 ) -> tuple[int, int]:
    """(dp, msize): data-parallel ways and TP (model) ways of one cell.

    Derived from the topology when given — the innermost level is the TP
    lane group, everything outer is data-parallel — falling back to the
    historical ``n_dev // 16`` production heuristic (a 16-wide `model`
    axis) when the cell's geometry is unknown.
    """
    if topology is not None:
        msize = topology.lanes_per_cluster
        dp = max(1, n_dev // msize)
    else:
        msize = min(16, n_dev)
        dp = max(1, n_dev // 16)
    return dp, msize


def resident_model_bytes(cfg, shape, n_dev: int, nm: int,
                         args_bytes: float,
                         topology: Topology | None = None) -> float:
    """Analytic per-device HBM *residency* (TPU buffer-reuse semantics).

    The CPU backend's temp arena double-buffers where a TPU executable
    aliases (donated params/opt updated in place, grad buffers reused), so
    the measured arena is an upper bound.  Analytic residency =

        args (exact, from the compile)
      + grads (one param-sized buffer, acc dtype)
      + grad accumulator (if microbatched)
      + layer-boundary activation saves (seq-sharded residual x L)
      + transient workspace (attention chunk + MoE dispatch + CE chunk),
        bounded by the largest single layer's working set x2.
    """
    bpe = 2
    P = cfg.n_params()
    dp, msize = mesh_factors(n_dev, topology)
    grads = P * bpe / n_dev
    acc = grads if (shape.kind == "train" and nm > 1) else 0.0
    if shape.kind != "train":
        return args_bytes + 2**30            # caches are args; +1GiB workspace
    B_mb_loc = max(1, shape.global_batch // nm // dp)
    x_save = cfg.n_layers * B_mb_loc * shape.seq_len * cfg.d_model * bpe \
        / msize                              # act_seq-sharded residual saves
    # largest layer working set (recompute live set), x2 safety
    ffe = cfg.d_ff_expert or cfg.d_ff or cfg.d_inner_ssm
    work = 2 * (B_mb_loc * shape.seq_len
                * max(cfg.d_model, ffe // msize * 4) * 4)
    ce = 2 * B_mb_loc * max(1, cfg.loss_chunk or 512) \
        * cfg.vocab_size // msize * 4
    return args_bytes + grads + acc + x_save + work + ce


def memory_model_bytes(cfg, shape, n_dev: int, nm: int,
                       topology: Topology | None = None) -> float:
    """Analytic per-device HBM traffic (fusion-aware second opinion).

    The CPU backend's cost_analysis counts every unfused op's operands, a
    ~5x overestimate of TPU HBM traffic; this model counts only the
    traffic a fused TPU program must pay:

      weights   3x local bf16 params per microbatch (fwd + bwd + remat re-read)
      optimizer 16 B/param local (m, v, master read+write, grad, param)
      acts      c_act x tokens_loc x d x 2 B per layer (c_act ~= 12:
                residual save+load, qkv/mlp intermediates, f32 upcasts)
      scores    2 x B_loc x H_loc x S x T x 4 B per attention layer (chunked)
      caches    decode: full KV/state cache read per step
    """
    bpe = 2
    P_loc = cfg.n_params() * bpe / n_dev
    d = cfg.d_model
    dp, msize = mesh_factors(n_dev, topology)
    if shape.kind == "train":
        B_loc_mb = max(1, shape.global_batch // nm // dp)
        toks = B_loc_mb * shape.seq_len
        c_act = 12.0
        act = nm * cfg.n_layers * c_act * toks * d * bpe
        n_attn = sum(1 for layer in cfg.layer_period
                     for k in layer if k in ("attn", "xattn")) * cfg.n_periods
        H_loc = max(1, cfg.n_heads // msize)
        scores = nm * n_attn * 2 * B_loc_mb * H_loc * shape.seq_len \
            * shape.seq_len * 4
        weights = nm * 3 * P_loc
        opt = 16 * cfg.n_params() / n_dev
        return act + scores + weights + opt
    if shape.kind == "prefill":
        B_loc = max(1, shape.global_batch // dp)
        toks = B_loc * shape.seq_len
        act = cfg.n_layers * 6.0 * toks * d * bpe
        H_loc = max(1, cfg.n_heads // msize)
        n_attn = sum(1 for layer in cfg.layer_period
                     for k in layer if k in ("attn", "xattn")) * cfg.n_periods
        scores = n_attn * B_loc * H_loc * shape.seq_len * shape.seq_len * 4
        return act + P_loc + scores
    # decode: weights + cache residency read once per token
    W = min(shape.seq_len, cfg.window) if cfg.window else shape.seq_len
    n_attn = sum(1 for layer in cfg.layer_period
                 for k in layer if k == "attn") * cfg.n_periods
    cache = n_attn * 2 * shape.global_batch * W * cfg.n_kv_heads \
        * cfg.head_dim * bpe / n_dev
    return P_loc + cache
