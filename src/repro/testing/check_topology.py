"""Multi-device check: one shared Topology drives emulator and sim.

For every (C, L) factorisation of the 8-device ring this builds the sim
params for that grid, hands ``params.topology`` — the *same value* — to
``repro.core.machine.make_machine``, asserts the machine stores it verbatim
(``machine.spec.topology == params.topology``), and then runs the GLSU round
trip, a slide and both reductions under both hierarchies against numpy
oracles.  With 8 devices it additionally checks the *three-level* 2x2x2
(pod, cluster, lane) machine — the mesh grows one axis per topology level
and the hierarchical GLSU/RINGI walk the levels generically.  This is the
acceptance gate that the two stacks can never drift apart on geometry again.

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       python -m repro.testing.check_topology [n]
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.testing.x64 import x64_mode


def main(n: int = 8) -> None:
    # float64 scoped via x64_mode (restore + tamper-assert on exit) — never
    # at import time (the tier-1 import sweep loads this module in-process)
    with x64_mode(True):
        _main(n)


def _main(n: int = 8) -> None:
    from repro.core import make_machine
    from repro.sim import araxl_params
    from repro.topology import HIERARCHIES, factorizations

    assert len(jax.devices()) >= n, "need more fake devices"
    grids = factorizations(n)
    assert grids, f"n={n} has no power-of-two (C, L) factorisation to check"
    rng = np.random.default_rng(0)

    def exercise(v, x):
        """GLSU round trip, both reductions and a slide vs numpy oracles."""
        r = v.vle(x)
        np.testing.assert_array_equal(np.asarray(v.vse(r)), x)
        np.testing.assert_allclose(float(v.vredsum(r)), x.sum(), rtol=1e-12)
        np.testing.assert_allclose(float(v.vredmax(r)), x.max(), rtol=0)
        s = np.asarray(v.vse(v.vslide1down(r, fill=-1.0)))
        np.testing.assert_allclose(s, np.concatenate([x[1:], [-1.0]]))

    for C, L in grids:
        params = araxl_params(n, lanes_per_cluster=L)
        assert params.topology.grid == (C, L)
        for hierarchy in HIERARCHIES:
            topo = params.with_hierarchy(hierarchy).topology
            v = make_machine(topology=topo, vlen_bits=4096, dtype=jnp.float64)
            # one Topology, shared by value across both stacks
            assert v.spec.topology == topo, (v.spec.topology, topo)
            assert v.hierarchy == hierarchy
            exercise(v, rng.normal(size=n * n * 2))
        print(f"check_topology C{C}xL{L} ok")

    # Three-level (pod, cluster, lane) machines: one mesh axis per level,
    # params and emulator still share the identical Topology value.
    if n == 8:
        for n_pods, lpc in ((2, 2), (2, 1), (4, 2)):
            params = araxl_params(n, lanes_per_cluster=lpc, n_pods=n_pods)
            topo = params.topology
            assert topo.n_levels == 3
            assert topo.shape == (n_pods, n // n_pods // lpc, lpc)
            for hierarchy in ("flat", "three-level"):
                topo_h = params.with_hierarchy(hierarchy).topology
                v = make_machine(topology=topo_h, vlen_bits=4096,
                                 dtype=jnp.float64)
                assert v.spec.topology == topo_h
                assert v.hierarchy == hierarchy
                assert set(v.spec.mesh.shape) == {"pod", "cluster", "lane"}
                exercise(v, rng.normal(size=n * n * 2))
            print(f"check_topology P{n_pods}x"
                  f"C{n // n_pods // lpc}xL{lpc} ok")

    print(f"check_topology OK (n={n}, grids={grids})")


if __name__ == "__main__":
    argv = [int(a) for a in sys.argv[1:]]
    main(*argv)
