"""Multi-device check: ring attention == reference attention (8 devices)."""
from __future__ import annotations

import sys

import jax

jax.config.update("jax_enable_x64", False)

import jax.numpy as jnp
import numpy as np


def main(n: int = 8) -> None:
    from repro.kernels import ref
    from repro.parallel.ring_attention import ring_attention

    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(0)
    B, S, H, Hkv, D = 2, 8 * 16, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)

    for causal, window in [(True, None), (False, None), (True, 24)]:
        got = jax.jit(lambda q, k, v: ring_attention(
            q, k, v, mesh, causal=causal, window=window))(q, k, v)
        want = ref.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=causal,
                             window=window).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
    print(f"check_ring_attention OK (n={n})")


if __name__ == "__main__":
    main(*(int(a) for a in sys.argv[1:]))
