"""Multi-device check: ring attention == reference attention (8 devices).

Covers both schedules: the flat single-axis ring, and the hierarchical
(pod, cluster, lane) odometer schedule on a 2x2x2 mesh driven by a shared
:class:`repro.topology.Topology`.  The hierarchical result must match the
flat-axis result to fp-reassociation precision (the online-softmax terms
are identical, only their combine order differs) and the reference oracle
at the same tolerance as the flat path.
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.testing.x64 import x64_mode

#: |hier - flat| bound: same softmax terms, re-associated combine (f32)
REASSOC_TOL = 2e-6


def main(n: int = 8) -> None:
    # the f32 reassociation bounds assume x64 OFF, scoped via x64_mode
    # (flag restored + tamper-asserted on exit; import-clean)
    with x64_mode(False):
        _main(n)


def _main(n: int = 8) -> None:
    from repro.kernels import ref
    from repro.parallel.ring_attention import ring_attention
    from repro.topology import Topology

    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(0)
    B, S, H, Hkv, D = 2, 8 * 16, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)

    hier = None
    if n == 8:                       # the 2x2x2 three-level machine
        topo = Topology.from_levels([("pod", 2, 8.0), ("cluster", 2, 4.0),
                                     ("lane", 2, 2.0)])
        mesh3 = jax.make_mesh((2, 2, 2), ("pod", "cluster", "lane"))
        hier = lambda q, k, v, causal, window: ring_attention(
            q, k, v, mesh3, topology=topo, causal=causal, window=window)

    for causal, window in [(True, None), (False, None), (True, 24)]:
        got = jax.jit(lambda q, k, v: ring_attention(
            q, k, v, mesh, causal=causal, window=window))(q, k, v)
        want = ref.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=causal,
                             window=window).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        if hier is None:
            continue
        got3 = jax.jit(lambda q, k, v: hier(q, k, v, causal, window))(q, k, v)
        np.testing.assert_allclose(np.asarray(got3), np.asarray(want),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"hier vs oracle ({causal},{window})")
        np.testing.assert_allclose(
            np.asarray(got3), np.asarray(got), rtol=0, atol=REASSOC_TOL,
            err_msg=f"hier vs flat ({causal},{window})")
    print(f"check_ring_attention OK (n={n}"
          f"{', hier 2x2x2' if hier is not None else ''})")


if __name__ == "__main__":
    main(*(int(a) for a in sys.argv[1:]))
