"""Chaos-tested elastic training check (8 fake devices).

Runs the same training twice through ``launch.train.run_chaos``:

* **reference** — empty fault schedule: N steps, full (4, 2) mesh, no
  restarts (the uninterrupted loss curve);
* **chaos** — a transient straggler (tolerated, no eviction), a torn
  checkpoint (``ckpt_crash``: the newest save is corrupted after publish),
  and a host kill.  The harness must detect the kill via heartbeat
  timeout, back off, ``plan_rescale`` 8 -> 4 devices (one host of 4 lost,
  model axis intact), restore from the *previous* durable checkpoint
  (skipping the torn one), and replay data bit-identically.

Asserted, in order of strictness:

1. exactly the expected restart happened, onto the (2, 2) survivor mesh,
   from the pre-torn checkpoint step (proves the torn-write gate worked);
2. batch fingerprints are byte-identical per step across both runs —
   including every step recomputed after the rescale (the pipeline's
   (seed, step) purity surviving a mesh change);
3. loss-curve continuity: steps before the restore point match the
   reference bit-exactly (same mesh, same program); steps at/after the
   restore point — recomputed on the smaller mesh — match within fp
   tolerance (reduction-order drift only, compounding over the tail).

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       python -m repro.testing.check_chaos [--steps 12]
"""
from __future__ import annotations

import argparse
import shutil
import tempfile

import numpy as np

from repro.testing.x64 import x64_mode

#: the injected schedule: straggle is transient (EWMA recovers, no
#: eviction), the ckpt_crash tears the save landing after step 6 (the
#: step-8 checkpoint), the kill at step 5 is detected ~timeout later
CHAOS_SPEC = "straggle@1:h1:x2.5:d2,ckpt_crash@6,kill@5:h0"

#: fp tolerance for post-rescale steps: same math, different device
#: partitioning, so only reduction-order drift — loose enough for a few
#: steps of compounding, tight enough that a wrong restore (off-by-one
#: step, stale optimizer state) fails by orders of magnitude
POST_RESCALE_RTOL = 2e-3
POST_RESCALE_ATOL = 2e-4


def main(steps: int = 12, arch: str = "llama3-8b", seed: int = 0,
         verbose: bool = False) -> None:
    from repro.launch.train import run_chaos

    common = dict(arch=arch, steps=steps, seed=seed, n_hosts=2,
                  model_axis=2, global_batch=8, seq_len=32, ckpt_every=4,
                  timeout_s=3.5, base_step_s=1.0, verbose=verbose)
    dirs = [tempfile.mkdtemp(prefix="check_chaos_")
            for _ in ("ref", "chaos")]
    try:
        with x64_mode(False):
            ref = run_chaos(chaos_spec="", ckpt_dir=dirs[0], **common)
            chaos = run_chaos(chaos_spec=CHAOS_SPEC, ckpt_dir=dirs[1],
                              **common)

        assert ref["n_restarts"] == 0, ref["restarts"]
        assert ref["final_mesh_shape"] == [4, 2], ref["final_mesh_shape"]

        # 1. the restart state machine ran, rescaled, and skipped the torn
        #    checkpoint (save 8 was torn; save 4 is the durable one)
        assert chaos["n_restarts"] == 1, chaos["restarts"]
        r = chaos["restarts"][0]
        assert r["lost_hosts"] == [0], r
        assert r["new_mesh_shape"] == [2, 2], r
        assert chaos["final_mesh_shape"] == [2, 2], chaos["final_mesh_shape"]
        assert r["restore_step"] == 4, \
            (f"expected restore from the pre-torn step-4 checkpoint, got "
             f"{r['restore_step']} (torn-write gate failed?)")
        torn = [t for t in chaos["timeline"] if t["event"] == "ckpt_torn"]
        assert torn and torn[0]["ckpt_step"] == 8, chaos["timeline"]

        # 2. bit-identical (seed, step) batch replay across kill + rescale
        assert chaos["fingerprints"] == ref["fingerprints"], \
            "data replay diverged from the uninterrupted run"

        # 3. loss-curve continuity across the kill/restart boundary
        rstep = r["restore_step"]
        for s in range(rstep):
            assert chaos["losses"][s] == ref["losses"][s], \
                (f"pre-restart step {s} diverged: {chaos['losses'][s]} vs "
                 f"{ref['losses'][s]} (same mesh, must be bit-identical)")
        np.testing.assert_allclose(
            chaos["losses"][rstep:], ref["losses"][rstep:],
            rtol=POST_RESCALE_RTOL, atol=POST_RESCALE_ATOL,
            err_msg="post-restart loss curve diverged beyond fp tolerance")

        lost_work = chaos["steps_executed"] - steps
        print(f"check_chaos OK ({steps} steps, 1 kill + 1 torn ckpt + 1 "
              f"transient straggler; restored step {rstep} onto "
              f"{r['new_mesh_shape']}, {lost_work} steps of lost work "
              f"replayed bit-identically, post-rescale loss within "
              f"rtol={POST_RESCALE_RTOL:g})")
    finally:
        for d in dirs:
            shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verbose", action="store_true")
    a = ap.parse_args()
    main(steps=a.steps, arch=a.arch, seed=a.seed, verbose=a.verbose)
