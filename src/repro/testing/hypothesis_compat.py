"""Deterministic stand-in for the tiny slice of `hypothesis` this repo uses.

The CI environment is offline and cannot ``pip install hypothesis``; rather
than losing the four property-test modules, :func:`install` registers this
module's ``given`` / ``settings`` / ``strategies`` under the ``hypothesis``
name in ``sys.modules`` **only when the real package is missing** (see
``tests/conftest.py``).  With the real package present, nothing happens.

Differences from real hypothesis — all deliberate for an offline CI:

* examples are drawn from a seeded PRNG keyed on the test name, so every run
  exercises the identical case list (no flaky shrink phases, no database);
* ``max_examples`` is honoured (default 10);
* only the strategies the test-suite uses exist: ``integers``,
  ``sampled_from``, ``booleans``.
"""
from __future__ import annotations

import functools
import inspect
import random
import sys
import types
import zlib
from typing import Any, Sequence

DEFAULT_MAX_EXAMPLES = 10


class SearchStrategy:
    """A deterministic value source: ``draw(rng)`` yields one example."""

    def __init__(self, draw, label: str):
        self._draw = draw
        self.label = label

    def draw(self, rng: random.Random):
        return self._draw(rng)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"SearchStrategy({self.label})"


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.randint(min_value, max_value),
                          f"integers({min_value}, {max_value})")


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    els = list(elements)
    if not els:
        raise ValueError("sampled_from requires a non-empty sequence")
    return SearchStrategy(lambda rng: els[rng.randrange(len(els))],
                          f"sampled_from(<{len(els)} elements>)")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")


class settings:
    """Decorator recording ``max_examples`` for a later ``@given``."""

    def __init__(self, max_examples: int | None = None, deadline=None, **_):
        self.max_examples = max_examples

    def __call__(self, fn):
        fn._compat_settings = self
        return fn


def given(*arg_strategies: SearchStrategy, **kw_strategies: SearchStrategy):
    """Run the test over a deterministic, seeded example sweep."""

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # resolved at call time so @settings works above OR below @given
            # (wraps copied a below-@given marker; an above-@given settings
            # decorates the wrapper itself)
            cfg = getattr(wrapper, "_compat_settings", None)
            n_examples = (cfg.max_examples if cfg and cfg.max_examples
                          else DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = random.Random(seed)
            for _ in range(n_examples):
                pos = tuple(s.draw(rng) for s in arg_strategies)
                kws = {k: s.draw(rng) for k, s in kw_strategies.items()}
                fn(*args, *pos, **kwargs, **kws)

        # pytest must not mistake the strategy parameters for fixtures.
        wrapper.__signature__ = inspect.Signature()
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper

    return decorate


def install(force: bool = False) -> None:
    """Register the compat API as ``hypothesis`` if the real one is absent."""
    if not force:
        try:
            import hypothesis  # noqa: F401  (real package wins)
            return
        except ImportError:
            pass
    mod = types.ModuleType("hypothesis")
    mod.__doc__ = __doc__
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "booleans"):
        setattr(strategies, name, globals()[name])
    strategies.SearchStrategy = SearchStrategy
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
