"""Multi-device correctness checks for repro.core (run under 8 fake devices).

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       python -m repro.testing.check_core [C] [L]
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.testing.x64 import x64_mode


def main(C: int = 4, L: int = 2) -> None:
    # float64 scoped to this check: x64_mode restores the flag on exit and
    # asserts nothing inside re-toggled it (import stays clean)
    with x64_mode(True):
        _main(C, L)


def _main(C: int = 4, L: int = 2) -> None:
    from repro.core import isa_kernels, make_machine
    from repro.core.layout import mem_to_striped_host

    assert len(jax.devices()) >= C * L, "need more fake devices"
    n = C * L
    rng = np.random.default_rng(0)

    configs = [(g, r, "flat") for g in ("staged", "direct")
               for r in ("ring", "xla")]
    configs.append(("staged", "ring", "two-level"))   # §III-B.4 hierarchy
    for glsu_mode, reduce_mode, hierarchy in configs:
        v = make_machine(C, L, vlen_bits=4096, glsu_mode=glsu_mode,
                         reduce_mode=reduce_mode, hierarchy=hierarchy,
                         dtype=jnp.float64)

        # --- GLSU round trip + exact byte map --------------------------
        vl = n * n * 3
        x = rng.normal(size=vl)
        r = v.vle(x)
        np.testing.assert_array_equal(np.asarray(r.data),
                                      mem_to_striped_host(x, C, L))
        np.testing.assert_array_equal(np.asarray(v.vse(r)), x)

        # --- slides -----------------------------------------------------
        s = np.asarray(v.vse(v.vslide1down(r, fill=-7.0)))
        exp = np.concatenate([x[1:], [-7.0]])
        np.testing.assert_allclose(s, exp)
        s = np.asarray(v.vse(v.vslide1up(r, fill=-3.0)))
        np.testing.assert_allclose(s, np.concatenate([[-3.0], x[:-1]]))
        for k in (1, 2, n - 1, n, n + 3, 2 * n):
            s = np.asarray(v.vse(v.vslidedown(r, k)))
            exp = np.concatenate([x[k:], np.zeros(k)])
            np.testing.assert_allclose(
                s, exp, err_msg=f"slidedown k={k} {glsu_mode}/{reduce_mode}")

        # --- reductions --------------------------------------------------
        np.testing.assert_allclose(float(v.vredsum(r)), x.sum(), rtol=1e-12)
        np.testing.assert_allclose(float(v.vredmax(r)), x.max(), rtol=0)

        # --- elementwise + masks ----------------------------------------
        y = rng.normal(size=vl)
        ry = v.vle(y)
        np.testing.assert_allclose(np.asarray(v.vse(v.vfma(r, ry, ry))),
                                   x * y + y, rtol=1e-12)
        m = v.vmslt(r, 0.0)
        np.testing.assert_array_equal(int(v.vcpop(m)), int((x < 0).sum()))
        np.testing.assert_allclose(
            np.asarray(v.vse(v.vmerge(m, ry, r))), np.where(x < 0, y, x))

        # --- unpadded vl (tail handling) ---------------------------------
        vl2 = n * n * 2 + 5
        x2 = rng.normal(size=vl2)
        r2 = v.vle(x2)
        np.testing.assert_array_equal(np.asarray(v.vse(r2)), x2)
        np.testing.assert_allclose(float(v.vredsum(r2)), x2.sum(), rtol=1e-12)
        np.testing.assert_allclose(float(v.vredmax(r2)), x2.max())
        e2 = np.asarray(v.vse(v.vexp(r2)))
        np.testing.assert_allclose(e2, np.exp(x2), rtol=1e-12)

    # --- paper kernels on the JAX machine vs numpy ---------------------------
    v = make_machine(C, L, vlen_bits=65536, dtype=jnp.float64)
    N = n * 8

    A = rng.normal(size=(3, 4))
    B = rng.normal(size=(4, N))
    np.testing.assert_allclose(isa_kernels.fmatmul(v, A, B), A @ B, rtol=1e-10)

    a, b = rng.normal(size=N), rng.normal(size=N)
    np.testing.assert_allclose(float(isa_kernels.fdotproduct(v, a, b)),
                               float(a @ b), rtol=1e-10)

    M = rng.normal(size=(4, N))
    got = isa_kernels.jacobi2d(v, M)
    pad = np.pad(M, ((0, 0), (1, 1)))
    want = 0.25 * (M[:-2] + M[2:] + pad[1:-1, :-2] + pad[1:-1, 2:])
    np.testing.assert_allclose(got, want, rtol=1e-10)

    F = rng.normal(size=(3, 3))
    Img = rng.normal(size=(5, N))
    got = isa_kernels.fconv2d(v, Img, F)
    want = np.zeros((3, N - 2))
    for r_ in range(3):
        for c_ in range(3):
            want += F[r_, c_] * Img[r_:r_ + 3, c_:c_ + N - 2][:, :N - 2] * 0
    # direct reference conv (valid mode)
    from numpy.lib.stride_tricks import sliding_window_view
    win = sliding_window_view(Img, (3, 3))
    want = np.einsum("ijkl,kl->ij", win, F)
    np.testing.assert_allclose(got, want, rtol=1e-10)

    S = rng.normal(size=(3, N))
    got = isa_kernels.softmax(v, S)
    e = np.exp(S - S.max(axis=1, keepdims=True))
    np.testing.assert_allclose(got, e / e.sum(axis=1, keepdims=True), rtol=1e-10)

    got = isa_kernels.vexp(v, a)
    np.testing.assert_allclose(got, np.exp(a), rtol=1e-10)

    print(f"check_core OK (C={C}, L={L}, n={n})")


if __name__ == "__main__":
    argv = [int(a) for a in sys.argv[1:]]
    main(*argv)
