"""Multi-device check: pod-local KV serving on a 2x2x2 mesh of 8 devices.

Two :class:`repro.serve.ServingEngine` instances run the identical request
stream on the same (pod, data, model) mesh with the same sharding rules —
one topology-blind, one with the three-level Topology.  The check asserts:

  1. *placement*: every KV-cache leaf of the topology engine is sharded by
     inner-level axes only (the `pod` axis never appears in a cache
     PartitionSpec), both at construction and after the decode loop ran;
  2. *affinity*: after pods have served distinct prompt prefixes, a request
     repeating a prefix is admitted into a slot of the pod that already
     holds it, even though lower-numbered slots in the other pod are free
     (the blind engine keeps the historical first-free order);
  3. *bit-identity*: per-request token streams of the two engines match
     exactly — placement and affinity only move where a request lands,
     never what it computes.

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       python -m repro.testing.check_serve_topology
"""
from __future__ import annotations

import sys

import jax
import numpy as np


def _spec_axes(spec) -> set:
    out = set()
    for part in spec:
        if part is None:
            continue
        out.update((part,) if isinstance(part, str) else part)
    return out


def _assert_pod_local(engine, when: str) -> set:
    seen = set()
    for leaf in jax.tree.leaves(engine.cache):
        axes = _spec_axes(leaf.sharding.spec)
        assert "pod" not in axes, \
            f"cache sharded across the pod ring {when}: {leaf.sharding.spec}"
        seen |= axes
    return seen


def main(n: int = 8) -> None:
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.parallel.sharding import default_rules, init_params
    from repro.serve import Request, ServeConfig, ServingEngine
    from repro.topology import Topology

    assert len(jax.devices()) >= n, "need more fake devices"
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    topo = Topology.from_levels([("pod", 2, 8.0), ("data", 2, 4.0),
                                 ("model", 2, 2.0)])
    cfg = get_smoke_config("llama3-8b")
    # serving rules: batch stays unsharded (the admit loop prefills one
    # request at a time), TP over `model` as in the decode dry-run cells
    rules = default_rules(mesh, kv_heads=cfg.n_kv_heads, batch=1)
    params = init_params(lm.model_defs(cfg), jax.random.key(0))
    scfg = ServeConfig(max_batch=4, max_seq=64)

    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, cfg.vocab_size, int(rng.integers(4, 12)))
               .astype(np.int32) for _ in range(3)]
    prompts.append(prompts[2].copy())       # r3 repeats r2's prefix

    def request_stream():
        return [Request(rid=i, prompt=p, max_new_tokens=6)
                for i, p in enumerate(prompts)]

    def drive(engine, reqs):
        # phase 1: three distinct prompts fill slots 0..2 in both engines
        for r in reqs[:3]:
            engine.submit(r)
        engine.run()
        # phase 2: all slots free again; r3 repeats r2's prefix
        engine.submit(reqs[3])
        engine.run()
        return {r.rid: (r.slot, list(r.out)) for r in reqs}

    blind = ServingEngine(cfg, params, rules, scfg)
    aware = ServingEngine(cfg, params, rules, scfg, topology=topo)
    assert aware.n_pods == 2

    axes_used = _assert_pod_local(aware, "at construction")
    assert {"data", "model"} <= axes_used, \
        f"cache should still shard over inner axes, got {axes_used}"

    reqs_b, reqs_a = request_stream(), request_stream()
    got_b = drive(blind, reqs_b)
    got_a = drive(aware, reqs_a)
    _assert_pod_local(aware, "after the decode loop")

    # bit-identical token streams, request by request
    for rid in got_b:
        assert got_b[rid][1] == got_a[rid][1], \
            (rid, got_b[rid][1], got_a[rid][1])

    # phase-1 admission is first-free in both engines (no prefix history)
    assert [got_a[i][0] for i in range(3)] == [0, 1, 2]
    # r2's prefix landed in slot 2 = pod 1; the aware engine steers the
    # repeat there while the blind engine reuses the first free slot
    assert aware.slot_pod(2) == 1
    assert got_b[3][0] == 0, got_b[3]
    assert aware.slot_pod(got_a[3][0]) == 1, got_a[3]

    print(f"check_serve_topology OK (mesh 2x2x2, {n} devices; "
          f"pod-local cache axes={sorted(axes_used)})")


if __name__ == "__main__":
    argv = [int(a) for a in sys.argv[1:]]
    main(*argv)
