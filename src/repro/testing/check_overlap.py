"""Multi-device overlap checks (8 fake devices): the double-buffered
schedules are *semantically free* — bit-identical or tolerance-equivalent
to their sequential twins — and their 8-device wall-clock is measured.

Parts (first CLI argument; default ``all``):

``attn``  ring attention ``schedule="db"`` vs ``"seq"`` on the flat 8-ring
          and on the hierarchical 2x2x2 (pod, cluster, lane) odometer —
          bit-identical results, plus ``ringattn/...`` CSV rows with the
          median wall-clock of both schedules (the measured sequential-vs-
          double-buffered comparison ``benchmarks/run.py ring_attn``
          records into BENCH_sim.json).

``grad``  the bucketed, backward-overlapped gradient sync
          (``make_grad_sync(bucket_mb=...)``, ``fsdp_hier_ov``) is
          grad-equivalent to the plain hierarchical hook (``fsdp_hier``)
          on the tiny trainer: one train step of the smoke llama3-8b on a
          2x2x2 mesh under pod-local FSDP rules, updated params and loss
          compared across the two hooks (and against no hook at all —
          sharding constraints and optimization barriers are identities).

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       python -m repro.testing.check_overlap [attn|grad|all]
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.testing.timing import median_time_us
from repro.testing.x64 import x64_mode


def _attn(n: int = 8) -> None:
    from repro.parallel.ring_attention import ring_attention
    from repro.topology import Topology

    rng = np.random.default_rng(0)
    B, S, H, Hkv, D = 2, n * 16, 4, 2, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.float32)

    mesh = jax.make_mesh((n,), ("data",))
    cases = {"flat": dict(mesh=mesh)}
    if n == 8:
        topo = Topology.from_levels([("pod", 2, 8.0), ("cluster", 2, 4.0),
                                     ("lane", 2, 2.0)])
        mesh3 = jax.make_mesh((2, 2, 2), ("pod", "cluster", "lane"))
        cases["hier2x2x2"] = dict(mesh=mesh3, topology=topo)

    for name, kw in cases.items():
        outs = {}
        for sched in ("seq", "db"):
            fn = jax.jit(lambda q, k, v, kw=kw, sched=sched: ring_attention(
                q, k, v, kw["mesh"], topology=kw.get("topology"),
                causal=True, schedule=sched))
            outs[sched] = np.asarray(fn(q, k, v))
            us = median_time_us(fn, q, k, v, reps=5, warmup=1)
            print(f"ringattn/{name}/{sched},{us:.0f},ok")
        # same blocks, same order, same arithmetic: db must be bit-identical
        np.testing.assert_array_equal(outs["db"], outs["seq"],
                                      err_msg=f"db vs seq ({name})")
    print(f"check_overlap attn OK (n={n})")


def _grad() -> None:
    from repro.configs import get_smoke_config
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import make_production_mesh, parse_launch_topology
    from repro.launch.perf import apply_strategy
    from repro.train import (OptConfig, init_train_state, make_grad_sync,
                             make_train_step)

    cfg = get_smoke_config("llama3-8b")
    topo = parse_launch_topology("2x2x2")
    mesh = make_production_mesh(topology=topo)
    shape = ShapeSpec("tiny_train", 32, 8, "train")
    cfg, rules, _, sync_hier = apply_strategy("fsdp_hier", cfg, shape, mesh,
                                              topo)
    # tiny bucket size so the smoke model genuinely splits into >1 bucket
    sync_ov = make_grad_sync(cfg, rules, bucket_mb=0.02)

    ocfg = OptConfig()
    key = jax.random.PRNGKey(0)
    state0 = init_train_state(cfg, ocfg, key)
    tokens = jnp.asarray(
        np.random.default_rng(1).integers(0, cfg.vocab_size,
                                          size=(shape.global_batch,
                                                shape.seq_len)), jnp.int32)
    batch = {"tokens": tokens}

    results = {}
    for name, sync in (("none", None), ("hier", sync_hier), ("ov", sync_ov)):
        step = jax.jit(make_train_step(cfg, rules, ocfg, grad_sync=sync))
        state1, metrics = step(state0, batch)
        results[name] = (jax.tree.map(np.asarray, state1.params),
                         float(metrics["loss"]))

    l_none, l_hier, l_ov = (results[k][1] for k in ("none", "hier", "ov"))
    assert l_hier == l_ov, (l_hier, l_ov)     # loss precedes the sync: exact
    assert l_none == l_hier, (l_none, l_hier)
    ref = results["hier"][0]
    for name in ("none", "ov"):
        got = results[name][0]
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                a, b, rtol=1e-6, atol=1e-7,
                err_msg=f"params diverge ({name} vs hier)"),
            got, ref)
    print("check_overlap grad OK (fsdp_hier == fsdp_hier_ov == unsynced)")


def main(part: str = "all", n: int = 8) -> None:
    with x64_mode(False):                     # f32 tolerances assume x64 off
        if part in ("attn", "all"):
            _attn(n)
        if part in ("grad", "all"):
            _grad()


if __name__ == "__main__":
    args = sys.argv[1:]
    main(args[0] if args else "all", *(int(a) for a in args[1:]))
