"""Multi-process chaos acceptance check: real SIGKILL, real clocks.

The procs-mode counterpart of ``check_chaos``: the same elastic-training
story (detection -> backoff -> rescale -> newest-valid restore -> bit-exact
replay), but every simulated host is a separate OS process heartbeating
over a localhost socket, and every injected fault is a real ``SIGKILL``
(see ``repro.ft.cluster``).  Three runs:

* **reference** — no faults: one epoch, full (4, 2) mesh over 4 worker
  processes, the uninterrupted loss curve;
* **chaos** — ``kill@4:h2,kill@4:h3,ckpt_crash@5``: two standbys are
  SIGKILLed at the step-4 fence (8 -> 4 devices, whole dp rows, model
  axis intact), then the ``ckpt_crash`` SIGKILLs the *writer* parked
  mid-save of the step-8 checkpoint — leaf files durable, manifest never
  published — forcing the next epoch to fall back to the step-4
  checkpoint (4 -> 2 devices, primary fails over from h0 to h1);
* **chaos again, same seed** — byte-for-byte the same records once real
  detection latencies and backoffs are stripped: the fence discipline
  pins *where* in the step stream the SIGKILLs land, so real-clock chaos
  is still a deterministic, diffable experiment.

Asserted: the expected restart sequence (detected by missed socket
heartbeats within the real deadline window), restore step 4 both times
(the mid-write-killed step-8 dir must fail the validity gate), byte-
identical batch fingerprints vs the reference — including every replayed
step — bit-exact pre-restore losses, fp-tolerance continuity after, and
full determinism across the two seeded chaos runs.

Usage: python -m repro.testing.check_chaos_procs [--steps 10]
(the parent needs no fake devices — workers pin their own env).
"""
from __future__ import annotations

import argparse
import shutil
import tempfile

import numpy as np

#: two whole-host kills at one fence (dp 4 -> 2: param dims must stay
#: divisible by dp, so hosts die in powers of two), then a mid-write
#: writer kill tearing the step-8 checkpoint
CHAOS_SPEC = "kill@4:h2,kill@4:h3,ckpt_crash@5"

#: post-rescale fp tolerance: two mesh changes (8 -> 4 -> 2 devices)
#: recompute the tail with different reduction partitionings; anything
#: beyond reduction-order drift (wrong restore step, stale optimizer
#: state) misses by orders of magnitude
POST_RESCALE_RTOL = 5e-3
POST_RESCALE_ATOL = 5e-4

#: the heartbeat deadline the supervisor enforces (real seconds), and the
#: slack CI machine load is allowed to add on top before we call the
#: detection path broken
TIMEOUT_S = 2.0
DETECT_SLACK_S = 30.0


def _strip_timing(out: dict) -> dict:
    """The determinism contract: everything except real-clock latencies
    (detection, backoff) and log paths must replay byte-identically."""
    return {
        "losses": out["losses"],
        "fingerprints": out["fingerprints"],
        "steps_executed": out["steps_executed"],
        "final_mesh_shape": out["final_mesh_shape"],
        "epochs": out["epochs"],
        "chaos_spec": out["chaos_spec"],
        "restarts": [{k: v for k, v in r.items()
                      if k not in ("detect_s", "backoff_s")}
                     for r in out["restarts"]],
        "timeline": [{k: v for k, v in t.items() if k != "logs"}
                     for t in out["timeline"]],
    }


def main(steps: int = 10, arch: str = "llama3-8b", seed: int = 0,
         verbose: bool = False) -> None:
    from repro.checkpoint.ckpt import valid_steps
    from repro.ft.cluster import ClusterSupervisor

    common = dict(steps=steps, n_hosts=4, n_devices=8, model_axis=2,
                  global_batch=8, seq_len=32, seed=seed, ckpt_every=4,
                  timeout_s=TIMEOUT_S, beat_interval_s=0.1,
                  backoff_s=0.05, verbose=verbose)
    dirs = [tempfile.mkdtemp(prefix="check_chaos_procs_")
            for _ in ("ref", "chaos_a", "chaos_b")]
    try:
        ref = ClusterSupervisor(arch, ckpt_dir=dirs[0], **common).run()
        chaos = ClusterSupervisor(arch, chaos_spec=CHAOS_SPEC,
                                  ckpt_dir=dirs[1], **common).run()
        again = ClusterSupervisor(arch, chaos_spec=CHAOS_SPEC,
                                  ckpt_dir=dirs[2], **common).run()

        assert ref["n_restarts"] == 0, ref["restarts"]
        assert ref["final_mesh_shape"] == [4, 2], ref["final_mesh_shape"]
        assert ref["epochs"] == 1, ref["epochs"]

        # 1. the restart sequence: fence double-kill then mid-write kill,
        #    each detected by missed socket heartbeats on the real clock
        assert chaos["n_restarts"] == 2, chaos["restarts"]
        r0, r1 = chaos["restarts"]
        assert r0["lost_hosts"] == [2, 3], r0
        assert r0["new_mesh_shape"] == [2, 2], r0
        assert r0["restore_step"] == 4, r0
        assert r1["lost_hosts"] == [0], r1          # the writer died...
        assert r1["new_mesh_shape"] == [1, 2], r1
        assert r1["restore_step"] == 4, \
            (f"expected fallback to the step-4 checkpoint (step 8 was "
             f"killed mid-write, manifest unpublished), got "
             f"{r1['restore_step']}")
        assert chaos["final_mesh_shape"] == [1, 2], chaos["final_mesh_shape"]
        mid = [t for t in chaos["timeline"] if t["event"] == "ckpt_mid_kill"]
        assert mid and mid[0]["ckpt_step"] == 8 and mid[0]["host"] == 0, \
            chaos["timeline"]
        for r in (r0, r1):
            assert r["detect_s"] is not None and \
                TIMEOUT_S - 0.5 < r["detect_s"] < TIMEOUT_S + DETECT_SLACK_S, \
                (f"detection latency {r['detect_s']} outside the heartbeat-"
                 f"deadline window (timeout {TIMEOUT_S}s)")

        # 2. the failed-over survivor rewrote checkpoint 8 properly
        assert 8 in valid_steps(dirs[1]), valid_steps(dirs[1])

        # 3. bit-identical (seed, step) batch replay across both SIGKILLs
        #    and both rescales
        assert chaos["fingerprints"] == ref["fingerprints"], \
            "data replay diverged from the uninterrupted run"

        # 4. loss continuity: bit-exact before the restore point (same
        #    mesh, same program), fp tolerance after (tail recomputed on
        #    the shrunk meshes)
        rstep = r1["restore_step"]
        for s in range(rstep):
            assert chaos["losses"][s] == ref["losses"][s], \
                (f"pre-restore step {s} diverged: {chaos['losses'][s]} vs "
                 f"{ref['losses'][s]} (same mesh, must be bit-identical)")
        np.testing.assert_allclose(
            chaos["losses"][rstep:], ref["losses"][rstep:],
            rtol=POST_RESCALE_RTOL, atol=POST_RESCALE_ATOL,
            err_msg="post-restart loss curve diverged beyond fp tolerance")

        # 5. determinism: the second seeded run replays the whole
        #    experiment byte-identically once real latencies are stripped
        assert _strip_timing(chaos) == _strip_timing(again), \
            "seeded chaos runs diverged (real-clock nondeterminism leaked " \
            "into the step stream)"
        assert chaos["steps_executed"] > steps, chaos["steps_executed"]

        lost_work = chaos["steps_executed"] - steps
        print(f"check_chaos_procs OK ({steps} steps, 3 real SIGKILLs across "
              f"2 restarts; detected in "
              f"{r0['detect_s']:.2f}s/{r1['detect_s']:.2f}s via socket "
              f"heartbeats, restored step {rstep} onto "
              f"{r1['new_mesh_shape']}, {lost_work} steps of lost work "
              f"replayed bit-identically, deterministic across seeded runs)")
    finally:
        for d in dirs:
            shutil.rmtree(d, ignore_errors=True)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--verbose", action="store_true")
    a = ap.parse_args()
    main(steps=a.steps, arch=a.arch, seed=a.seed, verbose=a.verbose)
