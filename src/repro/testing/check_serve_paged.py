"""Multi-device check: paged-KV serving on a 2x2x2 mesh of 8 devices.

The paged engine (:class:`repro.serve.PagedServingEngine`) and the dense
:class:`repro.serve.ServingEngine` run the identical request stream on the
same (pod, data, model) mesh with the same sharding rules.  Asserts:

  1. *bit-identity*: per-request token streams of dense and paged match
     exactly for the same admission order — the block-table indirection,
     COW prefix sharing, and the zero-block gather are all invisible to
     the math;
  2. *block reuse*: with duplicate prompts in the stream the allocator
     records shared-prefix hits, and a shared block that must diverge is
     copied (COW) rather than mutated in place;
  3. *hygiene*: after all requests finish every block is back on the free
     list (no leaks) and the zero block stays all-zeros;
  4. *chunked prefill*: the chunk-interleaved engine completes the same
     stream (admission under PREFILL, per-slot positions) and its streams
     also match dense for this single-slot-prefill admission order;
  5. *router affinity*: behind :class:`repro.serve.PrefixRouter`, a
     repeated prompt routes to the pod that served it first.

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       python -m repro.testing.check_serve_paged
"""
from __future__ import annotations

import sys

import jax
import numpy as np


def _drive(engine, reqs):
    for r in reqs:
        engine.submit(r)
    engine.run()
    return {r.rid: list(r.out) for r in reqs}


def main(n: int = 8) -> None:
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.parallel.sharding import default_rules, init_params
    from repro.serve import (PagedServeConfig, PagedServingEngine,
                             PrefixRouter, Request, ServeConfig,
                             ServingEngine)

    assert len(jax.devices()) >= n, "need more fake devices"
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = get_smoke_config("llama3-8b")
    rules = default_rules(mesh, kv_heads=cfg.n_kv_heads, batch=1)
    params = init_params(lm.model_defs(cfg), jax.random.key(0))

    rng = np.random.default_rng(0)
    base = [rng.integers(1, cfg.vocab_size, int(rng.integers(5, 20)))
            .astype(np.int32) for _ in range(4)]
    # duplicates adjacent to their originals so the sharing pairs are
    # co-resident (admitted in the same wave -> block retain, not re-alloc)
    prompts = [base[0], base[0].copy(), base[1], base[1].copy(),
               base[2], base[3]]
    reqs = lambda: [Request(rid=i, prompt=p, max_new_tokens=8)
                    for i, p in enumerate(prompts)]

    dense = ServingEngine(cfg, params, rules, ServeConfig(max_batch=4,
                                                          max_seq=64))
    got_dense = _drive(dense, reqs())

    scfg = PagedServeConfig(max_batch=4, max_seq=64, block_tokens=8,
                            n_blocks=32)
    paged = PagedServingEngine(cfg, params, rules, scfg)
    got_paged = _drive(paged, reqs())

    # 1. bit-identity per request
    for rid in got_dense:
        assert got_dense[rid] == got_paged[rid], \
            (rid, got_dense[rid], got_paged[rid])

    # 2. duplicate prompts became block reuse, and divergence copied
    assert paged.alloc.shared_hits >= 1, "no shared-prefix block reuse"
    assert paged.cow_copies >= 1, "no COW copy despite shared full blocks"

    # 3. allocator hygiene: everything returned, zero block untouched —
    # shutdown() is the full gate (free list, refcounts, prefix registry)
    paged.shutdown()
    zeros = jax.tree.leaves(paged.pool)
    assert all(bool((leaf[:, 0] == 0).all()) for leaf in zeros), \
        "zero block written"

    # 4. chunked prefill completes the stream with identical streams for
    # this admission order (single prefill slot at a time)
    chunked = PagedServingEngine(cfg, params, rules,
                                 PagedServeConfig(max_batch=4, max_seq=64,
                                                  block_tokens=8,
                                                  n_blocks=32, chunk=16))
    got_chunked = _drive(chunked, reqs())
    for rid in got_dense:
        assert got_dense[rid] == got_chunked[rid], \
            (rid, got_dense[rid], got_chunked[rid])
    assert chunked.prefill_chunks > 0, "chunked engine never chunked"

    # 5. prefix-affinity routing: r1 (dup of r0) follows r0's pod even
    # when the other pod is idle
    def fresh():
        return PagedServingEngine(cfg, params, rules, scfg)

    router = PrefixRouter([fresh(), fresh()])
    stream = reqs()
    pod_first = router.submit(stream[0])     # r0 lands somewhere
    router.run()
    for r in stream[2:]:
        router.submit(r)                     # load up both pods
    router.run()
    pod_dup = router.submit(stream[1])       # dup of r0
    router.run()
    assert pod_dup == pod_first, \
        f"duplicate prompt routed {pod_first} -> {pod_dup}"
    assert router.affinity_hits >= 1

    print(f"check_serve_paged OK (mesh 2x2x2, {n} devices; "
          f"shared_hits={paged.alloc.shared_hits} "
          f"cow_copies={paged.cow_copies} "
          f"peak_blocks={paged.alloc.peak_allocated} "
          f"prefill_chunks={chunked.prefill_chunks})")


if __name__ == "__main__":
    argv = [int(a) for a in sys.argv[1:]]
    main(*argv)
