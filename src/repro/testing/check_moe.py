"""Multi-device check: MoE EP (psum) and EP (a2a) match the local oracle."""
import sys

import jax
import jax.numpy as jnp
import numpy as np


def main(nd: int = 2, nm: int = 4) -> None:
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import layers as L
    from repro.models import lm
    from repro.parallel.sharding import default_rules, init_params

    mesh = jax.make_mesh((nd, nm), ("data", "model"))
    cfg0 = get_smoke_config("qwen3-moe-235b-a22b")
    cfg0 = dataclasses.replace(cfg0, n_experts=8, experts_per_token=2,
                               capacity_factor=8.0)
    rng = np.random.default_rng(0)
    B, S = 4, 16
    from repro.parallel.sharding import PV
    defs = L.moe_defs(cfg0)
    params = init_params(defs, jax.random.key(1))
    x = jnp.asarray(rng.normal(size=(B, S, cfg0.d_model)) * 0.3, jnp.float32)

    rules0 = default_rules(None)
    want = L.moe_layer(params, x, cfg0, rules0)

    rules = default_rules(mesh, act_seq=True, batch=B)
    with mesh:
        got_ep = jax.jit(lambda p, x: L.moe_layer(
            p, x, cfg0, rules))(params, x)
        cfg_a2a = dataclasses.replace(cfg0, moe_impl="a2a")
        got_a2a = jax.jit(lambda p, x: L.moe_layer(
            p, x, cfg_a2a, rules))(params, x)
    np.testing.assert_allclose(np.asarray(got_ep), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_a2a), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    print(f"check_moe OK (mesh {nd}x{nm})")


if __name__ == "__main__":
    main(*(int(a) for a in sys.argv[1:]))
