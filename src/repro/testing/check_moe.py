"""Multi-device check: MoE EP (psum) and EP (a2a) match the local oracle —
including the hierarchical a2a, which must be *bit-identical* to the flat
exchange (the per-level all-to-all stages invert exactly and the expert FFN
is row-independent, so no fp reassociation occurs)."""
import sys

import jax
import jax.numpy as jnp
import numpy as np


def main(nd: int = 2, nm: int = 4) -> None:
    import dataclasses
    from repro.configs import get_smoke_config
    from repro.models import layers as L
    from repro.models import lm
    from repro.parallel.sharding import (ShardingRules, default_rules,
                                         init_params)
    from repro.topology import Topology

    mesh = jax.make_mesh((nd, nm), ("data", "model"))
    cfg0 = get_smoke_config("qwen3-moe-235b-a22b")
    cfg0 = dataclasses.replace(cfg0, n_experts=8, experts_per_token=2,
                               capacity_factor=8.0)
    rng = np.random.default_rng(0)
    B, S = 4, 16
    from repro.parallel.sharding import PV
    defs = L.moe_defs(cfg0)
    params = init_params(defs, jax.random.key(1))
    x = jnp.asarray(rng.normal(size=(B, S, cfg0.d_model)) * 0.3, jnp.float32)

    rules0 = default_rules(None)
    want = L.moe_layer(params, x, cfg0, rules0)

    rules = default_rules(mesh, act_seq=True, batch=B)
    with mesh:
        got_ep = jax.jit(lambda p, x: L.moe_layer(
            p, x, cfg0, rules))(params, x)
        cfg_a2a = dataclasses.replace(cfg0, moe_impl="a2a")
        got_a2a = jax.jit(lambda p, x: L.moe_layer(
            p, x, cfg_a2a, rules))(params, x)
    np.testing.assert_allclose(np.asarray(got_ep), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_a2a), np.asarray(want),
                               rtol=2e-4, atol=2e-4)

    # Hierarchical EP a2a on the 2x2x2 three-level machine: the expert ring
    # spans every topology level axis; results must be BIT-identical both
    # to the one-stage exchange on the same mesh and to the single-axis
    # flat machine.
    if nd * nm == 8:
        topo = Topology.from_levels([("pod", 2, 8.0), ("cluster", 2, 4.0),
                                     ("lane", 2, 2.0)])
        mesh3 = jax.make_mesh((2, 2, 2), ("pod", "cluster", "lane"))
        axes = ("pod", "cluster", "lane")
        rules3 = ShardingRules(mesh3, {"batch": None, "seq": None,
                                       "fsdp": None, "model": axes,
                                       "kv": None, "cache_seq": None,
                                       "act_seq": axes})
        assert L.moe_mode(cfg_a2a, rules3) == "ep_a2a"
        mesh1 = jax.make_mesh((8,), ("model",))
        rules1 = default_rules(mesh1, act_seq=True, batch=B)
        with mesh1:
            got_flat1 = jax.jit(lambda p, x: L.moe_layer(
                p, x, cfg_a2a, rules1))(params, x)
        with mesh3:
            got_hier = jax.jit(lambda p, x: L.moe_layer(
                p, x, cfg_a2a, rules3, topology=topo))(params, x)
            got_flat3 = jax.jit(lambda p, x: L.moe_layer(
                p, x, cfg_a2a, rules3))(params, x)
        np.testing.assert_allclose(np.asarray(got_hier), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_array_equal(np.asarray(got_hier),
                                      np.asarray(got_flat3),
                                      err_msg="hier vs one-stage (same mesh)")
        np.testing.assert_array_equal(np.asarray(got_hier),
                                      np.asarray(got_flat1),
                                      err_msg="hier vs flat single axis")
        print("check_moe hier 2x2x2 bitwise OK")
    print(f"check_moe OK (mesh {nd}x{nm})")


if __name__ == "__main__":
    main(*(int(a) for a in sys.argv[1:]))
