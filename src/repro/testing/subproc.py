"""Run a repro.testing check module in a subprocess with N fake devices.

The child gets exactly N devices regardless of what the parent inherited
(``tests/conftest.py`` sets 8 idempotently for the main pytest process),
so every multi-device correctness check runs as
``python -m repro.testing.<module>`` with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` pinned in its env.
"""
from __future__ import annotations

import os
import pathlib
import subprocess
import sys

SRC = str(pathlib.Path(__file__).resolve().parents[2])


def pinned_env(devices: int = 8) -> dict[str, str]:
    """A child-process environment with the fake-device count, ``src`` on
    ``PYTHONPATH``, and the CPU platform pinned — the one way any repro
    subprocess (check modules, chaos cluster workers) gets its devices,
    regardless of what this process inherited."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


def run_check(module: str, *args: str, devices: int = 8, timeout: int = 900) -> str:
    env = pinned_env(devices)
    proc = subprocess.run(
        [sys.executable, "-m", module, *args],
        env=env, capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise AssertionError(
            f"multi-device check {module} {args} failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    return proc.stdout
