"""Steady-state wall-clock helper for the multi-device checks.

Single-shot timings on the CI hosts jump by integer factors with scheduler
noise; every ``coll/`` / ``ringattn/`` CSV row therefore reports the
*median* of ``reps`` compiled executions after ``warmup`` discarded calls.
"""
from __future__ import annotations

import statistics
import time

import jax


def median_time_us(fn, *args, reps: int = 10, warmup: int = 2) -> float:
    """Compiled-execution microseconds: jit once, ``warmup`` discarded
    steady-state calls, then the median of ``reps`` timed calls."""
    jfn = jax.jit(fn)
    jax.block_until_ready(jfn(*args))          # compile
    for _ in range(warmup):
        jax.block_until_ready(jfn(*args))
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(jfn(*args))
        samples.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(samples)
