"""The repo's single wall-clock authority (lint rule L4).

Single-shot timings on the CI hosts jump by integer factors with scheduler
noise; every ``coll/`` / ``ringattn/`` CSV row therefore reports the
*median* of ``reps`` compiled executions after ``warmup`` discarded calls.
Everything else that needs a clock — elapsed-seconds progress lines,
benchmark stopwatches — goes through :func:`now`, so clock discipline
(monotonic vs wall, steady-state medians) is decided in exactly one file.

jax is imported lazily: the sim-only benchmark sections and the lint
front must stay importable without pulling in the runtime.
"""
from __future__ import annotations

import dataclasses
import statistics
import time


@dataclasses.dataclass(frozen=True)
class Sample:
    """A steady-state timing sample: the median plus its dispersion.

    ``iqr_us`` is the interquartile range of the timed reps — consumers
    (the kernel autotuner) use it to reject noisy ranks instead of caching
    a scheduler fluke.  ``reps`` is the number of timed calls behind the
    statistics (warmup calls excluded).
    """
    median_us: float
    iqr_us: float
    reps: int


def now() -> float:
    """Monotonic seconds — the only sanctioned raw clock read.

    Monotonic on purpose: every in-repo use is an *interval* (elapsed
    training seconds, tokens/s, benchmark stopwatches), where wall clocks
    lie under NTP slew.  Timestamps-of-record do not exist in this repo;
    artifacts are keyed by config, not date.
    """
    return time.perf_counter()


def monotonic() -> float:
    """Real monotonic seconds — the *liveness-deadline* clock.

    The second (and last) sanctioned raw clock read.  :func:`now` serves
    interval *measurement* (benchmark stopwatches, tokens/s); this one
    serves *deadlines* against the outside world: the multi-process chaos
    supervisor (``repro.ft.cluster``) must decide that a worker whose
    socket heartbeats stopped is actually dead, which is only meaningful
    on a clock that keeps ticking while this process sleeps.
    ``time.monotonic`` never jumps under NTP slew and, unlike
    ``perf_counter``, is documented system-wide on the platforms we run
    on — two processes' deadlines compose.  Everything virtual-clock
    (``ft.chaos.VirtualClock``) stays virtual; reaching for this function
    outside supervisor liveness code is an L4 finding waiting to happen.
    """
    return time.monotonic()


def measure_us(fn, *args, reps: int = 10, warmup: int = 2) -> Sample:
    """Compiled-execution microseconds with dispersion: jit once,
    ``warmup`` discarded steady-state calls, then ``reps`` timed calls
    summarised as a :class:`Sample` (median + IQR)."""
    import jax
    jfn = jax.jit(fn)
    jax.block_until_ready(jfn(*args))          # compile
    for _ in range(warmup):
        jax.block_until_ready(jfn(*args))
    samples = []
    for _ in range(max(reps, 1)):
        t0 = now()
        jax.block_until_ready(jfn(*args))
        samples.append((now() - t0) * 1e6)
    if len(samples) >= 2:
        q1, _, q3 = statistics.quantiles(samples, n=4)
        iqr = q3 - q1
    else:
        iqr = 0.0
    return Sample(median_us=statistics.median(samples), iqr_us=iqr,
                  reps=len(samples))


def median_time_us(fn, *args, reps: int = 10, warmup: int = 2) -> float:
    """Float-returning façade over :func:`measure_us` (the historical
    call-site contract: just the median)."""
    return measure_us(fn, *args, reps=reps, warmup=warmup).median_us
