"""The repo's single wall-clock authority (lint rule L4).

Single-shot timings on the CI hosts jump by integer factors with scheduler
noise; every ``coll/`` / ``ringattn/`` CSV row therefore reports the
*median* of ``reps`` compiled executions after ``warmup`` discarded calls.
Everything else that needs a clock — elapsed-seconds progress lines,
benchmark stopwatches — goes through :func:`now`, so clock discipline
(monotonic vs wall, steady-state medians) is decided in exactly one file.

jax is imported lazily: the sim-only benchmark sections and the lint
front must stay importable without pulling in the runtime.
"""
from __future__ import annotations

import statistics
import time


def now() -> float:
    """Monotonic seconds — the only sanctioned raw clock read.

    Monotonic on purpose: every in-repo use is an *interval* (elapsed
    training seconds, tokens/s, benchmark stopwatches), where wall clocks
    lie under NTP slew.  Timestamps-of-record do not exist in this repo;
    artifacts are keyed by config, not date.
    """
    return time.perf_counter()


def median_time_us(fn, *args, reps: int = 10, warmup: int = 2) -> float:
    """Compiled-execution microseconds: jit once, ``warmup`` discarded
    steady-state calls, then the median of ``reps`` timed calls."""
    import jax
    jfn = jax.jit(fn)
    jax.block_until_ready(jfn(*args))          # compile
    for _ in range(warmup):
        jax.block_until_ready(jfn(*args))
    samples = []
    for _ in range(reps):
        t0 = now()
        jax.block_until_ready(jfn(*args))
        samples.append((now() - t0) * 1e6)
    return statistics.median(samples)
