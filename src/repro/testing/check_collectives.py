"""Oracle checks + timings for flat vs two-level vs XLA-native collectives.

Runs under 8 fake CPU devices for a (C, L) factorization of the lane ring
(both 4x2 and 2x4 in CI).  Every variant is checked against a pure-numpy
host oracle (the ``mem_to_reg_host`` discipline); integer payloads must match
*bit for bit* across hierarchies (addition is exact, so any schedule
discrepancy is a routing bug, not roundoff), float64 payloads to 1e-12.

Also emits ``coll/...`` CSV timing rows consumed by ``benchmarks/run.py``.

Usage: XLA_FLAGS=--xla_force_host_platform_device_count=8 \
       python -m repro.testing.check_collectives [C] [L]
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.testing.timing import median_time_us as _time_us
from repro.testing.x64 import x64_mode


def main(C: int = 4, L: int = 2) -> None:
    # float64 payloads scoped to the check: x64_mode restores the flag on
    # exit and asserts nothing inside re-toggled it (import-clean)
    with x64_mode(True):
        _main(C, L)


def _main(C: int = 4, L: int = 2) -> None:
    from repro.core import glsu, ring
    from repro.core.glsu import mem_to_reg_host, n_staged_rounds
    from repro.core.layout import VectorMachineSpec
    from repro.core.machine import make_vector_mesh

    n = C * L
    assert len(jax.devices()) >= n, "need more fake devices"
    spec = VectorMachineSpec(make_vector_mesh(C, L))
    rng = np.random.default_rng(0)
    tag = f"C{C}L{L}"

    # --- staged-network cost model coherence ------------------------------
    assert n_staged_rounds(1) == 0              # 1-lane machine routes nothing
    assert n_staged_rounds(n) == int(np.log2(n))

    # --- reduce_scalar ----------------------------------------------------
    B = 4 * n
    xf = rng.normal(size=(B, C, L))
    xi = rng.integers(-1_000, 1_000, size=(B, C, L))
    jf, ji = jnp.asarray(xf), jnp.asarray(xi, jnp.int64)
    variants = [("flat", dict(mode="ring", hierarchy="flat")),
                ("two-level", dict(mode="ring", hierarchy="two-level")),
                ("xla", dict(mode="xla"))]
    int_results = {}
    for name, kw in variants:
        got = ring.reduce_scalar(spec, jf, "sum", **kw)
        np.testing.assert_allclose(float(got), xf.sum(), rtol=1e-12,
                                   err_msg=f"reduce_scalar/{name}")
        int_results[name] = int(ring.reduce_scalar(spec, ji, "sum", **kw))
        for op, ref in (("max", xf.max()), ("min", xf.min())):
            np.testing.assert_array_equal(
                float(ring.reduce_scalar(spec, jf, op, **kw)), ref,
                err_msg=f"reduce_scalar/{op}/{name}")
        us = _time_us(lambda d, kw=kw: ring.reduce_scalar(spec, d, "sum",
                                                          **kw), jf)
        print(f"coll/reduce/{tag}/{name},{us:.0f},ok")
    assert len(set(int_results.values())) == 1, int_results   # bit-for-sum
    assert int_results["flat"] == int(xi.sum())

    # --- ring_allgather ---------------------------------------------------
    # ``*-db`` rows are the double-buffered schedules (next hop issued
    # before the current block is consumed) — must stay bit-identical
    db_variants = [("flat-db", dict(mode="ring", hierarchy="flat",
                                    schedule="db")),
                   ("two-level-db", dict(mode="ring", hierarchy="two-level",
                                         schedule="db"))]
    shard = rng.normal(size=(n, 6))
    js = jnp.asarray(shard)
    want_ag = np.tile(shard.reshape(-1), (n, 1))
    for name, kw in variants + db_variants:
        got = np.asarray(ring.ring_allgather(spec, js, **kw))
        np.testing.assert_array_equal(got, want_ag,
                                      err_msg=f"ring_allgather/{name}")
        us = _time_us(lambda d, kw=kw: ring.ring_allgather(spec, d, **kw), js)
        print(f"coll/allgather/{tag}/{name},{us:.0f},ok")

    # consumer-interleaved db gather: consume(block, j) runs as each block
    # lands (the shift fetching block j+1 already in flight) — must equal
    # transforming after the gather
    from jax.sharding import PartitionSpec as P

    from repro import substrate

    def _ag_consumed(x):
        out = ring.ring_allgather_local_db(x[0], spec.ring_axes, n,
                                           consume=lambda b, j: 2.0 * b + 1.0)
        return out[None]

    got = substrate.shard_map(_ag_consumed, mesh=spec.mesh,
                              in_specs=(P(spec.ring_axes, None),),
                              out_specs=P(spec.ring_axes, None))(js)
    np.testing.assert_array_equal(np.asarray(got), 2.0 * want_ag + 1.0,
                                  err_msg="ring_allgather_db/consume")

    # --- ring_reduce_scatter ---------------------------------------------
    m = 3
    contrib_f = rng.normal(size=(n, n * m))
    contrib_i = rng.integers(-1_000, 1_000, size=(n, n * m))
    want_rs_f = contrib_f.sum(axis=0).reshape(n, m)
    want_rs_i = contrib_i.sum(axis=0).reshape(n, m)
    jcf = jnp.asarray(contrib_f)
    jci = jnp.asarray(contrib_i, jnp.int64)
    for name, kw in variants + db_variants:
        got = np.asarray(ring.ring_reduce_scatter(spec, jcf, **kw))
        np.testing.assert_allclose(got, want_rs_f, rtol=1e-12,
                                   err_msg=f"ring_reduce_scatter/{name}")
        np.testing.assert_array_equal(
            np.asarray(ring.ring_reduce_scatter(spec, jci, **kw)), want_rs_i,
            err_msg=f"ring_reduce_scatter/int/{name}")   # bit-for-sum
        us = _time_us(lambda d, kw=kw: ring.ring_reduce_scatter(spec, d,
                                                                **kw), jcf)
        print(f"coll/reduce_scatter/{tag}/{name},{us:.0f},ok")

    # --- staged GLSU: two-level Align == flat Align == host byte map ------
    vl = n * n * 3
    x = rng.normal(size=vl)
    jx = jnp.asarray(x)
    want_reg = mem_to_reg_host(x, C, L)
    for hierarchy in ("flat", "two-level"):
        reg = glsu.mem_to_reg(spec, jx, "staged", hierarchy)
        np.testing.assert_array_equal(np.asarray(reg), want_reg,
                                      err_msg=f"mem_to_reg/{hierarchy}")
        back = glsu.reg_to_mem(spec, reg, "staged", hierarchy)
        np.testing.assert_array_equal(np.asarray(back), x,
                                      err_msg=f"reg_to_mem/{hierarchy}")
        us = _time_us(lambda d, h=hierarchy: glsu.mem_to_reg(spec, d,
                                                             "staged", h), jx)
        print(f"coll/glsu_load/{tag}/{hierarchy},{us:.0f},ok")

    print(f"check_collectives OK (C={C}, L={L}, n={n})")


if __name__ == "__main__":
    argv = [int(a) for a in sys.argv[1:]]
    main(*argv)
