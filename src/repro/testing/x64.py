"""Scoped ``jax_enable_x64`` control for the multi-device check modules.

Historically every ``repro.testing.check_*`` module toggled
``jax.config.update("jax_enable_x64", ...)`` at *import* time.  Because the
tier-1 import sweep loads modules in alphabetical order, whichever check
imported last decided the flag for the rest of the process — float64 leaks
in later tests were masked or revealed by import order alone.

:func:`x64_mode` replaces that: the flag is flipped only around the check's
``main`` body, restored on exit (exceptions included), and the context
asserts nothing inside re-toggled it behind its back — so a check module is
import-clean and execution-clean by construction.
"""
from __future__ import annotations

import contextlib

import jax


@contextlib.contextmanager
def x64_mode(enabled: bool):
    """Run the body under ``jax_enable_x64=enabled``; save/restore around it.

    On exit the flag must still hold the value this context set (anything
    else means the body leaked its own toggle — the import-order trap this
    module exists to kill), then the previous value is restored.
    """
    prev = bool(jax.config.jax_enable_x64)
    jax.config.update("jax_enable_x64", enabled)
    try:
        yield
        assert bool(jax.config.jax_enable_x64) == enabled, (
            f"jax_enable_x64 changed to {jax.config.jax_enable_x64} inside "
            f"an x64_mode({enabled}) block — toggle through x64_mode only")
    finally:
        jax.config.update("jax_enable_x64", prev)
