"""The AraXL vector-register-file capacity model, shared by every kernel.

One vreg holds VLEN = 64 Kibit = 8 KiB; an LMUL=8 register group is the
largest single operand the ISA can name (64 KiB), and the whole 32-vreg
VRF bounds the resident working set (256 KiB).  Analysis rule S3 enforces
exactly these two budgets on every traced ``pallas_call``; the kernels'
block clamps and the autotuner's candidate filter mirror them here so
there is a single source of truth.
"""
from __future__ import annotations

VLEN_BITS = 65536
VREG_BYTES = VLEN_BITS // 8          # 8 KiB: one vector register
LMUL_MAX = 8
VREG_GROUP_BYTES = LMUL_MAX * VREG_BYTES   # 64 KiB: one LMUL=8 group
VRF_VREGS = 32
VRF_BYTES = VRF_VREGS * VREG_BYTES         # 256 KiB: whole register file


def clamp_div(b: int, dim: int) -> int:
    """Halve ``b`` until it divides ``dim`` (terminates at 1).

    Halving preserves divisibility for even divisors, so later budget
    clamps that keep halving never re-break the grid.
    """
    b = max(1, min(b, dim))
    while dim % b:
        b //= 2
    return max(b, 1)


def clamp_budget(b: int, bytes_per_unit: int,
                 budget: int = VREG_GROUP_BYTES) -> int:
    """Halve ``b`` until ``b * bytes_per_unit`` fits ``budget``."""
    b = max(b, 1)
    while b > 1 and b * bytes_per_unit > budget:
        b //= 2
    return b
