"""fmatmul — MXU-tiled matmul Pallas kernel (paper Table I, 2*LC FLOP/cycle).

TPU adaptation of the paper's flagship kernel.  AraXL streams B's rows
through 64 scalar-vector FMA lanes; the TPU analogue keeps a ``(bm, bn)``
accumulator tile resident in VMEM (the "VRF") and streams ``(bm, bk) x
(bk, bn)`` operand tiles from HBM through the MXU — same dataflow
(output-stationary, operand streaming), re-blocked for a 128x128 systolic
array instead of 64 scalar FPUs.

Block shapes default to MXU-native multiples of 128; K is the innermost
grid axis so the accumulator revisits the same VMEM tile (sequential grid
dimension on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = False) -> jax.Array:
    """a @ b with f32 accumulation. Shapes must tile by (bm, bn, bk)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2 and M % bm == 0 and N % bn == 0 and K % bk == 0, \
        (a.shape, b.shape, bm, bn, bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
