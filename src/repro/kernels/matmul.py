"""fmatmul — MXU-tiled matmul Pallas kernel (paper Table I, 2*LC FLOP/cycle).

TPU adaptation of the paper's flagship kernel.  AraXL streams B's rows
through 64 scalar-vector FMA lanes; the TPU analogue keeps a ``(bm, bn)``
accumulator tile resident in VMEM (the "VRF") and streams ``(bm, bk) x
(bk, bn)`` operand tiles from HBM through the MXU — same dataflow
(output-stationary, operand streaming), re-blocked for a 128x128 systolic
array instead of 64 scalar FPUs.

Block shapes default to MXU-native multiples of 128; K is the innermost
grid axis so the accumulator revisits the same VMEM tile (sequential grid
dimension on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .vrf import VREG_GROUP_BYTES, VRF_BYTES, clamp_div


def clamp_blocks(M: int, N: int, K: int, bm: int, bn: int, bk: int,
                 itemsize: int) -> tuple[int, int, int]:
    """rmsnorm-style block clamp: halve until the grid divides and every
    buffer fits one LMUL=8 register group (resident set inside the VRF).

    Buffers mirror analysis rule S3's view of the kernel: ``(bm, bk)`` /
    ``(bk, bn)`` operand blocks in the input dtype, a ``(bm, bn)`` output
    block, and the f32 accumulator scratch.  Halving a divisor keeps it a
    divisor, so the budget loop never re-breaks divisibility.
    """
    bm, bn, bk = clamp_div(bm, M), clamp_div(bn, N), clamp_div(bk, K)
    while True:
        a_b, b_b = bm * bk * itemsize, bk * bn * itemsize
        o_b, acc = bm * bn * itemsize, bm * bn * 4
        group_ok = max(a_b, b_b, o_b, acc) <= VREG_GROUP_BYTES
        if group_ok and a_b + b_b + o_b + acc <= VRF_BYTES:
            return bm, bn, bk
        if (a_b > VREG_GROUP_BYTES or b_b > VREG_GROUP_BYTES) and bk > 1:
            bk //= 2
        elif bm >= bn and bm > 1:
            bm //= 2
        elif bn > 1:
            bn //= 2
        elif bk > 1:
            bk //= 2
        else:
            return bm, bn, bk


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
           bk: int = 128, interpret: bool = False) -> jax.Array:
    """a @ b with f32 accumulation.

    ``(bm, bn, bk)`` are ceilings: they are halved until the grid divides
    and the blocks fit the register-group / VRF budgets (see
    :func:`clamp_blocks`), so arbitrary model shapes and autotuner
    candidates are always legal.
    """
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = clamp_blocks(M, N, K, bm, bn, bk, a.dtype.itemsize)
    return pl.pallas_call(
        _mm_kernel,
        grid=(M // bm, N // bn, K // bk),
        in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                  pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
