"""fdotproduct / exp / softmax Pallas kernels (paper Table I).

* ``dotprod`` mirrors AraXL's 4-stage reduction: the SIMD/intra-lane stage is
  the in-block multiply-accumulate, the inter-lane/inter-cluster log-tree is
  the sequential-grid accumulation into a VMEM scalar accumulator (on real
  TPU the cross-chip stages live in `repro.core.ring`, not in-kernel).
* ``expv`` evaluates the paper's range-reduction polynomial explicitly
  (2^k * P(r), degree-6 — the 28-FLOP/element budget of Table I).
* ``softmax_rows`` is a one-pass online-softmax over W blocks per row —
  vfredmax / vexp / vfredsum / vfdiv fused into one VMEM-resident sweep.
* ``combine_partials`` / ``dotprod_hier`` lift the in-kernel intra-lane stage
  to the full machine: per-lane Pallas partials combined in the RINGI
  log-tree order, either over the flattened ring (``hierarchy="flat"``) or
  the paper's two-level intra-cluster -> inter-cluster pipeline
  (``hierarchy="two-level"``, §III-B.4).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# dot product
# ---------------------------------------------------------------------------

def _dot_kernel(a_ref, b_ref, o_ref, acc_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    acc_ref[...] += jnp.sum(a * b, axis=-1, keepdims=True)

    @pl.when(i == pl.num_programs(0) - 1)
    def _flush():
        o_ref[...] = jnp.sum(acc_ref[...]).reshape(1, 1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def dotprod(a: jax.Array, b: jax.Array, *, block: int = 2048,
            interpret: bool = False) -> jax.Array:
    """sum(a*b) over 1-D inputs (length % (8*block) == 0; ops.py pads)."""
    (n,) = a.shape
    rows = 8                                  # sublane-friendly 2-D layout
    assert n % (rows * block) == 0, (n, block)
    a2 = a.reshape(rows, n // rows)
    b2 = b.reshape(rows, n // rows)
    cols = n // rows
    out = pl.pallas_call(
        _dot_kernel,
        grid=(cols // block,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (0, i)),
                  pl.BlockSpec((rows, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((rows, 1), jnp.float32)],
        interpret=interpret,
    )(a2, b2)
    return out[0, 0]


# ---------------------------------------------------------------------------
# exp — explicit range-reduction polynomial (the paper's 28-FLOP budget)
# ---------------------------------------------------------------------------

_LN2 = math.log(2.0)
# degree-6 minimax-ish coefficients for e^r on r in [-ln2/2, ln2/2] (Taylor
# is adequate at f32 for this range)
_EXP_COEFFS = [1 / 720., 1 / 120., 1 / 24., 1 / 6., 0.5, 1.0, 1.0]


def _exp_poly(x):
    """exp(x) = 2**k * P(r),  x = k*ln2 + r,  |r| <= ln2/2."""
    k = jnp.round(x / _LN2)
    r = x - k * _LN2
    p = jnp.full_like(r, _EXP_COEFFS[0])
    for c in _EXP_COEFFS[1:]:                  # 6 FMAs (Horner)
        p = p * r + c
    return jnp.ldexp(p, k.astype(jnp.int32))


def _exp_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    x = jnp.clip(x, -80.0, 80.0)               # the kernel's mask/merge guard
    o_ref[...] = _exp_poly(x).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def expv(x: jax.Array, *, block: int = 2048, interpret: bool = False) -> jax.Array:
    (n,) = x.shape
    rows = 8
    assert n % (rows * block) == 0, (n, block)
    x2 = x.reshape(rows, n // rows)
    out = pl.pallas_call(
        _exp_kernel,
        grid=(x2.shape[1] // block,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (0, i))],
        out_specs=pl.BlockSpec((rows, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2)
    return out.reshape(n)


# ---------------------------------------------------------------------------
# softmax — fused online one-pass over W blocks
# ---------------------------------------------------------------------------

def _softmax_kernel(x_ref, o_ref, m_ref, d_ref):
    """Grid = (rows/bm, W/bw) with W innermost; two sweeps fused by the
    revisiting output trick: pass 1 accumulates (m, d) online; the rescale
    happens when the row's last block is processed, revisiting o_ref blocks
    would need a second pass — instead we keep the row resident: bw == W
    (one block per row stripe), so this kernel requires W <= block budget;
    the ops wrapper falls back to the two-pass ref for larger W."""
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    d = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (e / d).astype(o_ref.dtype)
    m_ref[...] = m
    d_ref[...] = d


# ---------------------------------------------------------------------------
# hierarchical partial combine (the machine-level log-tree, host/XLA side)
# ---------------------------------------------------------------------------

def _pairwise_tree(v: jax.Array, op) -> jax.Array:
    """Binary-tree reduce along axis 0 in the fixed pairing order of the
    recursive-doubling hardware stages (odd stragglers fold in next round)."""
    while v.shape[0] > 1:
        if v.shape[0] % 2:
            tail, v = v[-1:], v[:-1]
            v = op(v[0::2], v[1::2])
            v = jnp.concatenate([v, tail], axis=0)
        else:
            v = op(v[0::2], v[1::2])
    return v[0]


def combine_partials(partials: jax.Array, C: int, L: int,
                     hierarchy: str = "two-level", op=jnp.add) -> jax.Array:
    """Combine the (C*L, ...) per-lane partials in the RINGI log-tree order.

    ``hierarchy="two-level"``: log2(L) intra-cluster stages then log2(C)
    inter-cluster stages, exactly the paper's reduction schedule;
    ``hierarchy="flat"``: one log2(C*L) tree over the flattened ring.  Both
    return the same value for exact ops; for floats they fix the two
    summation orders the §Perf ablation compares.
    """
    p = jnp.asarray(partials)
    n = C * L
    assert p.shape[0] == n, (p.shape, C, L)
    if hierarchy == "two-level":
        per_cluster = p.reshape((C, L) + p.shape[1:])
        intra = jax.vmap(lambda row: _pairwise_tree(row, op))(per_cluster)
        return _pairwise_tree(intra, op)
    if hierarchy == "flat":
        return _pairwise_tree(p, op)
    raise ValueError(f"unknown hierarchy {hierarchy!r}")


def dotprod_hier(a: jax.Array, b: jax.Array, *, C: int, L: int,
                 block: int = 2048, hierarchy: str = "two-level",
                 interpret: bool = False) -> jax.Array:
    """fdotproduct as the paper's full 4-stage pipeline: each of the C*L
    lanes runs the Pallas ``dotprod`` kernel over its contiguous slice
    (SIMD/intra-lane stage), and the scalar partials ride the
    inter-lane/inter-cluster log-tree via :func:`combine_partials`."""
    (N,) = a.shape
    n = C * L
    assert N % n == 0, (N, n)
    parts = jnp.stack([
        dotprod(a[i * (N // n):(i + 1) * (N // n)],
                b[i * (N // n):(i + 1) * (N // n)],
                block=block, interpret=interpret)
        for i in range(n)])
    return combine_partials(parts, C, L, hierarchy)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def softmax_rows(x: jax.Array, *, bm: int = 8, interpret: bool = False):
    """Row softmax for (R, W); whole row resident per block (long-vector
    style: the row is the vector register)."""
    R, W = x.shape
    assert R % bm == 0, (x.shape, bm)
    out, _, _ = pl.pallas_call(
        _softmax_kernel,
        grid=(R // bm,),
        in_specs=[pl.BlockSpec((bm, W), lambda i: (i, 0))],
        out_specs=(pl.BlockSpec((bm, W), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0)),
                   pl.BlockSpec((bm, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((R, W), x.dtype),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32),
                   jax.ShapeDtypeStruct((R, 1), jnp.float32)),
        interpret=interpret,
    )(x)
    return out
