"""Flash attention (causal/SWA, GQA) — the LM hot-spot kernel.

The long-vector connection: online softmax over KV blocks is AraXL's
stripmined vfredmax/vexp/vfredsum pipeline with the running (m, l) carried in
"VRF" (VMEM scratch) instead of re-reading scores — the same
latency-tolerant streaming the paper exploits, re-tiled for MXU matmuls.

Layout: q (B, Hq, S, D), k/v (B, Hkv, S, D), GQA mapped by h_kv = h_q //
(Hq // Hkv) in the index maps.  Grid = (B*Hq, S/bq, S/bk) with the KV axis
innermost (sequential); causal and sliding-window masking prune nothing at
the grid level in interpret mode but the masks are exact.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .vrf import VREG_GROUP_BYTES, VRF_BYTES, clamp_div

NEG_INF = -1e30


def clamp_blocks(S: int, Sk: int, D: int, bq: int, bk: int,
                 itemsize: int) -> tuple[int, int]:
    """rmsnorm-style clamp for the attention block args: halve ``bq``/``bk``
    until they divide S/Sk and the S3 buffers — q/o blocks plus the f32
    accumulator on the q side, k/v blocks on the kv side — fit one LMUL=8
    register group with the resident set inside the VRF."""
    bq, bk = clamp_div(bq, S), clamp_div(bk, Sk)
    while bq > 1 and max(bq * D * itemsize, bq * D * 4) > VREG_GROUP_BYTES:
        bq //= 2
    while bk > 1 and bk * D * itemsize > VREG_GROUP_BYTES:
        bk //= 2
    def resident(bq, bk):
        return (2 * bq * D * itemsize + 2 * bk * D * itemsize
                + bq * D * 4 + 2 * bq * 4)
    while resident(bq, bk) > VRF_BYTES and (bq > 1 or bk > 1):
        if bq >= bk and bq > 1:
            bq //= 2
        else:
            bk //= 2
    return bq, bk


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 scale: float, causal: bool, window: int | None,
                 bq: int, bk: int):
    kv_i = pl.program_id(2)
    q_i = pl.program_id(1)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                     # (bq, D)
    k = k_ref[0].astype(jnp.float32)                     # (bk, D)
    v = v_ref[0].astype(jnp.float32)                     # (bk, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = q_i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                                  # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_i == pl.num_programs(2) - 1)
    def _flush():
        # fully-masked rows (prefix of a window) produce l == 0 -> emit 0
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q (B,Hq,S,D), k/v (B,Hkv,S,D) -> (B,Hq,S,D).

    ``bq``/``bk`` are ceilings, halved until they divide S/Sk and fit the
    register-group budget (see :func:`clamp_blocks`).
    """
    B, Hq, S, D = q.shape
    _, Hkv, Sk, _ = k.shape
    bq, bk = clamp_blocks(S, Sk, D, bq, bk, q.dtype.itemsize)
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qf = q.reshape(B * Hq, S, D)
    kf = k.reshape(B * Hkv, Sk, D)
    vf = v.reshape(B * Hkv, Sk, D)

    def kv_map(h, i, j):
        return (h // group, j, 0)

    kernel = functools.partial(_attn_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk)
    out = pl.pallas_call(
        kernel,
        grid=(B * Hq, S // bq, Sk // bk),
        in_specs=[pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
                  pl.BlockSpec((1, bk, D), kv_map),
                  pl.BlockSpec((1, bk, D), kv_map)],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, D), jnp.float32)],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, Hq, S, D)
