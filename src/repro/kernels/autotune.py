"""Model-guided block-shape autotuner — the sim <-> kernel loop, closed.

AraXL's headline efficiency comes from matching blocking to the machine:
register-group capacity, lane count and wire level decide the winning
tile.  This module connects the repo's two halves of that story: the
calibrated sim (`repro.sim`) *prices* a candidate tiling, the Pallas
kernel library *runs* it.  Per problem signature
``(kernel, shape, dtype, topology_tag)``:

1. **enumerate** legal block-shape candidates — power-of-two divisors of
   the grid, filtered by the S3 VRF budget (every buffer fits one LMUL=8
   register group, the resident set fits the 32-vreg VRF; see
   `repro.kernels.vrf`);
2. **rank** them with the sim cost model — a representative register-group
   strip replayed through `sim.kernels` traces, scaled to the full grid,
   plus a per-grid-step dispatch charge (`glsu_lat` + `issue_gap`) and the
   HBM stream priced at the innermost `Topology.wire_bw` level;
3. **measure** only the model's top-k shortlist with
   `repro.testing.timing.measure_us` (median + IQR; noisy ranks are
   re-measured, not cached);
4. **cache** the winner in a persistent JSON table that the `kernels.ops`
   wrappers consult ambiently (the ctx-driven config plumbing idiom), so
   `launch.train` / `launch.perf` / `serve` pick up tuned blocks with
   zero call-site churn.

The model-predicted vs measured rank table is recorded into
``BENCH_kernels.json`` by ``python -m benchmarks.run kernels`` — an
ongoing calibration test of the sim against the kernels it prices.
"""
from __future__ import annotations

import argparse
import contextlib
import functools
import json
import os
import pathlib

from .vrf import VREG_GROUP_BYTES, VRF_BYTES

#: the tunable kernel families and their static block defaults (what the
#: ops wrappers fall back to when no tuned entry exists)
DEFAULTS: dict[str, dict[str, int]] = {
    "matmul": {"bm": 128, "bn": 128, "bk": 128},
    "flash_attention": {"bq": 128, "bk": 128},
    "paged_attention": {"bt": 16},
    "rmsnorm": {"bm": 8},
    "reduction": {"block": 2048},
    "stencil": {"bh": 8, "bw": 256},
}
KERNELS = tuple(DEFAULTS)

#: problem-shape conventions, documented once:
#:   matmul           (M, K, N)
#:   flash_attention  (B, Hq, Hkv, S, Sk, D)
#:   paged_attention  (B, Hq, Hkv, T, D)  — T = max tokens (nblk * bt)
#:   rmsnorm          (R, D)
#:   reduction        (n,)
#:   stencil          (H, W)  — interior grid, before halo padding

_ITEMSIZE = {"float32": 4, "bfloat16": 2, "float16": 2, "float64": 8}


def _itemsize(dtype: str) -> int:
    return _ITEMSIZE.get(str(dtype), 4)


def signature(kernel: str, shape, dtype: str, topology_tag: str) -> str:
    return "|".join((kernel, "x".join(str(int(s)) for s in shape),
                     str(dtype), topology_tag))


# ---------------------------------------------------------------- candidates

def _pow2_divisors(dim: int, lo: int, hi: int) -> list[int]:
    out, b = [], 1
    while b <= min(dim, hi):
        if b >= lo and dim % b == 0:
            out.append(b)
        b *= 2
    return out or [max(1, min(lo, dim))]


def candidate_buffers(kernel: str, shape, dtype: str, cfg: dict
                      ) -> list[tuple[str, int]]:
    """The S3 view of one candidate: (buffer label, resident bytes) for
    every operand/output block and scratch the pallas_call would hold."""
    isz = _itemsize(dtype)
    if kernel == "matmul":
        bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
        return [("a", bm * bk * isz), ("b", bk * bn * isz),
                ("out", bm * bn * isz), ("acc", bm * bn * 4)]
    if kernel == "flash_attention":
        D = shape[5]
        bq, bk = cfg["bq"], cfg["bk"]
        return [("q", bq * D * isz), ("k", bk * D * isz),
                ("v", bk * D * isz), ("out", bq * D * isz),
                ("m", bq * 4), ("l", bq * 4), ("acc", bq * D * 4)]
    if kernel == "paged_attention":
        _, Hq, Hkv, _, D = shape
        gq = Hq // Hkv
        bt = cfg["bt"]
        return [("q", gq * D * isz), ("k", bt * D * isz),
                ("v", bt * D * isz), ("out", gq * D * isz),
                ("m", gq * 4), ("l", gq * 4), ("acc", gq * D * 4)]
    if kernel == "rmsnorm":
        D = shape[1]
        bm = cfg["bm"]
        return [("x", bm * D * isz), ("gamma", D * isz),
                ("out", bm * D * isz)]
    if kernel == "reduction":
        block = cfg["block"]
        return [("a", 8 * block * isz), ("b", 8 * block * isz),
                ("out", 8 * 4), ("acc", 8 * 4)]
    if kernel == "stencil":
        bh, bw = cfg["bh"], cfg["bw"]
        return [("halo", (bh + 2) * (bw + 2) * isz), ("out", bh * bw * isz)]
    raise ValueError(f"unknown kernel {kernel!r}")


def is_legal(kernel: str, shape, dtype: str, cfg: dict) -> bool:
    bufs = candidate_buffers(kernel, shape, dtype, cfg)
    return (max(b for _, b in bufs) <= VREG_GROUP_BYTES
            and sum(b for _, b in bufs) <= VRF_BYTES)


def grid_steps(kernel: str, shape, cfg: dict) -> int:
    if kernel == "matmul":
        M, K, N = shape
        return (M // cfg["bm"]) * (N // cfg["bn"]) * (K // cfg["bk"])
    if kernel == "flash_attention":
        B, Hq, _, S, Sk, _ = shape
        return B * Hq * (S // cfg["bq"]) * (Sk // cfg["bk"])
    if kernel == "paged_attention":
        B, _, Hkv, T, _ = shape
        return B * Hkv * (T // cfg["bt"])
    if kernel == "rmsnorm":
        return shape[0] // cfg["bm"]
    if kernel == "reduction":
        return shape[0] // (8 * cfg["block"])
    if kernel == "stencil":
        H, W = shape
        return (H // cfg["bh"]) * (W // cfg["bw"])
    raise ValueError(f"unknown kernel {kernel!r}")


def enumerate_candidates(kernel: str, shape, dtype: str = "float32", *,
                         min_block: int | None = None,
                         max_candidates: int = 32) -> list[dict]:
    """Legal block-shape candidates: power-of-two divisors of the grid
    dims that pass the register-group / VRF budget.  When the space
    outgrows ``max_candidates`` the fewest-grid-steps candidates are kept
    (the rest are strictly dispatch-dominated under the cost model)."""
    if kernel == "matmul":
        M, K, N = shape
        lo = min_block or 32
        cands = [{"bm": bm, "bn": bn, "bk": bk}
                 for bm in _pow2_divisors(M, lo, 256)
                 for bn in _pow2_divisors(N, lo, 256)
                 for bk in _pow2_divisors(K, lo, 256)]
    elif kernel == "flash_attention":
        _, _, _, S, Sk, _ = shape
        lo = min_block or 32
        cands = [{"bq": bq, "bk": bk}
                 for bq in _pow2_divisors(S, lo, 256)
                 for bk in _pow2_divisors(Sk, lo, 256)]
    elif kernel == "paged_attention":
        T = shape[3]
        lo = min_block or 8
        cands = [{"bt": bt} for bt in _pow2_divisors(T, lo, 256)]
    elif kernel == "rmsnorm":
        R = shape[0]
        cands = [{"bm": bm} for bm in _pow2_divisors(R, 1, 64)]
    elif kernel == "reduction":
        n = shape[0]
        lo = min_block or 256
        cands = [{"block": b} for b in _pow2_divisors(n // 8, lo, 4096)
                 if n % (8 * b) == 0]
    elif kernel == "stencil":
        H, W = shape
        lo = min_block or 32
        cands = [{"bh": bh, "bw": bw}
                 for bh in _pow2_divisors(H, 2, 32)
                 for bw in _pow2_divisors(W, lo, 512)]
    else:
        raise ValueError(f"unknown kernel {kernel!r}")
    cands = [c for c in cands if is_legal(kernel, shape, dtype, c)]
    cands.sort(key=lambda c: (grid_steps(kernel, shape, c),
                              sorted(c.items())))
    return cands[:max_candidates]


# ---------------------------------------------------------------- cost model

_SIM_CACHE: dict[tuple, float] = {}


def _default_params():
    from repro.sim import araxl_params
    return araxl_params(64)


def _bpl(params, n: int) -> int:
    """bytes_per_lane for an ``n``-element row (`sim.kernels._vl` inverse)."""
    return max(1, int(n) * (params.sew_bits // 8) // params.n_lanes)


def _sim_cycles(params, kernel: str, bpl: int, **kw) -> float:
    key = (kernel, bpl, tuple(sorted(kw.items())),
           params.n_lanes, params.lanes_per_cluster, params.vlen_bits)
    if key not in _SIM_CACHE:
        from repro.sim import build_trace, simulate
        _SIM_CACHE[key] = simulate(
            build_trace(kernel, params, bpl, **kw), params).cycles
    return _SIM_CACHE[key]


def model_cost(kernel: str, shape, dtype: str, cfg: dict, *,
               params=None) -> dict:
    """Price one candidate: a representative LMUL=8 strip replayed through
    the sim, scaled to the full grid, plus per-grid-step dispatch
    (`glsu_lat` + `issue_gap`) and the HBM stream at the innermost
    `Topology.wire_bw`.  Returns the µs breakdown."""
    p = params or _default_params()
    isz = _itemsize(dtype)
    G = grid_steps(kernel, shape, cfg)

    if kernel == "matmul":
        M, K, N = shape
        bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
        strip = min(bm, 8)
        c_strip = _sim_cycles(p, "fmatmul", _bpl(p, bn),
                              M=strip, K=bk, rows_blk=strip)
        compute = c_strip * (bm / strip) * G
        stream_bytes = G * (bm * bk + bk * bn) * isz + M * N * isz
    elif kernel == "flash_attention":
        B, Hq, _, S, Sk, D = shape
        bq, bk = cfg["bq"], cfg["bk"]
        strip = min(bq, 8)
        c_strip = (_sim_cycles(p, "fmatmul", _bpl(p, bk),
                               M=strip, K=D, rows_blk=strip)
                   + _sim_cycles(p, "softmax", _bpl(p, bk), rows=strip)
                   + _sim_cycles(p, "fmatmul", _bpl(p, D),
                                 M=strip, K=bk, rows_blk=strip))
        compute = c_strip * (bq / strip) * G
        stream_bytes = G * (bq * D + 2 * bk * D) * isz + B * Hq * S * D * isz
    elif kernel == "paged_attention":
        B, Hq, Hkv, T, D = shape
        bt = cfg["bt"]
        gq = Hq // Hkv
        strip = min(gq, 8)
        # one block's score/softmax/weighted-sum strip, like flash_attention
        # but with a single q row group per grid step (decode: one token)
        c_strip = (_sim_cycles(p, "fmatmul", _bpl(p, bt),
                               M=strip, K=D, rows_blk=strip)
                   + _sim_cycles(p, "softmax", _bpl(p, bt), rows=strip)
                   + _sim_cycles(p, "fmatmul", _bpl(p, D),
                                 M=strip, K=bt, rows_blk=strip))
        compute = c_strip * (gq / strip) * G
        # each grid step streams one gathered K/V block; q/out ride once
        stream_bytes = G * 2 * bt * D * isz + 2 * B * Hq * D * isz
    elif kernel == "rmsnorm":
        R, D = shape
        bm = cfg["bm"]
        strip = min(bm, 8)
        c_strip = _sim_cycles(p, "softmax", _bpl(p, D), rows=strip)
        compute = c_strip * (bm / strip) * G
        # gamma is re-streamed every grid step: small blocks pay for it
        stream_bytes = 2 * R * D * isz + G * D * isz
    elif kernel == "reduction":
        block = cfg["block"]
        c_strip = _sim_cycles(p, "fdotproduct", block)
        compute = c_strip * G
        stream_bytes = 2 * shape[0] * isz + G * 8 * 4
    elif kernel == "stencil":
        H, W = shape
        bh, bw = cfg["bh"], cfg["bw"]
        c_tile = _sim_cycles(p, "jacobi2d", _bpl(p, bw), rows=bh + 2)
        compute = c_tile * G
        # the halo rows/cols are re-read by every neighbouring tile
        stream_bytes = G * (bh + 2) * (bw + 2) * isz + H * W * isz
    else:
        raise ValueError(f"unknown kernel {kernel!r}")

    dispatch = G * (p.glsu_lat + p.issue_gap)
    cycles_to_us = 1.0 / (p.freq_ghz * 1e3)
    wire_bw = p.topology.wire_bw(p.topology.wire_labels()[-1])
    wire_us = stream_bytes / wire_bw * 1e6
    return {
        "compute_us": compute * cycles_to_us,
        "dispatch_us": dispatch * cycles_to_us,
        "wire_us": wire_us,
        "us": (compute + dispatch) * cycles_to_us + wire_us,
    }


def model_cost_us(kernel: str, shape, dtype: str, cfg: dict, *,
                  params=None) -> float:
    return model_cost(kernel, shape, dtype, cfg, params=params)["us"]


def rank_candidates(kernel: str, shape, dtype: str, cands, *,
                    params=None) -> list[tuple[dict, float]]:
    """Model-ranked (config, predicted µs), cheapest first; ties broken by
    config so the order is deterministic."""
    priced = [(c, model_cost_us(kernel, shape, dtype, c, params=params))
              for c in cands]
    priced.sort(key=lambda cu: (cu[1], sorted(cu[0].items())))
    return priced


# ---------------------------------------------------------------- measurement

def _measure_case(kernel: str, shape, dtype: str, cfg: dict):
    """(fn, args) for `timing.measure_us`: the interpret-mode (off-TPU)
    Pallas kernel with the candidate blocks bound statically."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    interpret = jax.devices()[0].platform != "tpu"
    rng = np.random.default_rng(0)
    jdt = jnp.dtype(dtype)

    def arr(*s):
        return jnp.asarray(rng.standard_normal(s), dtype=jdt)

    if kernel == "matmul":
        from . import matmul as _mm
        M, K, N = shape
        fn = functools.partial(_mm.matmul, interpret=interpret, **cfg)
        return fn, (arr(M, K), arr(K, N))
    if kernel == "flash_attention":
        from . import flash_attention as _fa
        B, Hq, Hkv, S, Sk, D = shape
        fn = functools.partial(_fa.flash_attention, causal=True,
                               interpret=interpret, **cfg)
        return fn, (arr(B, Hq, S, D), arr(B, Hkv, Sk, D), arr(B, Hkv, Sk, D))
    if kernel == "paged_attention":
        from . import paged_attention as _pa
        B, Hq, Hkv, T, D = shape
        bt = cfg["bt"]          # baked into the pool layout, not a kwarg
        gq, nblk = Hq // Hkv, T // bt
        kpool = arr(Hkv, B * nblk + 1, bt, D)
        vpool = arr(Hkv, B * nblk + 1, bt, D)
        tables = jnp.arange(1, B * nblk + 1, dtype=jnp.int32) \
            .reshape(B, nblk)   # disjoint full tables, block 0 reserved
        lens = jnp.full((B,), T, jnp.int32)
        fn = functools.partial(_pa.paged_attention, interpret=interpret)
        return fn, (arr(B, Hkv, gq, D), kpool, vpool, tables, lens)
    if kernel == "rmsnorm":
        from . import rmsnorm as _rms
        R, D = shape
        fn = functools.partial(_rms.rmsnorm, interpret=interpret, **cfg)
        return fn, (arr(R, D), arr(D))
    if kernel == "reduction":
        from . import reduction as _red
        n = shape[0]
        fn = functools.partial(_red.dotprod, interpret=interpret, **cfg)
        return fn, (arr(n), arr(n))
    if kernel == "stencil":
        from . import stencil as _st
        H, W = shape
        fn = functools.partial(_st.jacobi2d, interpret=interpret, **cfg)
        return fn, (arr(H + 2, W + 2),)
    raise ValueError(f"unknown kernel {kernel!r}")


def measure_candidate(kernel: str, shape, dtype: str, cfg: dict, *,
                      reps: int = 5, warmup: int = 1):
    """One `timing.Sample` for a candidate; a noisy sample (IQR above half
    the median) is re-measured once at double reps rather than trusted."""
    from repro.testing import timing
    fn, args = _measure_case(kernel, shape, dtype, cfg)
    s = timing.measure_us(fn, *args, reps=reps, warmup=warmup)
    if s.reps >= 2 and s.iqr_us > 0.5 * s.median_us:
        s = timing.measure_us(fn, *args, reps=2 * reps, warmup=warmup)
    return s


# ---------------------------------------------------------------- context

def _default_cache_path() -> pathlib.Path:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return pathlib.Path(env)
    root = pathlib.Path(__file__).resolve().parents[3]
    return root / "results" / "autotune" / "cache.json"


class TuneContext:
    """Ambient autotuning state: the persistent winner table plus the
    measurement policy.  Installed with :func:`tuned`; the innermost
    context wins (the olmax ctx-plumbing idiom — config travels ambiently,
    call sites stay clean)."""

    def __init__(self, cache_path=None, *, params=None, top_k: int = 3,
                 reps: int = 5, warmup: int = 1,
                 min_block: int | None = None):
        self.cache_path = pathlib.Path(cache_path) if cache_path \
            else _default_cache_path()
        self._params = params
        self.top_k = top_k
        self.reps = reps
        self.warmup = warmup
        self.min_block = min_block
        self._table = None

    @property
    def params(self):
        if self._params is None:
            self._params = _default_params()
        return self._params

    @property
    def topology_tag(self) -> str:
        return "x".join(str(s) for s in self.params.topology.shape)

    @property
    def table(self) -> dict:
        if self._table is None:
            self._table = {}
            try:
                doc = json.loads(self.cache_path.read_text())
                if isinstance(doc, dict):
                    self._table = dict(doc.get("entries", {}))
            except (OSError, ValueError):
                pass
        return self._table

    def save(self) -> None:
        self.cache_path.parent.mkdir(parents=True, exist_ok=True)
        self.cache_path.write_text(
            json.dumps({"schema": 1, "entries": self.table},
                       indent=1, sort_keys=True))

    def lookup(self, kernel: str, shape, dtype: str) -> dict | None:
        """The cached winner config for a signature, or None."""
        sig = signature(kernel, shape, dtype, self.topology_tag)
        rec = self.table.get(sig)
        if isinstance(rec, dict) and isinstance(rec.get("winner"), dict):
            return dict(rec["winner"])
        return None


_STACK: list[TuneContext] = [TuneContext()]


def current() -> TuneContext:
    return _STACK[-1]


@contextlib.contextmanager
def tuned(cache_path=None, **kw):
    """Install a :class:`TuneContext` for the dynamic extent — every
    `kernels.ops` call (and `autotune`) inside resolves against it."""
    ctx = cache_path if isinstance(cache_path, TuneContext) \
        else TuneContext(cache_path, **kw)
    _STACK.append(ctx)
    try:
        yield ctx
    finally:
        _STACK.pop()


def tuned_config(kernel: str, shape, dtype: str) -> dict | None:
    """The ops-wrapper fast path: the ambient context's cached winner for
    this problem signature (never measures, never raises)."""
    try:
        return current().lookup(kernel, shape, str(dtype))
    except Exception:
        return None


# ---------------------------------------------------------------- autotune

def autotune(kernel: str, shape, dtype: str = "float32", *, ctx=None,
             measure_all: bool = False, min_block: int | None = None) -> dict:
    """Enumerate → model-rank → measure the top-k shortlist → cache.

    Returns (and persists) the record: every candidate with its model
    rank, the measured median+IQR for the shortlist, the winner, and
    whether the model's top-k contained it (``agreement_at_k``).  A cached
    signature short-circuits without re-measuring unless ``measure_all``
    asks for the full calibration table.
    """
    ctx = ctx or current()
    shape = tuple(int(s) for s in shape)
    sig = signature(kernel, shape, dtype, ctx.topology_tag)
    cached = ctx.table.get(sig)
    if cached is not None and not measure_all:
        return cached

    mb = min_block if min_block is not None else ctx.min_block
    cands = enumerate_candidates(kernel, shape, dtype, min_block=mb)
    ranked = rank_candidates(kernel, shape, dtype, cands, params=ctx.params)
    n_measure = len(ranked) if measure_all else min(ctx.top_k, len(ranked))

    entries = []
    for rank, (cfg, mus) in enumerate(ranked):
        e = {"config": cfg, "model_us": round(mus, 3), "model_rank": rank}
        if rank < n_measure:
            s = measure_candidate(kernel, shape, dtype, cfg,
                                  reps=ctx.reps, warmup=ctx.warmup)
            e.update(measured_us=round(s.median_us, 3),
                     iqr_us=round(s.iqr_us, 3), reps=s.reps)
        entries.append(e)

    measured = [e for e in entries if "measured_us" in e]
    measured.sort(key=lambda e: (e["measured_us"], e["model_rank"]))
    for mrank, e in enumerate(measured):
        e["measured_rank"] = mrank
    win = measured[0]
    record = {
        "kernel": kernel,
        "shape": list(shape),
        "dtype": str(dtype),
        "topology": ctx.topology_tag,
        "top_k": ctx.top_k,
        "candidates": entries,
        "winner": dict(win["config"]),
        "model_rank_of_winner": win["model_rank"],
        "agreement_at_k": win["model_rank"] < ctx.top_k,
    }
    ctx.table[sig] = record
    ctx.save()
    return record


# ---------------------------------------------------------------- CLI

#: moderate default shapes per kernel; --smoke swaps in the tiny set
CASES = {
    "matmul": [(128, 128, 128), (256, 256, 128)],
    "flash_attention": [(1, 2, 1, 128, 128, 64), (1, 2, 1, 256, 256, 64)],
    "paged_attention": [(1, 4, 2, 128, 64), (1, 4, 2, 256, 64)],
    "rmsnorm": [(64, 1024), (64, 4096)],
    "reduction": [(65536,), (262144,)],
    "stencil": [(64, 256), (128, 512)],
}
SMOKE_CASES = {
    "matmul": [(64, 64, 64)],
    "flash_attention": [(1, 2, 1, 64, 64, 32)],
    "paged_attention": [(1, 4, 2, 64, 32)],
    "rmsnorm": [(16, 256)],
    "reduction": [(16384,)],
    "stencil": [(16, 128)],
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.kernels.autotune",
        description="model-rank -> measure-shortlist -> cache kernel blocks")
    ap.add_argument("--kernel", action="append", choices=KERNELS,
                    help="kernel family (repeatable; default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes (the CI end-to-end loop)")
    ap.add_argument("--top-k", type=int, default=3)
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--min-block", type=int, default=None)
    ap.add_argument("--cache", type=pathlib.Path, default=None,
                    help="winner-table path (default results/autotune/)")
    args = ap.parse_args(argv)

    cases = SMOKE_CASES if args.smoke else CASES
    kernels = args.kernel or list(KERNELS)
    min_block = args.min_block if args.min_block is not None \
        else (32 if args.smoke else None)
    with tuned(args.cache, top_k=args.top_k, reps=args.reps,
               warmup=args.warmup, min_block=min_block) as ctx:
        for kernel in kernels:
            for shape in cases[kernel]:
                rec = autotune(kernel, shape, ctx=ctx)
                win = next(e for e in rec["candidates"]
                           if e["config"] == rec["winner"]
                           and "measured_us" in e)
                sig = signature(kernel, shape, "float32", ctx.topology_tag)
                print(f"autotune/{sig},{win['measured_us']:.1f},"
                      f"winner={rec['winner']} "
                      f"model_rank={rec['model_rank_of_winner']} "
                      f"agree@{rec['top_k']}={rec['agreement_at_k']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
