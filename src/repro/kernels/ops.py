"""Jit'd public wrappers: pick the Pallas kernel on TPU, the jnp reference
elsewhere (the CPU dry-run lowers the jnp path; interpret=True is for tests).

Wrappers also normalise shapes (padding to block multiples) so callers never
see tiling constraints, and resolve block shapes against the ambient
autotune winner table (`kernels.autotune`): an explicit caller arg wins,
then the tuned config for the problem signature, then the static default —
so `launch.train` / `launch.perf` / `serve` pick up tuned blocks with zero
call-site churn.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import autotune as _at
from . import flash_attention as _fa
from . import matmul as _mm
from . import paged_attention as _pa
from . import reduction as _red
from . import ref
from . import rmsnorm as _rms
from . import stencil as _st


def _on_tpu() -> bool:
    return jax.devices()[0].platform == "tpu"


def _resolve(kernel, shape, dtype, **given):
    """Block-arg resolution: explicit args win, then the ambient autotune
    table, then `autotune.DEFAULTS`."""
    defaults = _at.DEFAULTS[kernel]
    if any(v is None for v in given.values()):
        cfg = _at.tuned_config(kernel, shape, str(dtype)) or {}
        given = {k: (v if v is not None else cfg.get(k, defaults[k]))
                 for k, v in given.items()}
    return {k: int(v) for k, v in given.items()}


def _mode(use_pallas):
    """use_pallas: None=auto (TPU only), True=pallas (interpret off-TPU),
    False=reference."""
    if use_pallas is None:
        return "pallas" if _on_tpu() else "ref"
    if use_pallas and not _on_tpu():
        return "interpret"
    return "pallas" if use_pallas else "ref"


def _pad_to(x, mult, axis):
    r = (-x.shape[axis]) % mult
    if r == 0:
        return x, 0
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, r)
    return jnp.pad(x, pad), r


def matmul(a, b, *, use_pallas=None, bm=None, bn=None, bk=None):
    m = _mode(use_pallas)
    if m == "ref":
        return ref.matmul(a, b)
    cfg = _resolve("matmul", (a.shape[0], a.shape[1], b.shape[1]), a.dtype,
                   bm=bm, bn=bn, bk=bk)
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    a, pm = _pad_to(a, bm, 0)
    a, pk = _pad_to(a, bk, 1)
    b, _ = _pad_to(b, bk, 0)
    b, pn = _pad_to(b, bn, 1)
    out = _mm.matmul(a, b, bm=bm, bn=bn, bk=bk, interpret=(m == "interpret"))
    return out[:out.shape[0] - pm or None, :out.shape[1] - pn or None] \
        if (pm or pn) else out


def jacobi2d(x, *, use_pallas=None, bh=None, bw=None):
    """x (H, W) unpadded; zero boundary (one sweep over the interior grid)."""
    xp = jnp.pad(x, 1)
    m = _mode(use_pallas)
    if m == "ref":
        return ref.jacobi2d(xp)
    H, W = x.shape
    cfg = _resolve("stencil", (H, W), x.dtype, bh=bh, bw=bw)
    bh, bw = cfg["bh"], cfg["bw"]
    bh = min(bh, H) if H % bh else bh
    while H % bh:
        bh -= 1
    bw_ = bw
    while W % bw_:
        bw_ //= 2
    bw_ = max(bw_, 1)
    return _st.jacobi2d(xp, bh=bh, bw=bw_, interpret=(m == "interpret"))


def fconv2d(x, filt, *, use_pallas=None, bh=None, bw=None):
    """valid conv: x (H, W), filt (fr, fc) -> (H-fr+1, W-fc+1)."""
    fr, fc = filt.shape
    m = _mode(use_pallas)
    if m == "ref":
        return ref.fconv2d(x, filt)
    H, W = x.shape[0] - fr + 1, x.shape[1] - fc + 1
    cfg = _resolve("stencil", (H, W), x.dtype, bh=bh, bw=bw)
    bh, bw = cfg["bh"], cfg["bw"]
    while H % bh:
        bh -= 1
    bw_ = bw
    while W % bw_ and bw_ > 1:
        bw_ -= 1
    return _st.fconv2d(x, filt, fr=fr, fc=fc, bh=bh, bw=bw_,
                       interpret=(m == "interpret"))


def dotprod(a, b, *, use_pallas=None, block=None):
    m = _mode(use_pallas)
    if m == "ref":
        return ref.dotprod(a, b)
    block = _resolve("reduction", (a.shape[0],), a.dtype,
                     block=block)["block"]
    quantum = 8 * block
    a, _ = _pad_to(a, quantum, 0)
    b, _ = _pad_to(b, quantum, 0)
    return _red.dotprod(a, b, block=block, interpret=(m == "interpret"))


def dotprod_hier(a, b, *, C, L, hierarchy="two-level", use_pallas=None,
                 block=256):
    """fdotproduct through the machine-level log-tree: per-lane Pallas
    partials combined intra-cluster then inter-cluster (or over the
    flattened ring with hierarchy="flat")."""
    m = _mode(use_pallas)
    if m == "ref":
        return ref.dotprod(a, b)
    quantum = C * L * 8 * block
    a, _ = _pad_to(a, quantum, 0)
    b, _ = _pad_to(b, quantum, 0)
    return _red.dotprod_hier(a, b, C=C, L=L, block=block, hierarchy=hierarchy,
                             interpret=(m == "interpret"))


def expv(x, *, use_pallas=None, block=2048):
    m = _mode(use_pallas)
    if m == "ref":
        return ref.expv(x)
    n = x.shape[0]
    quantum = 8 * block
    xp, r = _pad_to(x, quantum, 0)
    out = _red.expv(xp, block=block, interpret=(m == "interpret"))
    return out[:n]


def softmax_rows(x, *, use_pallas=None, bm=8):
    m = _mode(use_pallas)
    if m == "ref":
        return ref.softmax_rows(x)
    R = x.shape[0]
    while R % bm:
        bm -= 1
    return _red.softmax_rows(x, bm=bm, interpret=(m == "interpret"))


def attention(q, k, v, *, causal=True, window=None, use_pallas=None,
              bq=None, bk=None):
    m = _mode(use_pallas)
    if m == "ref":
        return ref.attention(q, k, v, causal=causal, window=window)
    B, Hq, S, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    cfg = _resolve("flash_attention", (B, Hq, Hkv, S, Sk, D), q.dtype,
                   bq=bq, bk=bk)
    bq, bk = cfg["bq"], cfg["bk"]
    bq = min(bq, S)
    while S % bq:
        bq //= 2
    bk_ = min(bk, Sk)
    while Sk % bk_:
        bk_ //= 2
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               bq=max(bq, 1), bk=max(bk_, 1),
                               interpret=(m == "interpret"))


def rmsnorm(x, gamma, *, eps=1e-6, use_pallas=None, bm=None):
    m = _mode(use_pallas)
    if m == "ref":
        return ref.rmsnorm(x, gamma, eps)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    R = x2.shape[0]
    bm = _resolve("rmsnorm", (R, shape[-1]), x.dtype, bm=bm)["bm"]
    while R % bm:
        bm -= 1
    out = _rms.rmsnorm(x2, gamma, bm=bm, eps=eps, interpret=(m == "interpret"))
    return out.reshape(shape)


def dense(x, w, *, use_pallas=None):
    """The models' projection seam: ``x @ w`` contracting the last dim.

    Ref mode is *literally* ``x @ w`` (bit-identical to the historical
    inline call sites); Pallas mode flattens the leading dims and runs the
    tuned-block matmul."""
    if _mode(use_pallas) == "ref":
        return x @ w
    lead = x.shape[:-1]
    out = matmul(x.reshape(-1, x.shape[-1]), w, use_pallas=use_pallas)
    return out.reshape(*lead, w.shape[-1])


def paged_attention(q, kpool, vpool, tables, lens, *, use_pallas=None):
    """Paged decode attention: q (B, Hkv, G, D) against a block pool
    (Hkv, NB, bt, D) through per-sequence block tables.  The ref path is
    the gather + masked-softmax expression the serving engine's decode
    layers inline; the Pallas path never materialises the gathered view
    (scalar-prefetched tables drive the DMA).  The block size is baked
    into the pool layout, so tuning happens where the pool is *sized*
    (``serve.paged`` / :func:`paged_block_tokens`), not per call."""
    m = _mode(use_pallas)
    if m == "ref":
        return ref.paged_attention(q, kpool, vpool, tables, lens)
    return _pa.paged_attention(q, kpool, vpool, tables, lens,
                               interpret=(m == "interpret"))


def paged_block_tokens(B, Hq, Hkv, T, D, dtype, *, default=16):
    """Tokens-per-block for a paged KV pool serving this decode signature:
    the tuned ``paged_attention`` bt when the autotune table has one, else
    ``default`` — lowered to a power-of-two divisor of T so the pool tiles
    ``max_seq`` exactly."""
    cfg = _at.tuned_config("paged_attention", (B, Hq, Hkv, T, D),
                           str(dtype)) or {}
    bt = max(1, min(int(cfg.get("bt", default)), T))
    while T % bt:
        bt //= 2
    return max(bt, 1)


def attention_q_chunk(S, T, H, Dh, dtype, *, default=512):
    """The q-block for the chunked-attention seam in `models.layers`: the
    tuned ``flash_attention`` bq for this problem signature when recorded,
    else ``default`` — lowered to a divisor of S (the chunked math is
    per-q-row independent, so any chunk size is bit-identical)."""
    cfg = _at.tuned_config("flash_attention", (1, H, H, S, T, Dh),
                           str(dtype)) or {}
    cq = max(1, min(int(cfg.get("bq", default)), S))
    while S % cq:
        cq -= 1
    return cq
