"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul(a, b):
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)
                   ).astype(a.dtype)


def jacobi2d(x_padded):
    x = x_padded.astype(jnp.float32)
    out = 0.25 * (x[:-2, 1:-1] + x[2:, 1:-1] + x[1:-1, :-2] + x[1:-1, 2:])
    return out.astype(x_padded.dtype)


def fconv2d(x_padded, filt):
    x = x_padded.astype(jnp.float32)[None, :, :, None]
    f = filt.astype(jnp.float32)[:, :, None, None]
    out = jax.lax.conv_general_dilated(
        x, f, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return out[0, :, :, 0].astype(x_padded.dtype)


def dotprod(a, b):
    return jnp.sum(a.astype(jnp.float32) * b.astype(jnp.float32))


def expv(x):
    return jnp.exp(jnp.clip(x.astype(jnp.float32), -80.0, 80.0)).astype(x.dtype)


def softmax_rows(x):
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def attention(q, k, v, *, causal=True, window=None):
    """q (B,Hq,S,D), k/v (B,Hkv,Sk,D) with GQA head grouping."""
    B, Hq, S, D = q.shape
    Hkv = k.shape[1]
    group = Hq // Hkv
    kq = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vq = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kq)
    s = s / math.sqrt(D)
    Sk = k.shape[2]
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((S, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    # rows with no visible keys (all -inf) -> zero output
    any_visible = mask.any(axis=-1)[None, None, :, None]
    out = jnp.einsum("bhst,bhtd->bhsd", p, vq)
    out = jnp.where(any_visible, out, 0.0)
    return out.astype(q.dtype)


def rmsnorm(x, gamma, eps=1e-6):
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * gamma.astype(jnp.float32)
            ).astype(x.dtype)


def paged_attention(q, kpool, vpool, tables, lens):
    """Paged decode attention reference: q (B,Hkv,G,D), pools
    (Hkv,NB,bt,D), tables (B,nblk) int32, lens (B,) int32 -> (B,Hkv,G,D).
    Gathers the dense per-sequence view through the block table and masks
    positions >= lens; rows with no visible keys produce zeros (matching
    the kernel's zero-initialised accumulator)."""
    B, Hkv, G, D = q.shape
    bt = kpool.shape[2]
    k = jnp.transpose(kpool[:, tables], (1, 0, 2, 3, 4)) \
        .reshape(B, Hkv, -1, D).astype(jnp.float32)
    v = jnp.transpose(vpool[:, tables], (1, 0, 2, 3, 4)) \
        .reshape(B, Hkv, -1, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhtd->bhgt", q.astype(jnp.float32), k)
    s = s / math.sqrt(D)
    T = k.shape[2]
    visible = jnp.arange(T)[None, :] < lens[:, None]          # (B, T)
    s = jnp.where(visible[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bhtd->bhgd", p, v)
    out = jnp.where(visible.any(-1)[:, None, None, None], out, 0.0)
    return out.astype(q.dtype)
