"""Fused RMSNorm — the per-token normalization hot-spot of every LM layer.

One VMEM sweep per row block: mean-square reduce (the intra-lane reduction
stage), rsqrt, scale — no HBM round-trip for the intermediate.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# a row block must stay inside one LMUL=8 register group at the RVV-max
# 64 Kibit VLEN (AraXLParams), or the lanes spill mid-sweep
from .vrf import VREG_GROUP_BYTES as _VREG_GROUP_BYTES


def _rms_kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * g_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "eps", "interpret"))
def rmsnorm(x: jax.Array, gamma: jax.Array, *, bm: int = 8,
            eps: float = 1e-6, interpret: bool = False) -> jax.Array:
    """x (R, D), gamma (D,) -> (R, D).

    ``bm`` is a *ceiling*: it is halved until an (bm, D) f32 block fits one
    LMUL=8 register group, so wide-model rows (D=4096 busts 8 rows x 16 KiB)
    still stream without spilling, then lowered to a divisor of R so any
    row count is legal.
    """
    R, D = x.shape
    assert gamma.shape == (D,)
    bm = max(1, min(bm, R))
    while bm > 1 and bm * D * 4 > _VREG_GROUP_BYTES:
        bm //= 2
    while R % bm:
        bm -= 1
    kernel = functools.partial(_rms_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(R // bm,),
        in_specs=[pl.BlockSpec((bm, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bm, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, gamma)
