"""jacobi2d + fconv2d — slide-by-1 stencil kernels (paper Table I).

AraXL realises the horizontal taps of a stencil with RINGI slide-by-1
operations between neighbouring lanes/clusters.  On TPU the same data
movement is a *halo read*: each VMEM block is fetched with a one-column
(jacobi) or (fc-1)-column (conv) overlap, so the "slide" happens inside
the block load instead of on an inter-lane ring — the TPU memory system's
native idiom for neighbour access (HW adaptation recorded in DESIGN.md).

Inputs are pre-padded by the ops wrappers so every output block has a full
halo; row taps come from an ``fr``-row (or 2-row) vertical halo.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.substrate import halo_block_spec


# ---------------------------------------------------------------------------
# jacobi2d: out[i,j] = 0.25*(in[i-1,j] + in[i+1,j] + in[i,j-1] + in[i,j+1])
# on the interior of a (H+2, W+2) pre-padded input.
# ---------------------------------------------------------------------------

def _jacobi_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = 0.25 * (x[:-2, 1:-1] + x[2:, 1:-1] + x[1:-1, :-2]
                         + x[1:-1, 2:])


@functools.partial(jax.jit, static_argnames=("bh", "bw", "interpret"))
def jacobi2d(x_padded: jax.Array, *, bh: int = 8, bw: int = 256,
             interpret: bool = False) -> jax.Array:
    """One Jacobi sweep. ``x_padded`` is (H+2, W+2); returns (H, W)."""
    Hp, Wp = x_padded.shape
    H, W = Hp - 2, Wp - 2
    assert H % bh == 0 and W % bw == 0, (x_padded.shape, bh, bw)
    return pl.pallas_call(
        _jacobi_kernel,
        grid=(H // bh, W // bw),
        # overlapping halo blocks: element-offset indexing (portable spec).
        in_specs=[halo_block_spec((bh + 2, bw + 2),
                                  lambda i, j: (i * bh, j * bw))],
        out_specs=pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((H, W), x_padded.dtype),
        interpret=interpret,
    )(x_padded)


# ---------------------------------------------------------------------------
# fconv2d: valid 2-D convolution with a small (fr, fc) filter.
# Input pre-padded to (H + fr - 1, W_padded + fc - 1).
# ---------------------------------------------------------------------------

def _conv_kernel(x_ref, f_ref, o_ref, *, fr: int, fc: int):
    x = x_ref[...]
    f = f_ref[...]
    bh, bw = o_ref.shape
    acc = jnp.zeros((bh, bw), jnp.float32)
    for r in range(fr):                      # static taps: unrolled VMEM slides
        for c in range(fc):
            acc += f[r, c] * x[r:r + bh, c:c + bw].astype(jnp.float32)
    o_ref[...] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("fr", "fc", "bh", "bw", "interpret"))
def fconv2d(x_padded: jax.Array, filt: jax.Array, *, fr: int = 7, fc: int = 7,
            bh: int = 8, bw: int = 256, interpret: bool = False) -> jax.Array:
    Hp, Wp = x_padded.shape
    H, W = Hp - fr + 1, Wp - fc + 1
    assert filt.shape == (fr, fc)
    assert H % bh == 0 and W % bw == 0, (x_padded.shape, bh, bw)
    kernel = functools.partial(_conv_kernel, fr=fr, fc=fc)
    return pl.pallas_call(
        kernel,
        grid=(H // bh, W // bw),
        in_specs=[
            halo_block_spec((bh + fr - 1, bw + fc - 1),
                            lambda i, j: (i * bh, j * bw)),
            pl.BlockSpec((fr, fc), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bh, bw), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((H, W), x_padded.dtype),
        interpret=interpret,
    )(x_padded, filt)
