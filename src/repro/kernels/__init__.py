"""Pallas TPU kernels for the paper's Table-I set + LM hot-spots.

Layout per kernel: <name>.py holds the pl.pallas_call + BlockSpec tiling,
ops.py the jit'd public wrapper (auto TPU/interpret/reference dispatch,
block shapes resolved against the autotune winner table), ref.py the
pure-jnp oracle used by the allclose test sweeps, autotune.py the
model-guided block-shape tuner, vrf.py the shared register-file budget.
"""
from . import autotune, ops, ref, vrf

__all__ = ["autotune", "ops", "ref", "vrf"]
