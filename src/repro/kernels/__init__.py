"""Pallas TPU kernels for the paper's Table-I set + LM hot-spots.

Layout per kernel: <name>.py holds the pl.pallas_call + BlockSpec tiling,
ops.py the jit'd public wrapper (auto TPU/interpret/reference dispatch),
ref.py the pure-jnp oracle used by the allclose test sweeps.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
