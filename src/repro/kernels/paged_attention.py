"""Paged decode attention: online softmax over block-table-gathered KV.

The kernel half of the paged serving story: one query token per sequence
(GQA groups expanded in-register) attends over K/V blocks scattered
through a shared pool — the VRF-chunk gather as a Pallas kernel.  The
block table and per-sequence lengths ride in as *scalar-prefetch*
operands (``pltpu.PrefetchScalarGridSpec``), so each grid step's index
map sends the DMA engine straight to pool block ``tables[b, j]``: the
dense (B, W) view is never materialised, which is the whole point — HBM
traffic is `lens[b]` tokens of K/V per sequence, not `max_seq`.

Layouts (chosen so a block is contiguous per kv head):
    q      (B, Hkv, G, D)      one decode token per sequence
    kpool  (Hkv, NB, bt, D)    the shared block pool (block 0 = zeros)
    vpool  (Hkv, NB, bt, D)
    tables (B, nblk) int32     block ids per sequence, 0 = unallocated
    lens   (B,) int32          valid tokens per sequence
    out    (B, Hkv, G, D)

Grid (B, Hkv, nblk) with the block axis innermost: m/l/acc scratch
carries the running softmax across a sequence's blocks exactly like
``flash_attention.py``'s kv loop.  `bt` (tokens per block) is the tuned
parameter — the autotuner's VRF budget filter keeps (bt, D) K/V blocks
inside one LMUL=8 register group, the same constraint the serving
allocator's `max_block_tokens` applies.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, lens_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale, bt):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bt, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    k_pos = j * bt + jax.lax.broadcasted_iota(jnp.int32, (1, bt), 1)
    visible = k_pos < lens_ref[b]                          # (1, bt)
    s = jnp.where(visible, s, NEG_INF)                     # (G, bt)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    # mask p explicitly: on a fully-masked block m_new == NEG_INF and
    # exp(s - m_new) would be exp(0) == 1, not 0
    p = jnp.where(visible, jnp.exp(s - m_new), 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + \
        jnp.dot(p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _flush():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)                 # fully-masked row
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, kpool, vpool, tables, lens, *, interpret=False):
    """q (B, Hkv, G, D) + pools/tables/lens -> (B, Hkv, G, D)."""
    B, Hkv, G, D = q.shape
    bt = kpool.shape[2]
    nblk = tables.shape[1]
    scale = 1.0 / math.sqrt(D)

    def q_map(b, h, j, tables, lens):
        del tables, lens, j
        return (b, h, 0, 0)

    def kv_map(b, h, j, tables, lens):
        del lens
        return (h, tables[b, j], 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, Hkv, nblk),
        in_specs=[pl.BlockSpec((1, 1, G, D), q_map),
                  pl.BlockSpec((1, 1, bt, D), kv_map),
                  pl.BlockSpec((1, 1, bt, D), kv_map)],
        out_specs=pl.BlockSpec((1, 1, G, D), q_map),
        scratch_shapes=[pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, D), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, bt=bt),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        interpret=interpret,
    )(tables, lens, q, kpool, vpool)
