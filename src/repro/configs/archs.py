"""The assigned architectures (exact published configurations).

Sources are cited per entry; ``skip_shapes`` documents the noted cell skips
(DESIGN.md §5): ``long_500k`` requires sub-quadratic attention and is run
only for SWA/SSM/hybrid families.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .base import ATTN, MAMBA, MLP, MOE, XATTN, ModelConfig

_FULL_ATTN_SKIP = {"long_500k": "quadratic full attention at 524288 context"}


CONFIGS: dict[str, ModelConfig] = {}


def _reg(cfg: ModelConfig) -> ModelConfig:
    CONFIGS[cfg.name] = cfg
    return cfg


# --- MoE -------------------------------------------------------------------

# [hf:Qwen/Qwen3-235B-A22B; hf] 94L d4096 64H GQA kv=4, expert ff 1536,
# 128 experts top-8, head_dim 128
_reg(ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, d_ff_expert=1536, vocab_size=151936,
    n_experts=128, experts_per_token=8,
    rope_theta=1e6, norm_eps=1e-6,
    skip_shapes=dict(_FULL_ATTN_SKIP)))

# [arXiv:2401.04088; hf] Mixtral 8x7B: 32L d4096 32H kv=8 ff14336,
# 8 experts top-2, sliding window 4096
_reg(ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    d_ff_expert=14336, vocab_size=32000,
    n_experts=8, experts_per_token=2, moe_tp=True,
    window=4096, rope_theta=1e6, norm_eps=1e-5))

# --- enc-dec audio ----------------------------------------------------------

# [arXiv:2308.11596; hf] SeamlessM4T-large-v2 text dec: 24L d1024 16H ff8192;
# speech encoder stubbed as precomputed frames (d_ctx=1024)
_reg(ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    period=((ATTN, XATTN, MLP),),
    d_ctx=1024, rope_theta=1e4, norm_eps=1e-5,
    skip_shapes=dict(_FULL_ATTN_SKIP)))

# --- hybrid -----------------------------------------------------------------

# [arXiv:2403.19887; hf] Jamba-1.5-large: 72L d8192 64H kv=8 ff24576,
# attn:mamba 1:7, MoE (16e top-2) every other layer
_reg(ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    d_ff_expert=24576, vocab_size=65536,
    n_experts=16, experts_per_token=2,
    period=((MAMBA, MOE), (MAMBA, MLP), (MAMBA, MOE), (MAMBA, MLP),
            (ATTN, MOE), (MAMBA, MLP), (MAMBA, MOE), (MAMBA, MLP)),
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    rope_theta=1e4, norm_eps=1e-6))

# --- dense ------------------------------------------------------------------

# [arXiv:2404.14219; unverified] phi3-mini 3.8B
_reg(ModelConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32064, rope_theta=1e4, norm_eps=1e-5,
    skip_shapes=dict(_FULL_ATTN_SKIP)))

# [arXiv:2401.02954; hf] deepseek-llm-7b
_reg(ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008,
    vocab_size=102400, rope_theta=1e4, norm_eps=1e-6,
    skip_shapes=dict(_FULL_ATTN_SKIP)))

# [hf:THUDM/glm-4-9b; hf] glm4-9b — extreme GQA (kv=2)
_reg(ModelConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696,
    vocab_size=151552, rope_theta=1e4, norm_eps=1.5625e-7,
    skip_shapes=dict(_FULL_ATTN_SKIP)))

# [arXiv:2407.21783; unverified] llama3-8b
_reg(ModelConfig(
    name="llama3-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=128256, rope_theta=5e5, norm_eps=1e-5,
    skip_shapes=dict(_FULL_ATTN_SKIP)))

# --- VLM --------------------------------------------------------------------

# [hf:meta-llama/Llama-3.2-11B-Vision; unverified] 40L llama trunk,
# cross-attn image layers every 5th layer; vision frontend stubbed
# (1601 patch embeddings x 4 tiles, projected from d_ctx=7680)
_reg(ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=128256,
    period=((ATTN, MLP), (ATTN, MLP), (XATTN, MLP), (ATTN, MLP),
            (ATTN, MLP)),
    n_ctx_tokens=1601 * 4, d_ctx=7680,
    rope_theta=5e5, norm_eps=1e-5,
    skip_shapes=dict(_FULL_ATTN_SKIP)))

# --- SSM --------------------------------------------------------------------

# [arXiv:2405.21060; unverified] mamba2-370m: 48L d1024, attention-free,
# SSD state 128
_reg(ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=0,
    vocab_size=50280,
    period=((MAMBA,),),
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    norm_eps=1e-5, tie_embeddings=True))


# --- reduced smoke variants --------------------------------------------------

def smoke_variant(cfg: ModelConfig) -> ModelConfig:
    """Same family/period structure, tiny dimensions, CPU-friendly."""
    np_ = len(cfg.layer_period)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=2 * np_,
        d_model=64,
        n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)), d_head=16,
        d_ff=128, d_ff_expert=128 if cfg.d_ff_expert else 0,
        vocab_size=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2)
        if cfg.n_experts else 0,
        n_enc_layers=2 if cfg.n_enc_layers else 0,
        n_ctx_tokens=16 if cfg.n_ctx_tokens else 0,
        d_ctx=32 if cfg.d_ctx else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_chunk=8,
        window=min(cfg.window, 16) if cfg.window else None,
        dtype=jnp.float32,
        moe_tp=False,
        # capacity high enough that smoke-scale dispatch never drops —
        # batched-vs-sequential drop patterns would legitimately diverge
        capacity_factor=8.0,
        remat=False,
    )
