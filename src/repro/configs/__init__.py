"""Architecture registry: the 10 assigned configs + the AraXL paper machine.

``get_config(name)`` returns the full published configuration;
``get_smoke_config(name)`` a reduced same-family variant for CPU smoke tests.
"""
from __future__ import annotations

import dataclasses

from .base import ModelConfig, SHAPES, ShapeSpec
from . import archs


def list_archs() -> list[str]:
    return sorted(archs.CONFIGS)


def get_config(name: str) -> ModelConfig:
    return archs.CONFIGS[name]


def get_smoke_config(name: str) -> ModelConfig:
    return archs.smoke_variant(archs.CONFIGS[name])


__all__ = ["ModelConfig", "SHAPES", "ShapeSpec", "list_archs", "get_config",
           "get_smoke_config"]
