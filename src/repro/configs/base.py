"""Model / shape configuration schema for every assigned architecture."""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Sequence

import jax
import jax.numpy as jnp


# sublayer kinds; a layer is a tuple of sublayers, a period a tuple of layers
ATTN, MAMBA, XATTN = "attn", "mamba", "xattn"
MLP, MOE = "mlp", "moe"


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0              # 0 -> d_model // n_heads

    # repeating period: tuple of layers, each a tuple of sublayer kinds,
    # e.g. jamba: (("mamba","moe"), ("mamba","mlp"), ..., ("attn","moe"), ...).
    # empty -> every layer is ("attn", "mlp"/"moe").
    period: tuple = ()

    # MoE
    n_experts: int = 0
    experts_per_token: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    moe_tp: bool = False         # experts < |model| axis: shard d_ff instead
    moe_impl: str = "psum"       # "psum" (tokens replicated over model) |
    #                              "a2a" (GLSU-style token all-to-all EP)

    # attention
    rope_theta: float = 1e4
    window: int | None = None    # sliding-window attention

    # SSM (mamba2 / jamba)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # enc-dec
    n_enc_layers: int = 0
    # vlm / audio frontend stub
    n_ctx_tokens: int = 0        # image patches / audio frames per sample
    d_ctx: int = 0               # frontend embedding dim (projected to d_model)

    # numerics / training
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    remat: bool = True
    unroll_layers: bool = False  # python-loop periods (cost-analysis variants)
    loss_chunk: int = 0          # chunked cross-entropy (0 = single shot)

    # shape-cell applicability: {shape_name: reason} for noted skips
    skip_shapes: Any = dataclasses.field(default_factory=dict)

    # ---------------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/head tables padded to a 256 multiple so the vocab dim
        shards over any mesh axis (mamba2's 50280, seamless' 256206...).
        Logits for padded ids are masked to -inf in the loss/decode paths."""
        return ((self.vocab_size + 255) // 256) * 256

    @property
    def layer_period(self) -> tuple:
        if self.period:
            return self.period
        return ((ATTN, MOE if self.n_experts else MLP),)

    @property
    def n_periods(self) -> int:
        p = len(self.layer_period)
        assert self.n_layers % p == 0, (self.name, self.n_layers, p)
        return self.n_layers // p

    @property
    def d_inner_ssm(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner_ssm // self.ssm_head_dim

    def _sublayer_params(self, kind: str) -> int:
        d, hd = self.d_model, self.head_dim
        if kind in (ATTN, XATTN):
            return (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    + self.n_heads * hd * d + d)
        if kind == MAMBA:
            di = self.d_inner_ssm
            H, N = self.n_ssm_heads, self.ssm_state
            return (d * (2 * di + 2 * N + H) + self.ssm_conv * (di + 2 * N)
                    + 3 * H + di + di * d + d)
        if kind == MLP:
            return 3 * d * self.d_ff + d
        if kind == MOE:
            ffe = self.d_ff_expert or self.d_ff
            return (d * self.n_experts + self.n_experts * 3 * d * ffe + d)
        raise ValueError(kind)

    def n_params(self) -> int:
        """Total parameter count (embedding included)."""
        d = self.d_model
        n = self.vocab_size * d                       # embed
        if not self.tie_embeddings:
            n += d * self.vocab_size                  # head
        for layer in self.layer_period:
            for kind in layer:
                n += self.n_periods * self._sublayer_params(kind)
        n += d                                        # final norm
        if self.family == "encdec":
            n += self.n_enc_layers * (self._sublayer_params(ATTN)
                                      + self._sublayer_params(MLP)) + d
        if self.d_ctx:
            n += self.d_ctx * d                       # frontend projection
        return n

    def n_active_params(self) -> int:
        """Active (per-token) parameters — MoE counts top-k experts only."""
        if not self.n_experts:
            return self.n_params()
        ffe = self.d_ff_expert or self.d_ff
        n_moe = sum(1 for layer in self.layer_period
                    for k in layer if k == MOE) * self.n_periods
        inactive = n_moe * (self.n_experts - self.experts_per_token) \
            * 3 * self.d_model * ffe
        return self.n_params() - inactive

    def runnable(self, shape_name: str) -> bool:
        return shape_name not in self.skip_shapes
