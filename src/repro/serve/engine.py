"""Batched serving engine: continuous batching over prefill + decode steps.

The long-vector reading of serving: a decode batch is a vector register —
requests are elements, the engine keeps the register full (slot reuse on
completion), the KV/state caches are the per-lane VRF chunks.

Engine loop:
  1. admit: pack waiting requests into free slots (up to ``max_batch``),
     prefill them (left-padded to a common length bucket) and merge their
     caches into the live batch cache at their slots;
  2. step: one fused decode_step for the whole batch;
  3. retire: slots whose request hit EOS/max_tokens free up.

Topology-aware serving (``ServingEngine(..., topology=t)``): the KV cache
is placed *pod-locally* — its sharding rules are derived from the inner
topology levels only (:func:`pod_local_cache_rules`), so the outermost
(pod) ring never shards cache reads and each pod decodes from a full local
replica.  Slots are conceptually partitioned into per-pod blocks and the
admit loop prefers a slot whose pod has already served the request's prompt
prefix (prefix-cache affinity), falling back to the first free slot.  Both
policies only move *where* a request lands: admission order and per-slot
compute are unchanged, so the token streams are bit-identical to the
topology-blind engine (asserted by ``repro.testing.check_serve_topology``).

This container runs it at smoke scale on CPU; the same engine drives the
dry-run decode shapes on the production mesh.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.parallel.sharding import ShardingRules, param_shardings
from repro.topology import Topology

#: tokens of the prompt head that key the pod prefix-affinity cache
PREFIX_TOKENS = 16


def pod_local_cache_rules(rules: ShardingRules,
                          topology: Topology) -> ShardingRules:
    """Cache sharding from the *inner* topology levels only: strip the
    outermost level's mesh axes from every rule value, so no cache dim is
    ever sharded across the pod ring — each pod holds (and reads) a full
    local KV replica, the serving analogue of the paper's claim that the
    long wires must never carry inner-level traffic."""
    if rules.mesh is None or rules.rules is None or topology.n_levels < 2:
        return rules
    outer = set(topology.levels[0].axes)

    def strip(v):
        if v is None:
            return None
        axes = (v,) if isinstance(v, str) else tuple(v)
        kept = tuple(a for a in axes if a not in outer)
        if not kept:
            return None
        return kept[0] if len(kept) == 1 else kept

    return ShardingRules(rules.mesh, {k: strip(v)
                                      for k, v in rules.rules.items()})


def prefix_key(prompt: np.ndarray) -> tuple:
    """Hashable key of the prompt head (the prefix a pod's cache can reuse)."""
    return tuple(int(t) for t in np.asarray(prompt)[:PREFIX_TOKENS])


class PromptTooLongError(ValueError):
    """Prompt does not fit the engine's cache: the cache holds ``max_seq``
    positions and the first decode writes at position ``len(prompt)``, so
    admissible prompts satisfy ``len(prompt) <= max_seq - 1``."""


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    slot: int | None = None             # set at admit (observability)


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 256
    eos_id: int = 0


def validate_prompt(prompt, max_seq: int) -> int:
    """Shared submit()-time gate: returns the prompt length or raises
    :class:`PromptTooLongError` (a cache overflow waiting to happen) /
    ``ValueError`` (empty prompt)."""
    plen = int(np.asarray(prompt).shape[0])
    if plen < 1:
        raise ValueError("empty prompt")
    if plen >= max_seq:
        raise PromptTooLongError(
            f"prompt length {plen} >= max_seq {max_seq}: decode would "
            f"write position {plen} into a {max_seq}-position cache")
    return plen


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, rules: ShardingRules,
                 scfg: ServeConfig, topology: Topology | None = None):
        self.cfg = cfg
        self.params = params
        self.rules = rules
        self.scfg = scfg
        self.topology = topology
        B, S = scfg.max_batch, scfg.max_seq
        cache_defs = lm.cache_defs(cfg, B, S)
        self.cache = jax.tree.map(
            lambda pv: jnp.zeros(pv.shape, pv.dtype), cache_defs,
            is_leaf=lambda x: hasattr(x, "logical"))
        self._cache_sh = None
        self.n_pods = 1
        if topology is not None:
            self.n_pods = (topology.levels[0].size
                           if topology.n_levels > 1 else 1)
            cache_rules = pod_local_cache_rules(rules, topology)
            if cache_rules.mesh is not None:
                rr = dict(cache_rules.rules)
                if rr.get("batch") is None:
                    # serving rules keep activations batch-unsharded (the
                    # admit loop prefills one request at a time); the cache
                    # *slot* dim still shards over the inner dp levels when
                    # the slot count divides them — pod stays replicated
                    inner_dp = tuple(
                        a for lvl in topology.levels[1:-1] for a in lvl.axes
                        if a in cache_rules.mesh.shape)
                    dp_size = 1
                    for a in inner_dp:
                        dp_size *= cache_rules.mesh.shape[a]
                    if inner_dp and B % dp_size == 0:
                        rr["batch"] = inner_dp
                cache_rules = ShardingRules(cache_rules.mesh, rr)
                self._cache_sh = param_shardings(cache_defs, cache_rules)
                self.cache = jax.tree.map(jax.device_put, self.cache,
                                          self._cache_sh)
        # per-pod recently-served prompt prefixes (insertion-ordered dicts
        # used as bounded FIFO sets: old prefixes' KV gets overwritten as a
        # pod's slots recycle, so affinity beyond a few slot generations is
        # stale — and the history must not grow with distinct prompts)
        self._prefix_cap = max(1, 4 * B // self.n_pods)
        self.pod_prefixes: list[dict] = [{} for _ in range(self.n_pods)]
        self.slots: list[Request | None] = [None] * B
        self.slot_pos = np.zeros(B, np.int32)       # per-slot next position
        self.waiting: list[Request] = []
        self.finished: list[Request] = []
        self.peak_live = 0                  # high-water mark of live slots

        self._prefill = jax.jit(
            lambda p, t: lm.prefill(p, t, cfg, rules, S))
        self._step = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg, rules),
            out_shardings=(None, self._cache_sh)
            if self._cache_sh is not None else None)
        self._ctx = None

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        validate_prompt(req.prompt, self.scfg.max_seq)
        self.waiting.append(req)

    # -- observability (shared with the paged engine / router / traffic) -----
    @property
    def n_live(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def n_waiting(self) -> int:
        return len(self.waiting)

    @property
    def capacity(self) -> int:
        return self.scfg.max_batch

    def slot_pod(self, slot: int) -> int:
        """Home pod of a slot: slots are partitioned into contiguous
        per-pod blocks (pod p serves slots [p*B/P, (p+1)*B/P))."""
        return slot * self.n_pods // self.scfg.max_batch

    def _remember_prefix(self, pod: int, key: tuple) -> None:
        seen = self.pod_prefixes[pod]
        seen.pop(key, None)                 # refresh recency
        seen[key] = True
        while len(seen) > self._prefix_cap:
            seen.pop(next(iter(seen)))      # FIFO-evict the oldest

    def _pick_slot(self, free: list[int], req: Request) -> int:
        """First free slot, preferring pods that already hold the request's
        prompt prefix (pod-local KV reuse).  Topology-blind engines keep
        the historical first-free order bit for bit."""
        if self.topology is None or self.n_pods == 1:
            return free[0]
        key = prefix_key(req.prompt)
        for slot in free:
            if key in self.pod_prefixes[self.slot_pod(slot)]:
                return slot
        return free[0]

    def _admit(self):
        free = [i for i, s in enumerate(self.slots) if s is None]
        admitted = False
        while free and self.waiting:
            admitted = True
            req = self.waiting.pop(0)
            slot = self._pick_slot(free, req)
            free.remove(slot)
            self._remember_prefix(self.slot_pod(slot), prefix_key(req.prompt))
            req.slot = slot
            # prefill this request alone (bucketed batch prefill is the
            # batch>1 path; slot-merge is identical)
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            cache, logits = self._prefill(self.params, toks)
            nxt = int(jnp.argmax(logits[0, -1]))
            req.out.append(nxt)
            # merge this request's cache rows into the live batch cache
            self.cache = jax.tree.map(
                lambda big, small: big.at[:, slot].set(small[:, 0])
                if big.ndim >= 2 else big, self.cache, cache)
            self.slots[slot] = req
            self.slot_pos[slot] = len(req.prompt)
            self.peak_live = max(self.peak_live, self.n_live)
        if admitted and self._cache_sh is not None:
            # keep the merged cache pinned pod-locally (the .at[].set above
            # follows sharding propagation, which may drift); steps with no
            # admission skip this — _step's out_shardings already pins
            self.cache = jax.tree.map(jax.device_put, self.cache,
                                      self._cache_sh)

    # -- decode --------------------------------------------------------------
    def _live(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def step(self):
        self._admit()
        live = self._live()
        if not live:
            return False
        B = self.scfg.max_batch
        tok = np.zeros((B, 1), np.int32)
        for i in live:
            tok[i, 0] = self.slots[i].out[-1]
        # per-slot true positions: each slot writes its own ring slot and
        # masks at its own depth (dead slots carry a stale position and
        # write into their own retired rows — overwritten at next admit)
        pos = jnp.asarray(self.slot_pos, jnp.int32)
        logits, self.cache = self._step(self.params, jnp.asarray(tok),
                                        self.cache, pos)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i in live:
            req = self.slots[i]
            t = int(nxt[i])
            req.out.append(t)
            self.slot_pos[i] += 1
            if t == self.scfg.eos_id or \
                    len(req.out) >= req.max_new_tokens or \
                    self.slot_pos[i] >= self.scfg.max_seq - 1:
                req.done = True
                self.finished.append(req)
                self.slots[i] = None
        return True

    def run(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not self.step() and not self.waiting:
                break
        return self.finished
