"""Batched serving engine: continuous batching over prefill + decode steps.

The long-vector reading of serving: a decode batch is a vector register —
requests are elements, the engine keeps the register full (slot reuse on
completion), the KV/state caches are the per-lane VRF chunks.

Engine loop:
  1. admit: pack waiting requests into free slots (up to ``max_batch``),
     prefill them (left-padded to a common length bucket) and merge their
     caches into the live batch cache at their slots;
  2. step: one fused decode_step for the whole batch;
  3. retire: slots whose request hit EOS/max_tokens free up.

This container runs it at smoke scale on CPU; the same engine drives the
dry-run decode shapes on the production mesh.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.parallel.sharding import ShardingRules, init_params


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (len,) int32
    max_new_tokens: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_batch: int = 8
    max_seq: int = 256
    eos_id: int = 0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, rules: ShardingRules,
                 scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.rules = rules
        self.scfg = scfg
        B, S = scfg.max_batch, scfg.max_seq
        cache_defs = lm.cache_defs(cfg, B, S)
        self.cache = jax.tree.map(
            lambda pv: jnp.zeros(pv.shape, pv.dtype), cache_defs,
            is_leaf=lambda x: hasattr(x, "logical"))
        self.slots: list[Request | None] = [None] * B
        self.slot_pos = np.zeros(B, np.int32)       # per-slot next position
        self.waiting: list[Request] = []
        self.finished: list[Request] = []

        self._prefill = jax.jit(
            lambda p, t: lm.prefill(p, t, cfg, rules, S))
        self._step = jax.jit(
            lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg, rules))
        self._ctx = None

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        self.waiting.append(req)

    def _admit(self):
        free = [i for i, s in enumerate(self.slots) if s is None]
        while free and self.waiting:
            slot = free.pop(0)
            req = self.waiting.pop(0)
            # prefill this request alone (bucketed batch prefill is the
            # batch>1 path; slot-merge is identical)
            toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
            cache, logits = self._prefill(self.params, toks)
            nxt = int(jnp.argmax(logits[0, -1]))
            req.out.append(nxt)
            # merge this request's cache rows into the live batch cache
            self.cache = jax.tree.map(
                lambda big, small: big.at[:, slot].set(small[:, 0])
                if big.ndim >= 2 else big, self.cache, cache)
            self.slots[slot] = req
            self.slot_pos[slot] = len(req.prompt)

    # -- decode --------------------------------------------------------------
    def _live(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is not None]

    def step(self):
        self._admit()
        live = self._live()
        if not live:
            return False
        B = self.scfg.max_batch
        tok = np.zeros((B, 1), np.int32)
        for i in live:
            tok[i, 0] = self.slots[i].out[-1]
        # single shared position: engine advances the max; per-slot masks in
        # the attention layer handle shorter slots (pos monotone per slot)
        pos = int(self.slot_pos[live].max())
        logits, self.cache = self._step(self.params, jnp.asarray(tok),
                                        self.cache, jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for i in live:
            req = self.slots[i]
            t = int(nxt[i])
            req.out.append(t)
            self.slot_pos[i] += 1
            if t == self.scfg.eos_id or \
                    len(req.out) >= req.max_new_tokens or \
                    self.slot_pos[i] >= self.scfg.max_seq - 1:
                req.done = True
                self.finished.append(req)
                self.slots[i] = None
        return True

    def run(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not self.step() and not self.waiting:
                break
        return self.finished
