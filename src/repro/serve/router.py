"""Cross-pod request router: a front-end over per-pod serving engines.

The scale-out discipline of the paper applied to serving: when one engine
(one pod) can't grow further, cluster them — and keep inner-level reuse
off the long wires.  The router balances on two signals, in order:

1. **prefix history** — a bounded, per-pod FIFO of recently-routed prompt
   prefixes (:func:`repro.serve.engine.prefix_key`).  A request whose
   prefix a pod has seen goes back to that pod, where the paged engine
   turns the affinity into shared-prefix *block reuse* (COW blocks still
   resident from the earlier request);
2. **pod load** — waiting + live requests; fresh prefixes go to the
   least-loaded pod, and among history hits the least-loaded hit wins.

The router never touches tokens or caches: routing only picks *which*
engine a request is submitted to, so per-request token streams are the
single-engine streams (the property ``check_serve_paged`` asserts).
"""
from __future__ import annotations

from .engine import Request, prefix_key


class PrefixRouter:
    """Route requests across engines on prefix history + load."""

    def __init__(self, engines, prefix_cap: int = 64):
        if not engines:
            raise ValueError("router needs at least one engine")
        self.engines = list(engines)
        self.prefix_cap = prefix_cap
        # insertion-ordered dicts as bounded FIFO sets (same idiom as the
        # engine's pod_prefixes): stale prefixes age out as pods recycle
        self._history: list[dict] = [{} for _ in self.engines]
        self.routed = [0] * len(self.engines)
        self.affinity_hits = 0

    def load(self, pod: int) -> int:
        e = self.engines[pod]
        return e.n_waiting + e.n_live

    def route(self, req: Request) -> int:
        """Submit ``req`` to the chosen pod's engine; returns the pod."""
        key = prefix_key(req.prompt)
        hits = [p for p, seen in enumerate(self._history) if key in seen]
        if hits:
            pod = min(hits, key=self.load)
            self.affinity_hits += 1
        else:
            pod = min(range(len(self.engines)), key=self.load)
        seen = self._history[pod]
        seen.pop(key, None)                 # refresh recency
        seen[key] = True
        while len(seen) > self.prefix_cap:
            seen.pop(next(iter(seen)))
        self.engines[pod].submit(req)
        self.routed[pod] += 1
        return pod

    # engine-shaped surface so the traffic generator can drive a router
    # exactly like a single engine
    submit = route

    @property
    def n_live(self) -> int:
        return sum(e.n_live for e in self.engines)

    @property
    def n_waiting(self) -> int:
        return sum(e.n_waiting for e in self.engines)

    @property
    def capacity(self) -> int:
        return sum(e.capacity for e in self.engines)

    @property
    def peak_live(self) -> int:
        return sum(e.peak_live for e in self.engines)

    @property
    def finished(self) -> list[Request]:
        return [r for e in self.engines for r in e.finished]

    def step(self) -> bool:
        return any([e.step() for e in self.engines])

    def run(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not self.step() and self.n_waiting == 0:
                break
        return self.finished
