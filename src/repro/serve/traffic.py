"""Open-loop serving load: Poisson arrivals over a Zipf-popular prompt pool.

Open-loop means arrivals do not wait for the server (the load a fleet of
independent users generates): request i becomes submittable at a fixed
wall-clock offset drawn from exponential interarrival gaps, whether or
not the engine has kept up — so queueing delay shows up in TTFT instead
of being hidden by a closed feedback loop.  Prompt *popularity* is
Zipfian over a small pool (the same ``ranks**-a`` law as
``data/pipeline.py``'s corpus, whose Markov rows supply the prompt text),
which is what makes shared-prefix block reuse a first-class effect: the
head of the distribution hits the same prompt blocks over and over.

All wall-clock reads go through ``repro.testing.timing.now`` (lint L4);
this module records metrics and prints machine-parseable lines — the
schema-pinned BENCH artifact is written only by ``benchmarks/run.py``
(lint L3), which runs this module's CLI in an 8-fake-device subprocess.

CLI: ``python -m repro.serve.traffic --configs dense,paged,paged_chunked``
prints one ``serve/<tag>,...`` CSV line and one ``serve_json {...}`` line
per config.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

import numpy as np

from repro.data.pipeline import DataConfig, SyntheticCorpus
from repro.serve.engine import Request
from repro.testing.timing import now


@dataclasses.dataclass(frozen=True)
class LoadConfig:
    n_requests: int = 24
    rate_rps: float = 20.0      # Poisson arrival rate (requests / second)
    zipf_a: float = 1.1         # prompt-popularity exponent over the pool
    pool_size: int = 6
    min_prompt: int = 4
    max_prompt: int = 24
    max_new: int = 16
    vocab_size: int = 512
    seed: int = 0


def prompt_pool(lc: LoadConfig) -> list[np.ndarray]:
    """Pool of distinct prompts cut from the synthetic corpus rows (Zipf
    unigrams + Markov bigrams), with per-prompt lengths drawn uniformly —
    the corpus machinery reused, not reimplemented."""
    dc = DataConfig(vocab_size=lc.vocab_size, seq_len=lc.max_prompt,
                    global_batch=lc.pool_size, seed=lc.seed)
    rows = SyntheticCorpus(dc).batch(0)
    rng = np.random.default_rng(lc.seed)
    lens = rng.integers(lc.min_prompt, lc.max_prompt + 1, lc.pool_size)
    return [r[:n].astype(np.int32).copy() for r, n in zip(rows, lens)]


def request_schedule(lc: LoadConfig) -> tuple[np.ndarray, np.ndarray]:
    """(arrival offsets seconds, pool index) per request: exponential
    interarrival gaps (Poisson process) + Zipf-ranked pool popularity."""
    rng = np.random.default_rng(lc.seed + 1)
    arrivals = np.cumsum(rng.exponential(1.0 / lc.rate_rps, lc.n_requests))
    ranks = np.arange(1, lc.pool_size + 1, dtype=np.float64)
    p = ranks ** (-lc.zipf_a)
    p /= p.sum()
    idx = rng.choice(lc.pool_size, size=lc.n_requests, p=p)
    return arrivals, idx


def run_open_loop(engine, lc: LoadConfig, *, max_steps: int = 100_000) -> dict:
    """Drive ``engine`` (any object with submit/step/n_live/n_waiting/
    capacity/peak_live) under the open-loop schedule; returns the metrics
    dict ``benchmarks/run.py`` records per config."""
    pool = prompt_pool(lc)
    arrivals, idx = request_schedule(lc)
    reqs = [Request(rid=i, prompt=pool[j], max_new_tokens=lc.max_new)
            for i, j in enumerate(idx)]
    ttft: dict[int, float] = {}
    occ: list[float] = []
    submitted = 0
    t0 = now()
    for _ in range(max_steps):
        t = now() - t0
        while submitted < len(reqs) and arrivals[submitted] <= t:
            engine.submit(reqs[submitted])
            submitted += 1
        worked = engine.step()
        tnow = now() - t0
        for r in reqs[:submitted]:
            if r.out and r.rid not in ttft:
                ttft[r.rid] = tnow
        if worked:                  # slot utilization of actual engine steps
            occ.append(engine.n_live / engine.capacity)
        if submitted == len(reqs) and not worked and engine.n_waiting == 0 \
                and engine.n_live == 0:
            break
    wall = now() - t0
    done = [r for r in reqs if r.done]
    gen_tokens = sum(len(r.out) for r in reqs)
    ttft_ms = sorted(1e3 * (ttft[r.rid] - arrivals[r.rid])
                     for r in reqs if r.rid in ttft)
    pct = (lambda q: ttft_ms[min(len(ttft_ms) - 1,
                                 int(q * (len(ttft_ms) - 1)))]) \
        if ttft_ms else (lambda q: 0.0)
    return {
        "n_requests": lc.n_requests,
        "completed": len(done),
        "ttft_p50_ms": round(pct(0.50), 3),
        "ttft_p99_ms": round(pct(0.99), 3),
        "decode_tok_s": round(gen_tokens / max(wall, 1e-9), 3),
        "occupancy": round(float(np.mean(occ)) if occ else 0.0, 4),
        "max_concurrent": int(engine.peak_live),
        "wall_s": round(wall, 3),
    }


# ---------------------------------------------------------------------------
# CLI: the ablation benchmarks/run.py records (dense vs paged vs chunked)
# ---------------------------------------------------------------------------

def _build(tag: str, args):
    """One engine per ablation arm, all at EQUAL device memory: the dense
    engine holds ``dense_batch * max_seq`` KV token-slots; the paged pool
    holds the same token count in ``n_blocks`` blocks but serves
    ``max_batch`` slots over it."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.parallel.sharding import default_rules, init_params
    from repro.serve.engine import ServeConfig, ServingEngine
    from repro.serve.paged import (PagedServeConfig, PagedServingEngine,
                                   kv_token_bytes)
    from repro.topology import Topology

    cfg = get_smoke_config(args.arch)
    mesh = topo = None
    if len(jax.devices()) >= 8:
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        topo = Topology.from_levels([("pod", 2, 8.0), ("data", 2, 4.0),
                                     ("model", 2, 2.0)])
    rules = default_rules(mesh, kv_heads=cfg.n_kv_heads, batch=1)
    params = init_params(lm.model_defs(cfg), jax.random.key(args.seed))
    bt = args.block_tokens
    n_blocks = args.dense_batch * args.max_seq // bt   # equal token capacity
    per_tok = kv_token_bytes(cfg)
    if tag == "dense":
        scfg = ServeConfig(max_batch=args.dense_batch, max_seq=args.max_seq)
        eng = ServingEngine(cfg, params, rules, scfg, topology=topo)
        conf = {"max_batch": scfg.max_batch, "max_seq": scfg.max_seq,
                "block_tokens": 0, "chunk": 0}
        kv_cap = scfg.max_batch * scfg.max_seq * per_tok
        kv_peak = lambda: kv_cap                       # dense: always resident
    else:
        chunk = args.chunk if tag == "paged_chunked" else 0
        scfg = PagedServeConfig(max_batch=args.max_batch,
                                max_seq=args.max_seq, block_tokens=bt,
                                n_blocks=n_blocks, chunk=chunk)
        eng = PagedServingEngine(cfg, params, rules, scfg)
        conf = {"max_batch": scfg.max_batch, "max_seq": scfg.max_seq,
                "block_tokens": bt, "chunk": chunk}
        kv_cap = n_blocks * bt * per_tok
        kv_peak = eng.kv_bytes_resident_peak
    return eng, conf, kv_cap, kv_peak


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--configs", default="dense,paged,paged_chunked",
                    help="comma-separated: dense, paged, paged_chunked")
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=20.0)
    ap.add_argument("--pool", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--max-batch", type=int, default=8,
                    help="paged engine slots")
    ap.add_argument("--dense-batch", type=int, default=2,
                    help="dense slots at the same KV memory")
    ap.add_argument("--block-tokens", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    lc = LoadConfig(n_requests=args.requests, rate_rps=args.rate,
                    pool_size=args.pool, max_prompt=args.max_prompt,
                    max_new=args.max_new, seed=args.seed)
    for tag in args.configs.split(","):
        tag = tag.strip()
        eng, conf, kv_cap, kv_peak = _build(tag, args)
        metrics = run_open_loop(eng, lc)
        if hasattr(eng, "shutdown") and eng.n_live == 0 \
                and eng.n_waiting == 0:
            eng.shutdown()      # leaked KV blocks fail the run loudly
        metrics["kv_bytes_capacity"] = int(kv_cap)
        metrics["kv_bytes_resident_peak"] = int(kv_peak())
        conf["rate_rps"] = lc.rate_rps
        rec = {"tag": tag, "config": conf, **metrics}
        print(f"serve/{tag},{metrics['ttft_p50_ms']},{metrics['ttft_p99_ms']},"
              f"{metrics['decode_tok_s']},{metrics['occupancy']},"
              f"{metrics['max_concurrent']}")
        print("serve_json " + json.dumps(rec, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
