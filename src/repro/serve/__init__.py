from .engine import (Request, ServeConfig, ServingEngine,
                     pod_local_cache_rules, prefix_key)

__all__ = ["Request", "ServeConfig", "ServingEngine",
           "pod_local_cache_rules", "prefix_key"]
