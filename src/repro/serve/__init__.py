from .engine import (PromptTooLongError, Request, ServeConfig, ServingEngine,
                     pod_local_cache_rules, prefix_key, validate_prompt)
from .paged import (BlockAllocator, BlockLeakError, PagedServeConfig,
                    PagedServingEngine, kv_token_bytes, max_block_tokens)
from .router import PrefixRouter

__all__ = ["PromptTooLongError", "Request", "ServeConfig", "ServingEngine",
           "pod_local_cache_rules", "prefix_key", "validate_prompt",
           "BlockAllocator", "BlockLeakError", "PagedServeConfig",
           "PagedServingEngine", "kv_token_bytes", "max_block_tokens",
           "PrefixRouter"]
