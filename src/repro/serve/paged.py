"""Paged KV serving: block-table cache, COW prefix sharing, chunked prefill.

The serving translation of AraXL's VRF decoupling: instead of one dense
``max_seq``-long KV region per slot (capacity paid at worst case, like a
monolithic VRF), K/V live in a shared pool of fixed-size token *blocks* —
the VRF chunk map applied to serving.  Each request holds a table of block
ids; attention gathers through the table; a free-list allocator hands
blocks out on demand.  Block 0 is a reserved, permanently-zero block:
unallocated table entries gather exact zeros, which is precisely what the
dense cache's unwritten rows hold — the invariant that keeps paged decode
**bit-identical** to :class:`repro.serve.engine.ServingEngine` for the
same admission order.

Prefix sharing (PR 4's prefix-affinity turned into block *reuse*): full
prompt blocks are registered under their token-content key and retained by
later requests with the same prefix; a partially-filled last block is
keyed by the whole prompt.  Shared blocks are copy-on-write — the first
decode write into a refcount>1 block copies it — so sharers never observe
each other's generated tokens.

Chunked prefill (``PagedServeConfig.chunk``): prompts are prefilled in
fixed-size chunks interleaved with decode steps, so admitting a long
prompt never stalls the running batch, and the prefill executable
compiles once per *chunk shape* instead of once per prompt length.
Chunked streams are exact per the chunked-attention math but are not
claimed bit-identical to the dense engine (the attention view is the
padded ``max_seq`` window rather than the prompt length).

Block sizing is tied to the same `kernels/vrf.py` budgets the S3 check
enforces on every pallas_call: a (block_tokens, Hkv, Dh) K block must fit
one LMUL=8 register group (:func:`max_block_tokens`).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.kernels.vrf import VREG_GROUP_BYTES
from repro.models import lm
from repro.parallel.sharding import ShardingRules
from .engine import Request, validate_prompt

# chunked-prefill slot states
PREFILL, DECODE = 0, 1


def kv_token_bytes(cfg: ModelConfig) -> int:
    """KV bytes per token across the whole model (k+v, every attention
    sublayer instance) — the unit both engines' resident-bytes metrics
    are denominated in."""
    n_attn = sum(kind == ATTN for layer in cfg.layer_period
                 for kind in layer) * cfg.n_periods
    isz = jnp.dtype(cfg.dtype).itemsize
    return 2 * cfg.n_kv_heads * cfg.head_dim * isz * n_attn


def max_block_tokens(cfg: ModelConfig, *, budget: int = VREG_GROUP_BYTES) -> int:
    """Largest power-of-two block size whose per-layer K block fits one
    LMUL=8 register group — the same ``kernels/vrf.py`` budget the S3
    check enforces on pallas_call buffers."""
    per_tok = cfg.n_kv_heads * cfg.head_dim * jnp.dtype(cfg.dtype).itemsize
    bt = 1
    while 2 * (2 * bt) * per_tok <= budget:
        bt *= 2
    return bt


@dataclasses.dataclass(frozen=True)
class PagedServeConfig:
    """``n_blocks`` counts *allocatable* blocks; the pool holds one more
    (the reserved zero block).  Equal-device-memory comparisons against the
    dense engine equate ``n_blocks * block_tokens`` with the dense
    ``max_batch * max_seq`` token-slots."""
    max_batch: int = 8
    max_seq: int = 256
    eos_id: int = 0
    block_tokens: int = 16
    n_blocks: int = 128
    chunk: int = 0          # 0 = whole-prompt prefill; else chunk length


class BlockAllocator:
    """Free-list allocator over fixed-size KV token blocks with refcounts
    and a shared-prefix registry.

    Block ids index the pool; id 0 is the reserved zero block — never
    allocated, never written by a live slot.  ``alloc`` optionally
    registers the block under a content key so later requests with the
    same prefix can ``lookup`` + ``retain`` it; the *engine* implements
    copy-on-write above this class and must ``forget_key`` a block before
    writing into it exclusively (the content diverges from the key)."""

    def __init__(self, n_blocks: int, block_tokens: int):
        self.n_blocks = int(n_blocks)
        self.block_tokens = int(block_tokens)
        self._free = list(range(self.n_blocks, 0, -1))   # pop() -> lowest id
        self.refcount = np.zeros(self.n_blocks + 1, np.int64)
        self._prefix: dict[tuple, int] = {}
        self._key_of: dict[int, tuple] = {}
        self.peak_allocated = 0
        self.shared_hits = 0

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_allocated(self) -> int:
        return self.n_blocks - len(self._free)

    def alloc(self, key: tuple | None = None) -> int:
        if not self._free:
            raise RuntimeError("block pool exhausted (reservation bug: "
                               "admission must cover worst-case growth)")
        bid = self._free.pop()
        self.refcount[bid] = 1
        if key is not None:
            self.register(bid, key)
        self.peak_allocated = max(self.peak_allocated, self.n_allocated)
        return bid

    def lookup(self, key: tuple) -> int | None:
        return self._prefix.get(key)

    def retain(self, bid: int) -> int:
        assert self.refcount[bid] > 0, bid
        self.refcount[bid] += 1
        self.shared_hits += 1
        return bid

    def release(self, bid: int) -> None:
        assert self.refcount[bid] > 0, bid
        self.refcount[bid] -= 1
        if self.refcount[bid] == 0:
            self.forget_key(bid)
            self._free.append(bid)

    def register(self, bid: int, key: tuple) -> None:
        """Publish a block's content key (no-op if the key is taken —
        first writer wins; the duplicate block just stays private)."""
        if key in self._prefix:
            return
        self._prefix[key] = bid
        self._key_of[bid] = key

    def forget_key(self, bid: int) -> None:
        """Drop a block's registry entry before its content diverges."""
        key = self._key_of.pop(bid, None)
        if key is not None and self._prefix.get(key) == bid:
            del self._prefix[key]

    def assert_quiescent(self) -> None:
        """Shutdown hygiene gate: with no work in flight, every block must
        be back on the free list, every refcount zero (including the
        reserved zero block, which nothing may ever retain), and the
        shared-prefix registry empty.  A violation is a leaked reservation
        — the paged engine's equivalent of an fd leak: invisible to
        correctness checks, fatal to a long-running server as the pool
        quietly shrinks.  Raises :class:`BlockLeakError` naming the
        leaked block ids."""
        problems = []
        live = [int(b) for b in np.nonzero(self.refcount)[0]]
        if live:
            counts = {b: int(self.refcount[b]) for b in live[:8]}
            problems.append(f"{len(live)} blocks with live refcounts "
                            f"(id -> count, first 8: {counts})")
        if self.n_free != self.n_blocks:
            problems.append(f"free list holds {self.n_free} of "
                            f"{self.n_blocks} blocks")
        if self._prefix or self._key_of:
            problems.append(f"prefix registry not empty "
                            f"({len(self._prefix)} keys, "
                            f"{len(self._key_of)} reverse entries)")
        if problems:
            raise BlockLeakError("; ".join(problems))


class BlockLeakError(RuntimeError):
    """A shutdown-time block-accounting violation — see
    :meth:`BlockAllocator.assert_quiescent`."""


class PagedServingEngine:
    """Continuous batching over a paged KV pool.

    Same loop as :class:`ServingEngine` (admit -> step -> retire) with
    three changes: (1) admission allocates block-table entries instead of
    a dense slot region, sharing full prefix blocks COW; (2) admission is
    *reservation-based* — a request is admitted only if the pool can cover
    its worst-case future growth plus every outstanding reservation, so a
    decode-time ``alloc`` can never fail; (3) with ``chunk`` set, prefill
    runs one fixed-size chunk per engine step, interleaved with the decode
    batch, instead of blocking on the whole prompt."""

    def __init__(self, cfg: ModelConfig, params, rules: ShardingRules,
                 scfg: PagedServeConfig):
        if cfg.window:
            raise ValueError("paged serving supports full attention only")
        B, S, bt = scfg.max_batch, scfg.max_seq, scfg.block_tokens
        if S % bt:
            raise ValueError(f"max_seq {S} not a multiple of "
                             f"block_tokens {bt}")
        if scfg.chunk and (scfg.chunk % bt or S % scfg.chunk):
            raise ValueError(f"chunk {scfg.chunk} must be a multiple of "
                             f"block_tokens {bt} and divide max_seq {S}")
        cap = max_block_tokens(cfg)
        if bt > cap:
            raise ValueError(f"block_tokens {bt} busts the VREG-group "
                             f"budget (max {cap} for this config)")
        self.cfg = cfg
        self.params = params
        self.rules = rules
        self.scfg = scfg
        self.max_blocks = S // bt
        pool_defs = lm.pool_defs(cfg, scfg.n_blocks + 1, bt)
        self.pool = jax.tree.map(
            lambda pv: jnp.zeros(pv.shape, pv.dtype), pool_defs,
            is_leaf=lambda x: hasattr(x, "logical"))
        self.alloc = BlockAllocator(scfg.n_blocks, bt)
        self.tables = np.zeros((B, self.max_blocks), np.int32)
        self.slots: list[Request | None] = [None] * B
        self.slot_pos = np.zeros(B, np.int32)
        self.slot_state = np.full(B, DECODE, np.int32)
        self.slot_fill = np.zeros(B, np.int32)      # chunked-prefill progress
        self.slot_reserve = np.zeros(B, np.int64)   # worst-case future allocs
        self._slot_new: list[list[tuple[int, int]]] = [[] for _ in range(B)]
        self.waiting: list[Request] = []
        self.finished: list[Request] = []
        self.peak_live = 0
        self.cow_copies = 0
        self.decode_steps = 0
        self.prefill_chunks = 0

        self._prefill = jax.jit(
            lambda p, t: lm.prefill(p, t, cfg, rules, S))
        self._step = jax.jit(
            lambda p, t, pool, tab, pos, lv: lm.decode_step_paged(
                p, t, pool, tab, pos, lv, cfg, rules))
        self._chunk = jax.jit(
            lambda p, t, pool, row, start, valid: lm.prefill_chunk(
                p, t, pool, row, start, valid, cfg, rules))

    # -- observability -------------------------------------------------------
    @property
    def n_live(self) -> int:
        return sum(s is not None for s in self.slots)

    @property
    def n_waiting(self) -> int:
        return len(self.waiting)

    @property
    def capacity(self) -> int:
        return self.scfg.max_batch

    def kv_bytes_resident(self) -> int:
        return self.alloc.n_allocated * self.scfg.block_tokens \
            * kv_token_bytes(self.cfg)

    def kv_bytes_resident_peak(self) -> int:
        return self.alloc.peak_allocated * self.scfg.block_tokens \
            * kv_token_bytes(self.cfg)

    # -- admission -----------------------------------------------------------
    def submit(self, req: Request):
        plen = validate_prompt(req.prompt, self.scfg.max_seq)
        bt = self.scfg.block_tokens
        worst = min(math.ceil((plen + req.max_new_tokens) / bt),
                    self.max_blocks)
        if worst > self.scfg.n_blocks:
            raise ValueError(
                f"request needs up to {worst} blocks but the pool holds "
                f"{self.scfg.n_blocks}")
        self.waiting.append(req)

    def _plan(self, req: Request):
        """Admission plan: (table row, owned (blk_idx, key-or-None) list,
        shared bids, reservation).  None if the pool cannot cover this
        request's worst case plus every outstanding reservation."""
        bt = self.scfg.block_tokens
        prompt = np.asarray(req.prompt)
        plen = len(prompt)
        nfull = plen // bt
        row: list[int] = []
        own: list[tuple[int, tuple | None]] = []   # (blk_idx, registry key)
        shared: list[int] = []
        partial_shared = False
        for j in range(nfull):
            key = ("full", tuple(int(t) for t in prompt[:(j + 1) * bt]))
            bid = self.alloc.lookup(key)
            if bid is not None:
                row.append(bid)
                shared.append(bid)
            else:
                row.append(-1)
                own.append((j, key))
        if plen % bt:
            key = ("part", tuple(int(t) for t in prompt))
            bid = self.alloc.lookup(key)
            if bid is not None:
                row.append(bid)
                shared.append(bid)
                partial_shared = True
            else:
                row.append(-1)
                own.append((nfull, key))
        prompt_blocks = len(row)
        total = min(math.ceil((plen + req.max_new_tokens) / bt),
                    self.max_blocks)
        growth = total - prompt_blocks
        # reservation: decode-time growth blocks, plus one COW copy if the
        # partial block is shared (full shared blocks are never written)
        reserve = growth + (1 if partial_shared else 0)
        need_now = len(own)
        if self.alloc.n_free < need_now + reserve + int(self.slot_reserve.sum()):
            return None
        return row, own, shared, reserve

    def _free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def _admit(self):
        free = self._free_slots()
        while free and self.waiting:
            plan = self._plan(self.waiting[0])
            if plan is None:
                break                       # head-of-line waits for blocks
            row, own, shared, reserve = plan
            req = self.waiting.pop(0)
            slot = free.pop(0)
            req.slot = slot
            for bid in shared:
                self.alloc.retain(bid)
            new_bids = []
            chunked = bool(self.scfg.chunk)
            for j, key in own:
                # chunked prefill registers keys only once the content is
                # fully written (prefill completion), so a concurrent
                # admit never shares a half-filled block
                bid = self.alloc.alloc(None if chunked else key)
                row[row.index(-1)] = bid
                new_bids.append((j, bid))
            self._slot_new[slot] = new_bids
            self.tables[slot] = 0
            self.tables[slot, :len(row)] = row
            self.slot_reserve[slot] = reserve
            self.slots[slot] = req
            self.peak_live = max(self.peak_live, self.n_live)
            if chunked:
                self.slot_state[slot] = PREFILL
                self.slot_fill[slot] = 0
                self.slot_pos[slot] = 0
            else:
                self._prefill_whole(slot, req, new_bids)

    def _prefill_whole(self, slot: int, req: Request,
                       new_bids: list[tuple[int, int]]):
        """Non-chunked admission: run the *same* jitted prefill as the
        dense engine (identical first token and cache values), then
        scatter the newly-owned blocks of the dense cache into the pool —
        shared blocks already hold identical content and are skipped."""
        bt = self.scfg.block_tokens
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        cache, logits = self._prefill(self.params, toks)
        req.out.append(int(jnp.argmax(logits[0, -1])))
        if new_bids:
            js = jnp.asarray([j for j, _ in new_bids])
            bids = jnp.asarray([b for _, b in new_bids])

            def put(pool_leaf, cache_leaf):
                P = pool_leaf.shape[0]
                H, D = pool_leaf.shape[-2:]
                blocks = cache_leaf[:, 0].reshape(P, self.max_blocks, bt,
                                                  H, D)
                return pool_leaf.at[:, bids].set(blocks[:, js])

            self.pool = jax.tree.map(put, self.pool, cache)
        self.slot_state[slot] = DECODE
        self.slot_pos[slot] = len(req.prompt)

    # -- chunked prefill -----------------------------------------------------
    def _prefill_step(self) -> bool:
        """Run ONE prefill chunk for the lowest-index PREFILL slot (the
        interleave: at most one chunk of prefill work per engine step, so
        the decode batch never waits on a whole long prompt)."""
        pf = [i for i, s in enumerate(self.slots)
              if s is not None and self.slot_state[i] == PREFILL]
        if not pf:
            return False
        i = pf[0]
        req = self.slots[i]
        c = self.scfg.chunk
        prompt = np.asarray(req.prompt)
        plen = len(prompt)
        start = int(self.slot_fill[i])
        valid = min(c, plen - start)
        chunk = np.zeros((1, c), np.int32)
        chunk[0, :valid] = prompt[start:start + valid]
        logits, self.pool = self._chunk(
            self.params, jnp.asarray(chunk), self.pool,
            jnp.asarray(self.tables[i]), jnp.int32(start), jnp.int32(valid))
        self.prefill_chunks += 1
        self.slot_fill[i] = start + valid
        if self.slot_fill[i] >= plen:
            req.out.append(int(jnp.argmax(logits[0, valid - 1])))
            self.slot_state[i] = DECODE
            self.slot_pos[i] = plen
            # content now complete: publish the owned prompt blocks
            bt = self.scfg.block_tokens
            nfull = plen // bt
            for j, bid in self._slot_new[i]:
                if j < nfull:
                    key = ("full", tuple(int(t) for t in prompt[:(j + 1) * bt]))
                else:
                    key = ("part", tuple(int(t) for t in prompt))
                self.alloc.register(bid, key)
            self._slot_new[i] = []
        return True

    # -- decode --------------------------------------------------------------
    def _ensure_writable(self, i: int):
        """Pre-step guarantee for slot i: the block holding position
        ``slot_pos[i]`` exists, is exclusively owned, and carries no
        registry key — so the jitted step's scatter is a plain write.
        On-demand alloc and COW both draw on the slot's reservation."""
        bt = self.scfg.block_tokens
        j = int(self.slot_pos[i]) // bt
        bid = int(self.tables[i, j])
        if bid == 0:
            self.tables[i, j] = self.alloc.alloc()
            self.slot_reserve[i] = max(0, self.slot_reserve[i] - 1)
        elif self.alloc.refcount[bid] > 1:
            nb = self.alloc.alloc()
            self.pool = jax.tree.map(
                lambda pl: pl.at[:, nb].set(pl[:, bid]), self.pool)
            self.alloc.release(bid)
            self.tables[i, j] = nb
            self.cow_copies += 1
            self.slot_reserve[i] = max(0, self.slot_reserve[i] - 1)
        else:
            self.alloc.forget_key(bid)

    def _decode_live(self) -> list[int]:
        return [i for i, s in enumerate(self.slots)
                if s is not None and self.slot_state[i] == DECODE]

    def _retire(self, i: int):
        req = self.slots[i]
        req.done = True
        self.finished.append(req)
        for j in range(self.max_blocks):
            bid = int(self.tables[i, j])
            if bid:
                self.alloc.release(bid)
        self.tables[i] = 0
        self.slot_pos[i] = 0
        self.slot_fill[i] = 0
        self.slot_reserve[i] = 0
        self.slot_state[i] = DECODE
        self._slot_new[i] = []
        self.slots[i] = None

    def step(self) -> bool:
        self._admit()
        worked = False
        if self.scfg.chunk:
            worked |= self._prefill_step()
        live = self._decode_live()
        if live:
            for i in live:
                self._ensure_writable(i)
            B = self.scfg.max_batch
            tok = np.zeros((B, 1), np.int32)
            lv = np.zeros(B, bool)
            for i in live:
                tok[i, 0] = self.slots[i].out[-1]
                lv[i] = True
            logits, self.pool = self._step(
                self.params, jnp.asarray(tok), self.pool,
                jnp.asarray(self.tables), jnp.asarray(self.slot_pos),
                jnp.asarray(lv))
            self.decode_steps += 1
            nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
            for i in live:
                req = self.slots[i]
                t = int(nxt[i])
                req.out.append(t)
                self.slot_pos[i] += 1
                if t == self.scfg.eos_id or \
                        len(req.out) >= req.max_new_tokens or \
                        self.slot_pos[i] >= self.scfg.max_seq - 1:
                    self._retire(i)
            worked = True
        return worked

    def run(self, max_steps: int = 10_000):
        for _ in range(max_steps):
            if not self.step() and not self.waiting:
                break
        return self.finished

    def shutdown(self) -> None:
        """End-of-life hygiene: refuse to shut down over live work, then
        require the allocator quiescent (:class:`BlockLeakError` names any
        leaked blocks).  Callers that drain to completion (the traffic
        generator, the acceptance checks) call this so a refcount bug
        fails the run loudly instead of surviving as a slow pool leak."""
        live = [i for i, s in enumerate(self.slots) if s is not None]
        if live or self.waiting:
            raise BlockLeakError(
                f"shutdown with work in flight: live slots {live}, "
                f"{len(self.waiting)} waiting requests")
        self.alloc.assert_quiescent()
