"""Serving launcher: batched requests against a (smoke or full) model."""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.parallel.sharding import default_rules, init_params
from repro.serve import Request, ServeConfig, ServingEngine
from repro.testing.timing import now


def run(arch: str, *, smoke: bool = True, n_requests: int = 6,
        max_new: int = 16, max_batch: int = 4, max_seq: int = 128,
        seed: int = 0):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    rules = default_rules(None)
    params = init_params(lm.model_defs(cfg), jax.random.key(seed))
    eng = ServingEngine(cfg, params, rules,
                        ServeConfig(max_batch=max_batch, max_seq=max_seq))
    rng = np.random.default_rng(seed)
    t0 = now()
    for rid in range(n_requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
        eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=max_new))
    finished = eng.run()
    dt = now() - t0
    toks = sum(len(r.out) for r in finished)
    print(f"[serve] {len(finished)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile)")
    return finished


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    run(args.arch, n_requests=args.requests, max_new=args.max_new)


if __name__ == "__main__":
    main()
