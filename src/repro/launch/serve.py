"""Serving launcher: batched requests against a (smoke or full) model.

``--paged`` swaps the dense per-slot KV cache for the block-table pool
(``repro.serve.paged``) — ``--block-tokens`` sizes the blocks (0 = ask the
autotune table via :func:`repro.kernels.ops.paged_block_tokens`) and
``--chunk`` enables chunked prefill.  ``--pods N`` splits the request
stream across N engines behind the prefix-affinity router
(``repro.serve.router``), the cross-pod scale-out path.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.parallel.sharding import default_rules, init_params
from repro.serve import (PagedServeConfig, PagedServingEngine, PrefixRouter,
                         Request, ServeConfig, ServingEngine)
from repro.testing.timing import now


def _make_engine(cfg, params, rules, *, paged: bool, max_batch: int,
                 max_seq: int, block_tokens: int, chunk: int):
    if not paged:
        return ServingEngine(cfg, params, rules,
                             ServeConfig(max_batch=max_batch,
                                         max_seq=max_seq))
    if block_tokens <= 0:
        from repro.kernels.ops import paged_block_tokens
        block_tokens = paged_block_tokens(
            max_batch, cfg.n_heads, cfg.n_kv_heads, max_seq,
            cfg.d_model // cfg.n_heads, cfg.dtype)
    scfg = PagedServeConfig(max_batch=max_batch, max_seq=max_seq,
                            block_tokens=block_tokens,
                            n_blocks=max_batch * max_seq // block_tokens,
                            chunk=chunk)
    return PagedServingEngine(cfg, params, rules, scfg)


def run(arch: str, *, smoke: bool = True, n_requests: int = 6,
        max_new: int = 16, max_batch: int = 4, max_seq: int = 128,
        paged: bool = False, block_tokens: int = 0, chunk: int = 0,
        pods: int = 1, seed: int = 0):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    rules = default_rules(None)
    params = init_params(lm.model_defs(cfg), jax.random.key(seed))
    engines = [_make_engine(cfg, params, rules, paged=paged,
                            max_batch=max_batch, max_seq=max_seq,
                            block_tokens=block_tokens, chunk=chunk)
               for _ in range(max(pods, 1))]
    front = engines[0] if len(engines) == 1 else PrefixRouter(engines)
    rng = np.random.default_rng(seed)
    t0 = now()
    for rid in range(n_requests):
        plen = int(rng.integers(4, 24))
        prompt = rng.integers(1, cfg.vocab_size, plen).astype(np.int32)
        front.submit(Request(rid=rid, prompt=prompt, max_new_tokens=max_new))
    finished = front.run()
    dt = now() - t0
    toks = sum(len(r.out) for r in finished)
    mode = ("paged+chunked" if paged and chunk else
            "paged" if paged else "dense")
    pods_txt = f" pods={len(engines)}" if len(engines) > 1 else ""
    print(f"[serve] {len(finished)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s incl. compile) [{mode}{pods_txt}]")
    return finished


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--paged", action="store_true",
                    help="block-table KV pool instead of dense slots")
    ap.add_argument("--block-tokens", type=int, default=0,
                    help="tokens per KV block (0 = autotune table)")
    ap.add_argument("--chunk", type=int, default=0,
                    help="chunked-prefill chunk size (0 = whole-prompt)")
    ap.add_argument("--pods", type=int, default=1,
                    help="engines behind the prefix-affinity router")
    args = ap.parse_args()
    run(args.arch, n_requests=args.requests, max_new=args.max_new,
        max_batch=args.max_batch, max_seq=args.max_seq, paged=args.paged,
        block_tokens=args.block_tokens, chunk=args.chunk, pods=args.pods)


if __name__ == "__main__":
    main()
