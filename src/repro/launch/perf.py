"""§Perf hillclimbing: hypothesis -> change -> re-lower -> re-analyse.

Each named STRATEGY is one candidate change against the paper-faithful
baseline; the runner produces the same per-cell roofline record as
launch.dryrun so before/after is directly comparable.

  baseline    the dry-run configuration (TP over `model` + FSDP + SP)
  fsdp_pure   no TP: params fully sharded over ALL axes, batch over all axes
              (ZeRO-3 / pure-DP; kills the per-layer TP all-reduces)
  moe_a2a     token all-to-all expert parallelism (GLSU shuffle) instead of
              replicated-token psum-combine
  nm_half/nm1 fewer, larger microbatches (fewer FSDP gathers, more act mem)

Usage:
  python -m repro.launch.perf --arch llama3-8b --shape train_4k \
      --strategy baseline --strategy fsdp_pure --out results/perf
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import pathlib
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.launch import dryrun as dr
from repro.launch.mesh import (make_production_mesh, parse_launch_topology,
                               topology_tag)
from repro.parallel.sharding import ShardingRules, default_rules
from repro.topology import Topology


def _fsdp_pure_rules(mesh, cfg, shape):
    """Map batch AND fsdp over every mesh axis; no TP ('model' unused)."""
    names = tuple(mesh.axis_names)
    all_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    total = 1
    for a in all_axes:
        total *= mesh.shape[a]
    rules = {
        "batch": all_axes if shape.global_batch % total == 0 else
        tuple(a for a in ("pod", "data") if a in mesh.shape),
        "seq": None,
        "fsdp": all_axes,
        "model": None,
        "kv": None,
        "cache_seq": "model" if shape.is_decode else None,
        "act_seq": None,
    }
    return ShardingRules(mesh, rules)


def apply_strategy(strategy: str, cfg, shape, mesh):
    """Returns (cfg', rules_override, n_micro_override)."""
    if strategy == "baseline":
        return cfg, None, None
    if strategy == "fsdp_pure":
        return cfg, _fsdp_pure_rules(mesh, cfg, shape), 1
    if strategy == "moe_a2a":
        return dataclasses.replace(cfg, moe_impl="a2a"), None, None
    if strategy == "nm_half":
        nm = max(1, dr.n_microbatches(cfg, shape, mesh) // 2)
        return cfg, None, nm
    if strategy == "nm1":
        return cfg, None, 1
    if strategy == "moe_a2a_nm_half":
        nm = max(1, dr.n_microbatches(cfg, shape, mesh) // 2)
        return dataclasses.replace(cfg, moe_impl="a2a"), None, nm
    raise ValueError(strategy)


def analyse(arch: str, shape_name: str, strategy: str, multi: bool = False,
            topology: Topology | None = None):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi, topology=topology)
    mname = (topology_tag(topology) if topology is not None else
             "pod2x16x16" if multi else "pod16x16")
    cfg, rules_override, nm_override = apply_strategy(strategy, cfg, shape,
                                                      mesh)
    # monkey-patch the dryrun cell builder's rules when overridden
    if rules_override is not None:
        orig = dr.build_rules
        dr.build_rules = lambda *a, **k: rules_override
    try:
        if nm_override is not None:
            orig_nm = dr.n_microbatches
            dr.n_microbatches = lambda *a, **k: nm_override
        try:
            rec = dr.analyse_cell(cfg, shape, mesh, mname)
        finally:
            if nm_override is not None:
                dr.n_microbatches = orig_nm
    finally:
        if rules_override is not None:
            dr.build_rules = orig
    rec["strategy"] = strategy
    if topology is not None:
        rec["topology"] = topology.describe()
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--strategy", action="append", required=True)
    ap.add_argument("--topology", default=None,
                    metavar="[P x]CxL[:hierarchy]",
                    help="override the mesh with an explicit Topology "
                         "(clusters on `data`, lanes on `model`; a third "
                         "leading size adds the `pod` ring level)")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    topo = (parse_launch_topology(args.topology)
            if args.topology is not None else None)
    tsuffix = f"__{topology_tag(topo)}" if topo is not None else ""
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    for strat in args.strategy:
        path = out / f"{args.arch}__{args.shape}__{strat}{tsuffix}.json"
        if path.exists():
            print(f"[cached] {path}")
            continue
        try:
            rec = analyse(args.arch, args.shape, strat, topology=topo)
            path.write_text(json.dumps(rec, indent=2))
            r = rec["roofline"]
            print(f"[ok] {args.arch} x {args.shape} x {strat}: "
                  f"compute={r['compute_s']:.3f}s mem={r['memory_s']:.3f}s "
                  f"coll={r['collective_s']:.3f}s bound={r['bottleneck']} "
                  f"mfu_ub={r['mfu_upper_bound']:.3f} "
                  f"res={rec['mem_per_device']['resident_model_gib']:.1f}GiB",
                  flush=True)
        except Exception as e:
            print(f"[FAIL] {strat}: {e}")
            traceback.print_exc()


if __name__ == "__main__":
    main()
