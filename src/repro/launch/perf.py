"""§Perf hillclimbing: hypothesis -> change -> re-lower -> re-analyse.

Each named STRATEGY is one candidate change against the paper-faithful
baseline; the runner produces the same per-cell roofline record as
launch.dryrun so before/after is directly comparable.  Strategy overrides
are plain arguments on ``dryrun.analyse_cell`` (``rules=`` / ``n_micro=`` /
``grad_sync=``) — no module-global mutation.

  baseline    the dry-run configuration (TP over `model` + FSDP + SP)
  fsdp_pure   no TP: params fully sharded over ALL axes, batch over all axes
              (ZeRO-3 / pure-DP; kills the per-layer TP all-reduces)
  fsdp_hier   pod-local FSDP (HSDP): params sharded over the INNER topology
              levels only and replicated across the outermost (pod) ring;
              the gradient sync reduce-scatters level by level — inner rings
              first, pod ring last, like core.ring's hierarchical
              reduce-scatter — via the make_grad_sync hook, so the pod
              wires only ever carry the 1/|inner|-sized gradient shard
  fsdp_hier_ov fsdp_hier with the *bucketed, backward-overlapped* gradient
              sync (make_grad_sync(bucket_mb=...)): reverse-order gradient
              buckets fenced by optimization_barrier, so each bucket's
              inner-ring reduce-scatter launches as its grads become ready
              and overlaps the remaining backward compute (pod ring still
              last); grad-equivalent to fsdp_hier, the roofline record adds
              the exposed (non-overlappable) collective seconds per level
  moe_a2a     token all-to-all expert parallelism (GLSU shuffle) instead of
              replicated-token psum-combine
  nm_half/nm1 fewer, larger microbatches (fewer FSDP gathers, more act mem)

Usage:
  python -m repro.launch.perf --arch llama3-8b --shape train_4k \
      --strategy baseline --strategy fsdp_pure --out results/perf
  python -m repro.launch.perf --arch llama3-8b --shape train_4k --mesh multi \
      --strategy fsdp_pure --strategy fsdp_hier       # pod-ring ablation
"""
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import pathlib
import traceback

from repro.configs import SHAPES, get_config, get_smoke_config
from repro.launch import dryrun as dr
from repro.launch.mesh import (make_production_mesh, parse_launch_topology,
                               production_topology, topology_tag)
from repro.parallel.sharding import ShardingRules
from repro.topology import Topology
from repro.train import make_grad_sync


#: bucket size for the backward-overlapped gradient sync (fsdp_hier_ov):
#: ~25 MiB per bucket keeps each inner-ring reduce-scatter long enough to
#: amortise launch overhead yet small enough that the first bucket is on
#: the wires while most of the backward pass is still streaming
GRAD_BUCKET_MB = 25.0


def _all_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data", "model") if a in mesh.shape)


def _axes_size(mesh, axes) -> int:
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return total


def _fsdp_pure_rules(mesh, cfg, shape):
    """Map batch AND fsdp over every mesh axis; no TP ('model' unused)."""
    all_axes = _all_axes(mesh)
    rules = {
        "batch": all_axes if shape.global_batch % _axes_size(mesh, all_axes)
        == 0 else tuple(a for a in ("pod", "data") if a in mesh.shape),
        "seq": None,
        "fsdp": all_axes,
        "model": None,
        "kv": None,
        "cache_seq": "model" if shape.is_decode else None,
        "act_seq": None,
    }
    return ShardingRules(mesh, rules)


def _fsdp_hier_rules(mesh, cfg, shape, topology: Topology):
    """fsdp_pure, made pod-local: params shard over the *inner* topology
    levels only (each pod holds a full shard-group replica), so every FSDP
    all-gather stays off the pod ring and the cross-pod gradient sync runs
    on 1/|inner|-sized shards.  Every other rule — batch included — is the
    fsdp_pure mapping, so the compute side of the two strategies is
    identical and the ablation isolates the sync schedule."""
    inner = tuple(a for l in topology.levels[1:] for a in l.axes
                  if a in mesh.shape)
    if not inner:                      # single-level machine: nothing inner
        inner = _all_axes(mesh)
    base = _fsdp_pure_rules(mesh, cfg, shape)
    return ShardingRules(mesh, {**base.rules, "fsdp": inner})


def apply_strategy(strategy: str, cfg, shape, mesh, topology: Topology):
    """Returns (cfg', rules_override, n_micro_override, grad_sync)."""
    if strategy == "baseline":
        return cfg, None, None, None
    if strategy == "fsdp_pure":
        return cfg, _fsdp_pure_rules(mesh, cfg, shape), 1, None
    if strategy == "fsdp_hier":
        rules = _fsdp_hier_rules(mesh, cfg, shape, topology)
        return cfg, rules, 1, make_grad_sync(cfg, rules)
    if strategy == "fsdp_hier_ov":
        rules = _fsdp_hier_rules(mesh, cfg, shape, topology)
        return cfg, rules, 1, make_grad_sync(cfg, rules,
                                             bucket_mb=GRAD_BUCKET_MB)
    if strategy == "moe_a2a":
        return dataclasses.replace(cfg, moe_impl="a2a"), None, None, None
    if strategy == "nm_half":
        nm = max(1, dr.n_microbatches(cfg, shape, mesh) // 2)
        return cfg, None, nm, None
    if strategy == "nm1":
        return cfg, None, 1, None
    if strategy == "moe_a2a_nm_half":
        nm = max(1, dr.n_microbatches(cfg, shape, mesh) // 2)
        return dataclasses.replace(cfg, moe_impl="a2a"), None, nm, None
    raise ValueError(strategy)


def analyse(arch: str, shape_name: str, strategy: str, multi: bool = False,
            topology: Topology | None = None, smoke: bool = False):
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi, topology=topology)
    topo = topology if topology is not None else \
        production_topology(multi_pod=multi)
    mname = (topology_tag(topo) if topology is not None else
             "pod2x16x16" if multi else "pod16x16")
    cfg, rules, nm, gsync = apply_strategy(strategy, cfg, shape, mesh, topo)
    rec = dr.analyse_cell(cfg, shape, mesh, mname, topology=topo,
                          rules=rules, n_micro=nm, grad_sync=gsync)
    rec["strategy"] = strategy
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--strategy", action="append", required=True)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single",
                    help="production pod mesh (multi = the three-level "
                         "2x16x16 machine)")
    ap.add_argument("--topology", default=None,
                    metavar="[P x]CxL[:hierarchy]",
                    help="override the mesh with an explicit Topology "
                         "(clusters on `data`, lanes on `model`; a third "
                         "leading size adds the `pod` ring level)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family smoke config (CI-sized "
                         "compiles; artifacts are tagged by the smoke name)")
    ap.add_argument("--out", default="results/perf")
    args = ap.parse_args()
    if args.topology is not None and args.mesh != "single":
        ap.error("--topology replaces the pod mesh entirely; drop --mesh")
    topo = (parse_launch_topology(args.topology)
            if args.topology is not None else None)
    tsuffix = f"__{topology_tag(topo)}" if topo is not None else \
        ("__pod2x16x16" if args.mesh == "multi" else "")
    if args.smoke:
        tsuffix += "__smoke"
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    failures = []
    for strat in args.strategy:
        path = out / f"{args.arch}__{args.shape}__{strat}{tsuffix}.json"
        if path.exists():
            print(f"[cached] {path}")
            continue
        try:
            rec = analyse(args.arch, args.shape, strat,
                          multi=args.mesh == "multi", topology=topo,
                          smoke=args.smoke)
            path.write_text(json.dumps(rec, indent=2))
            r = rec["roofline"]
            lv = r.get("collective_s_by_level", {})
            lv_txt = " ".join(f"{k}={v:.4f}s" for k, v in lv.items())
            print(f"[ok] {args.arch} x {args.shape} x {strat}: "
                  f"compute={r['compute_s']:.3f}s mem={r['memory_s']:.3f}s "
                  f"coll={r['collective_s']:.3f}s [{lv_txt}] "
                  f"bound={r['bottleneck']} "
                  f"mfu_ub={r['mfu_upper_bound']:.3f} "
                  f"res={rec['mem_per_device']['resident_model_gib']:.1f}GiB",
                  flush=True)
        except Exception as e:
            # keep sweeping: later strategies still produce their artifacts
            failures.append(strat)
            print(f"[FAIL] {strat}: {e}")
            traceback.print_exc()
    if failures:
        print(f"{len(failures)} strategy failures: {failures}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
