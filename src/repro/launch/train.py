"""End-to-end training launcher (CPU-scale runs + the production recipe).

``python -m repro.launch.train --arch llama3-8b --smoke --steps 50`` trains
the reduced config on local devices; on a pod the same script runs the full
config on the production mesh with checkpoint/restart and straggler
monitoring wired in.

``--chaos`` switches to the **chaos-tested elastic** harness
(:func:`run_chaos`): N training steps on the local (8-fake-device) mesh
while a deterministic fault injector (``repro.ft.chaos``) kills and
straggles simulated hosts on a virtual clock.  A detected loss triggers the
restart state machine — RestartPolicy backoff, ``plan_rescale`` onto the
survivors, sharding rules re-derived from the logical table
(``ft.rescale_rules``), cross-mesh checkpoint restore, and bit-identical
``(seed, step)`` batch replay from the data pipeline's cursor.  See
``docs/RESILIENCE.md`` and ``repro.testing.check_chaos``.
"""
from __future__ import annotations

import argparse
import dataclasses
import tempfile
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, restore_checkpoint
from repro.checkpoint.ckpt import latest_step, tear_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, Pipeline, make_pipeline
from repro.ft import (ChaosSchedule, FaultInjector, HeartbeatMonitor,
                      RestartPolicy, StragglerMitigator, plan_rescale,
                      rescale_rules)
from repro.models import lm
from repro.parallel.sharding import (abstract_params, default_rules,
                                     init_params, param_shardings)
from repro.testing.timing import now
from repro.train import (OptConfig, TrainState, abstract_train_state,
                         make_train_step, train_state_shardings)
from repro.train.optimizer import adamw_init


def run(arch: str, *, smoke: bool = True, steps: int = 50,
        global_batch: int = 8, seq_len: int = 64, lr: float = 3e-3,
        ckpt_dir: str | None = None, ckpt_every: int = 25,
        n_microbatches: int = 1, resume: bool = True, log_every: int = 10,
        seed: int = 0) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    rules = default_rules(None)          # single-process CPU run
    opt_cfg = OptConfig(lr=lr, warmup_steps=max(2, steps // 10),
                        total_steps=steps)

    key = jax.random.key(seed)
    params = init_params(lm.model_defs(cfg), key)
    state = TrainState(params, adamw_init(params, opt_cfg))

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=global_batch, seed=seed)
    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume and latest_step(ckpt_dir) is not None:
        state, start_step, _ = restore_checkpoint(ckpt_dir, state)
        start_step = int(start_step)
        print(f"[train] resumed from step {start_step}")

    pipe = make_pipeline(dcfg, start_step=start_step)
    step_fn = jax.jit(make_train_step(cfg, rules, opt_cfg,
                                      n_microbatches=n_microbatches))

    monitor = HeartbeatMonitor(n_hosts=1)
    straggler = StragglerMitigator()
    losses = []
    t_prev = now()
    for step in range(start_step, steps):
        tokens = jnp.asarray(next(pipe))
        batch = {"tokens": tokens}
        if cfg.family in ("encdec", "vlm"):
            rng = np.random.default_rng(step)
            T = lm.context_len(cfg, seq_len)
            batch["ctx"] = jnp.asarray(
                rng.normal(size=(global_batch, T, cfg.d_ctx)) * 0.1,
                jnp.float32)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = now() - t_prev
        t_prev = now()
        monitor.beat(0, step, dt)
        straggler.update({0: monitor.hosts[0].ewma_step_s})
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} ({dt*1e3:.0f} ms)",
                  flush=True)
        if mgr and (step + 1) % ckpt_every == 0:
            mgr.save_async(state, step + 1)
    if mgr:
        mgr.wait()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "start_step": start_step}


# ---------------------------------------------------------------------------
# Chaos-tested elastic training
# ---------------------------------------------------------------------------

def _fingerprint(batch: np.ndarray) -> int:
    """Byte-exact batch identity: the replay assertion currency."""
    return zlib.crc32(np.ascontiguousarray(batch).tobytes())


def _host_mesh(devices, dp: int, model: int):
    from jax.sharding import Mesh
    return Mesh(np.array(devices[: dp * model]).reshape(dp, model),
                ("data", "model"))


def _place_state(cfg, opt_cfg, seed: int, rules) -> TrainState:
    """Deterministic init (pure function of ``seed``) placed under
    ``rules`` — fresh starts and post-rescale cold starts are identical."""
    key = jax.random.key(seed)
    params = init_params(lm.model_defs(cfg), key)
    state = TrainState(params, adamw_init(params, opt_cfg))
    if rules.mesh is not None:
        state = jax.device_put(state,
                               train_state_shardings(cfg, opt_cfg, rules))
    return state


def run_chaos(arch: str = "llama3-8b", *, steps: int = 12,
              chaos_seed: int = 0, chaos_spec: str | None = None,
              n_hosts: int = 2, model_axis: int = 2, global_batch: int = 8,
              seq_len: int = 32, lr: float = 3e-3, seed: int = 0,
              ckpt_dir: str | None = None, ckpt_every: int = 2,
              timeout_s: float = 3.5, base_step_s: float = 1.0,
              max_restarts: int = 3, backoff_s: float = 1.0,
              n_microbatches: int = 1, log_every: int = 1,
              n_kills: int = 1, n_straggles: int = 1,
              n_ckpt_crashes: int = 0, verbose: bool = True) -> dict:
    """One elastic training run under injected faults (the tentpole loop).

    The local devices are partitioned into ``n_hosts`` simulated hosts
    (host h owns a contiguous block of whole data-parallel rows).  Each
    step: pull the cursor's batch, train, then ``injector.tick`` — beats,
    straggle decay, and fault events on the virtual clock.  When the
    monitor times a host out (or the mitigator demands an eviction), the
    restart state machine runs:

        BACKOFF  RestartPolicy.next_delay (virtual seconds, budget-limited)
        RESCALE  plan_rescale drops the lost hosts' dp rows, model axis
                 intact; ft.rescale_rules re-derives the sharding rules on
                 the survivor mesh
        RESTORE  restore_checkpoint onto the new mesh's shardings (newest
                 checkpoint passing the torn-write gate; fresh determinstic
                 init if none exists yet)
        REPLAY   the data pipeline is rebuilt at the restored cursor — the
                 stream is a pure function of (seed, step), so every batch
                 after restart is byte-identical to the uninterrupted run

    Returns per-step losses/batch fingerprints plus a restart log; loss-
    curve continuity against a fault-free run is asserted by
    ``repro.testing.check_chaos`` (fp tolerance across the mesh change).
    """
    devices = jax.devices()
    n_dev = len(devices)
    if n_dev % n_hosts:
        raise ValueError(f"{n_dev} devices not divisible into "
                         f"{n_hosts} hosts")
    devices_per_host = n_dev // n_hosts
    if n_dev % model_axis or devices_per_host % model_axis:
        raise ValueError(
            f"model axis {model_axis} must divide both the device count "
            f"{n_dev} and devices/host {devices_per_host} (hosts own whole "
            f"dp rows — AraXL loses clusters, never lanes)")
    dp = n_dev // model_axis

    cfg = get_smoke_config(arch)
    opt_cfg = OptConfig(lr=lr, warmup_steps=max(2, steps // 10),
                        total_steps=steps)
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=global_batch, seed=seed)
    if ckpt_dir is None:
        ckpt_dir = tempfile.mkdtemp(prefix="repro_chaos_ckpt_")
    mgr = CheckpointManager(ckpt_dir, keep=3)

    schedule = (ChaosSchedule.parse(chaos_spec) if chaos_spec is not None
                else ChaosSchedule.from_seed(
                    chaos_seed, steps=steps, n_hosts=n_hosts,
                    n_kills=n_kills, n_straggles=n_straggles,
                    n_ckpt_crashes=n_ckpt_crashes))
    injector = FaultInjector(schedule, n_hosts=n_hosts, timeout_s=timeout_s,
                             base_step_s=base_step_s)
    policy = RestartPolicy(max_restarts=max_restarts, backoff_s=backoff_s,
                           clock=injector.clock)

    mesh = _host_mesh(devices, dp, model_axis)
    rules = default_rules(mesh, batch=global_batch)
    state = _place_state(cfg, opt_cfg, seed, rules)
    step_fn = jax.jit(make_train_step(cfg, rules, opt_cfg,
                                      n_microbatches=n_microbatches))
    pipe = Pipeline(dcfg, start_step=0)

    losses_by_step: dict[int, float] = {}
    fingerprints: dict[int, int] = {}
    restarts: list[dict] = []
    timeline: list[dict] = []
    tear_next_save = False
    steps_executed = 0
    step = 0
    while step < steps:
        assert pipe.cursor == step, (pipe.cursor, step)
        batch_np = next(pipe)
        fp = _fingerprint(batch_np)
        prev = fingerprints.get(step)
        assert prev is None or prev == fp, \
            f"replay diverged at step {step}: {prev} != {fp}"
        fingerprints[step] = fp
        state, metrics = step_fn(state, {"tokens": jnp.asarray(batch_np)})
        loss = float(metrics["loss"])
        losses_by_step[step] = loss
        steps_executed += 1

        status = injector.tick(step)
        tear_next_save = tear_next_save or status.tear_next_save
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"[chaos] step {step:4d} loss {loss:8.4f} "
                  f"mesh {dict(mesh.shape)} t={injector.clock():.1f}s "
                  f"alive={sorted(injector.alive)}", flush=True)

        if (step + 1) % ckpt_every == 0:
            mgr.save_async(state, step + 1,
                           extra={"mesh_shape": list(mesh.devices.shape),
                                  "global_batch": global_batch,
                                  "data_cursor": pipe.cursor})
            if tear_next_save:
                mgr.wait()                     # durable, then corrupted
                tear_checkpoint(ckpt_dir, step + 1)
                timeline.append({"step": step, "event": "ckpt_torn",
                                 "ckpt_step": step + 1})
                tear_next_save = False

        lost = status.lost
        if lost:
            mgr.wait()                         # flush + surface async errors
            if not policy.should_restart():
                raise RuntimeError(
                    f"restart budget exhausted after {policy.restarts} "
                    f"restarts (lost hosts {lost})")
            delay = policy.next_delay()
            injector.clock.advance(delay)      # virtual backoff, no sleep
            injector.evict(lost)
            restore_step = latest_step(ckpt_dir) or 0
            plan = plan_rescale(
                old_devices=mesh.devices.size, lost_hosts=len(lost),
                devices_per_host=devices_per_host,
                mesh_axes=tuple(mesh.devices.shape),
                global_batch=global_batch, restore_step=restore_step)
            if plan.new_global_batch != global_batch:
                raise ValueError(
                    f"global batch {global_batch} not divisible by the "
                    f"rescaled dp={plan.new_mesh_shape[0]} — bit-identical "
                    f"replay needs a batch divisible by every survivable "
                    f"dp size ({plan.notes})")
            mesh, rules = rescale_rules(plan, injector.failed,
                                        devices_per_host, devices=devices)
            if latest_step(ckpt_dir) is not None:
                state, rstep, _ = restore_checkpoint(
                    ckpt_dir, abstract_train_state(cfg, opt_cfg),
                    shardings=train_state_shardings(cfg, opt_cfg, rules))
                rstep = int(rstep)
            else:                              # killed before the first save
                state, rstep = _place_state(cfg, opt_cfg, seed, rules), 0
            step_fn = jax.jit(make_train_step(cfg, rules, opt_cfg,
                                              n_microbatches=n_microbatches))
            pipe.close()
            pipe = Pipeline(dcfg, start_step=rstep)
            restarts.append({
                "detected_at_step": step, "lost_hosts": list(lost),
                "restore_step": rstep, "backoff_s": delay,
                "new_mesh_shape": list(plan.new_mesh_shape),
                "new_devices": plan.new_devices, "notes": plan.notes})
            timeline.append({"step": step, "event": "restart",
                             "lost": list(lost), "restore_step": rstep})
            if verbose:
                print(f"[chaos] RESTART #{len(restarts)}: lost {list(lost)} "
                      f"at step {step}, backoff {delay:.1f}s, restored "
                      f"step {rstep} onto {plan.new_mesh_shape} "
                      f"({plan.notes})", flush=True)
            step = rstep
            continue
        step += 1

    mgr.wait()
    pipe.close()
    losses = [losses_by_step[s] for s in range(steps)]
    return {"losses": losses, "losses_by_step": losses_by_step,
            "final_loss": losses[-1] if losses else None,
            "fingerprints": fingerprints, "restarts": restarts,
            "n_restarts": len(restarts), "timeline": timeline,
            "chaos_spec": schedule.to_spec(), "ckpt_dir": ckpt_dir,
            "steps_executed": steps_executed,
            "final_mesh_shape": list(mesh.devices.shape),
            "virtual_seconds": injector.clock()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true",
                    help="full published config (pod scale)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--chaos", action="store_true",
                    help="elastic-training chaos harness: injected host "
                         "kills/straggles, checkpoint-rescale restarts, "
                         "bit-identical data replay")
    ap.add_argument("--procs", action="store_true",
                    help="with --chaos: run each simulated host as a real "
                         "OS worker process with socket heartbeats; kill@S "
                         "delivers an actual SIGKILL and detection runs on "
                         "real-clock deadlines (repro.ft.cluster)")
    ap.add_argument("--chaos-seed", type=int, default=0)
    ap.add_argument("--chaos-spec", default=None,
                    metavar="kill@S:hH,straggle@S:hH:xF:dD,ckpt_crash@S",
                    help="explicit fault schedule (overrides --chaos-seed)")
    ap.add_argument("--hosts", type=int, default=2,
                    help="simulated hosts the local devices split into")
    ap.add_argument("--model-axis", type=int, default=2)
    ap.add_argument("--ckpt-every", type=int, default=2)
    ap.add_argument("--timeout", type=float, default=3.5,
                    help="heartbeat timeout (virtual seconds)")
    ap.add_argument("--max-restarts", type=int, default=3)
    args = ap.parse_args()
    if args.procs and not args.chaos:
        ap.error("--procs requires --chaos")
    if args.chaos and args.procs:
        from repro.ft.cluster import ClusterSupervisor
        spec = args.chaos_spec
        if spec is None:
            # seeded schedule, procs-compatible events only (straggles are
            # virtual-clock-only: real slowness cannot be injected
            # deterministically into an OS process)
            spec = ChaosSchedule.from_seed(
                args.chaos_seed, steps=args.steps, n_hosts=args.hosts,
                n_kills=1, n_straggles=0, n_ckpt_crashes=0).to_spec()
        sup = ClusterSupervisor(
            args.arch, steps=args.steps, n_hosts=args.hosts,
            n_devices=len(jax.devices()), model_axis=args.model_axis,
            global_batch=args.batch, seq_len=args.seq, lr=args.lr,
            ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
            chaos_spec=spec, timeout_s=args.timeout,
            max_restarts=args.max_restarts,
            n_microbatches=args.microbatches)
        out = sup.run()
        print(f"[chaos] done (procs): {out['n_restarts']} restart(s) "
              f"across {out['epochs']} epoch(s), final mesh "
              f"{out['final_mesh_shape']}, first loss "
              f"{out['losses'][0]:.4f} final {out['final_loss']:.4f} "
              f"(schedule: {out['chaos_spec'] or 'none'})")
        return
    if args.chaos:
        out = run_chaos(args.arch, steps=args.steps,
                        chaos_seed=args.chaos_seed,
                        chaos_spec=args.chaos_spec, n_hosts=args.hosts,
                        model_axis=args.model_axis, global_batch=args.batch,
                        seq_len=args.seq, lr=args.lr, ckpt_dir=args.ckpt,
                        ckpt_every=args.ckpt_every, timeout_s=args.timeout,
                        max_restarts=args.max_restarts,
                        n_microbatches=args.microbatches)
        print(f"[chaos] done: {out['n_restarts']} restart(s), "
              f"final mesh {out['final_mesh_shape']}, "
              f"first loss {out['losses'][0]:.4f} "
              f"final {out['final_loss']:.4f} "
              f"(schedule: {out['chaos_spec'] or 'none'})")
        return
    out = run(args.arch, smoke=not args.full, steps=args.steps,
              global_batch=args.batch, seq_len=args.seq, lr=args.lr,
              ckpt_dir=args.ckpt, n_microbatches=args.microbatches)
    print(f"[train] done: first loss {out['losses'][0]:.4f} "
          f"final {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
