"""End-to-end training launcher (CPU-scale runs + the production recipe).

``python -m repro.launch.train --arch llama3-8b --smoke --steps 50`` trains
the reduced config on local devices; on a pod the same script runs the full
config on the production mesh with checkpoint/restart and straggler
monitoring wired in.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, restore_checkpoint
from repro.checkpoint.ckpt import latest_step
from repro.configs import get_config, get_smoke_config
from repro.data import DataConfig, make_pipeline
from repro.ft import HeartbeatMonitor, StragglerMitigator
from repro.models import lm
from repro.parallel.sharding import (abstract_params, default_rules,
                                     init_params, param_shardings)
from repro.testing.timing import now
from repro.train import OptConfig, TrainState, make_train_step
from repro.train.optimizer import adamw_init


def run(arch: str, *, smoke: bool = True, steps: int = 50,
        global_batch: int = 8, seq_len: int = 64, lr: float = 3e-3,
        ckpt_dir: str | None = None, ckpt_every: int = 25,
        n_microbatches: int = 1, resume: bool = True, log_every: int = 10,
        seed: int = 0) -> dict:
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    rules = default_rules(None)          # single-process CPU run
    opt_cfg = OptConfig(lr=lr, warmup_steps=max(2, steps // 10),
                        total_steps=steps)

    key = jax.random.key(seed)
    params = init_params(lm.model_defs(cfg), key)
    state = TrainState(params, adamw_init(params, opt_cfg))

    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=seq_len,
                      global_batch=global_batch, seed=seed)
    start_step = 0
    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    if mgr and resume and latest_step(ckpt_dir) is not None:
        state, start_step, _ = restore_checkpoint(ckpt_dir, state)
        start_step = int(start_step)
        print(f"[train] resumed from step {start_step}")

    pipe = make_pipeline(dcfg, start_step=start_step)
    step_fn = jax.jit(make_train_step(cfg, rules, opt_cfg,
                                      n_microbatches=n_microbatches))

    monitor = HeartbeatMonitor(n_hosts=1)
    straggler = StragglerMitigator()
    losses = []
    t_prev = now()
    for step in range(start_step, steps):
        tokens = jnp.asarray(next(pipe))
        batch = {"tokens": tokens}
        if cfg.family in ("encdec", "vlm"):
            rng = np.random.default_rng(step)
            T = lm.context_len(cfg, seq_len)
            batch["ctx"] = jnp.asarray(
                rng.normal(size=(global_batch, T, cfg.d_ctx)) * 0.1,
                jnp.float32)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = now() - t_prev
        t_prev = now()
        monitor.beat(0, step, dt)
        straggler.update({0: monitor.hosts[0].ewma_step_s})
        if step % log_every == 0 or step == steps - 1:
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"lr {float(metrics['lr']):.2e} "
                  f"gnorm {float(metrics['grad_norm']):.3f} ({dt*1e3:.0f} ms)",
                  flush=True)
        if mgr and (step + 1) % ckpt_every == 0:
            mgr.save_async(state, step + 1)
    if mgr:
        mgr.wait()
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "start_step": start_step}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--full", action="store_true",
                    help="full published config (pod scale)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()
    out = run(args.arch, smoke=not args.full, steps=args.steps,
              global_batch=args.batch, seq_len=args.seq, lr=args.lr,
              ckpt_dir=args.ckpt, n_microbatches=args.microbatches)
    print(f"[train] done: first loss {out['losses'][0]:.4f} "
          f"final {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
