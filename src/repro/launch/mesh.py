"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The single-pod mesh is 16x16 = 256 chips
(a TPU v5e pod); multi-pod adds a leading 2-pod axis (512 chips) — the AraXL
hierarchy: `model` = lanes within a cluster, `data` = clusters, `pod` = the
next ring level.

The geometry is also expressible as a shared :class:`repro.topology.Topology`
(``production_topology()``), and ``make_production_mesh(topology=...)``
builds the mesh straight from one — the same value ``repro.sim`` prices and
``repro.core.machine.make_machine`` emulates, so a fig6/fig7 C x L sweep and
a dry-run compile describe the identical machine.
"""
from __future__ import annotations

import jax

from repro.topology import Topology


def production_topology(*, multi_pod: bool = False) -> Topology:
    """The production geometry as a Topology: clusters ride the `data` axis
    (x2 pods fold into more clusters), lanes the `model` axis."""
    return Topology(32 if multi_pod else 16, 16, hierarchy="two-level",
                    cluster_axis="data", lane_axis="model")


def make_production_mesh(*, multi_pod: bool = False,
                         topology: Topology | None = None):
    if topology is not None:
        if multi_pod:
            raise ValueError("multi_pod and topology= are mutually exclusive "
                             "(fold the pods into n_clusters instead)")
        return jax.make_mesh(
            (topology.n_clusters, topology.lanes_per_cluster),
            (topology.cluster_axis, topology.lane_axis))
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
