"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The single-pod mesh is 16x16 = 256 chips
(a TPU v5e pod); multi-pod adds a leading 2-pod axis (512 chips) — the AraXL
hierarchy recursing outward: `model` = lanes within a cluster, `data` =
clusters, `pod` = the next ring level.

The geometry is also expressible as a shared :class:`repro.topology.Topology`
(``production_topology()`` — two levels single-pod, three levels multi-pod),
and ``make_production_mesh(topology=...)`` builds the mesh straight from one
(one mesh axis per topology level) — the same value ``repro.sim`` prices and
``repro.core.machine.make_machine`` emulates, so a fig6/fig7 sweep and a
dry-run compile describe the identical machine.
"""
from __future__ import annotations

import jax

from repro.topology import Level, Topology, parse_topology


def parse_launch_topology(s: str) -> Topology:
    """Parse a ``--topology`` spec onto the production axis names:
    ``CxL[:hierarchy]`` puts clusters on `data` and lanes on `model`;
    ``PxCxL[:hierarchy]`` adds the outermost `pod` ring level."""
    n_sizes = len(s.partition(":")[0].split("x"))
    if n_sizes == 2:
        return parse_topology(s, cluster_axis="data", lane_axis="model")
    axes = ("pod", "data", "model")
    if n_sizes > 3:
        axes = tuple(f"pod{j}" for j in range(n_sizes - 3)) + axes
    return parse_topology(s, level_axes=axes)


def topology_tag(topology: Topology) -> str:
    """Short artifact tag, e.g. "topo16x4-two-level" / "topo2x8x4-flat"."""
    sizes = "x".join(str(l.size) for l in topology.levels)
    return f"topo{sizes}-{topology.hierarchy}"


def production_topology(*, multi_pod: bool = False) -> Topology:
    """The production geometry as a Topology: clusters ride the `data` axis,
    lanes the `model` axis; the multi-pod machine adds an outermost 2-wide
    `pod` ring level."""
    if multi_pod:
        return Topology(levels=(Level("pod", 2, 8.0),
                                Level("data", 16, 4.0),
                                Level("model", 16, 2.0)))
    return Topology(16, 16, hierarchy="two-level",
                    cluster_axis="data", lane_axis="model")


def make_production_mesh(*, multi_pod: bool = False,
                         topology: Topology | None = None):
    # one mesh axis per topology level — the same builder the emulator uses
    from repro.core.machine import make_topology_mesh
    if topology is not None:
        if multi_pod:
            raise ValueError("multi_pod and topology= are mutually exclusive "
                             "(use a three-level pod x cluster x lane "
                             "topology instead)")
        return make_topology_mesh(topology)
    return make_topology_mesh(production_topology(multi_pod=multi_pod))


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
