"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The single-pod mesh is 16x16 = 256 chips
(a TPU v5e pod); multi-pod adds a leading 2-pod axis (512 chips) — the AraXL
hierarchy: `model` = lanes within a cluster, `data` = clusters, `pod` = the
next ring level.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
