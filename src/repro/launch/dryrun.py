"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: a successful
compile on the 16x16 (single-pod) and 2x16x16 (multi-pod) meshes means every
sharding constraint, collective, and buffer fits together; the printed
memory_analysis proves per-device HBM fit, cost_analysis + the collective
parse feed §Roofline.

Per cell we compile:
  * the FULL model (memory analysis is exact; while bodies counted once),
  * 1-period and 2-period variants (cost extrapolation: total(L) =
    f1 + (L-1)(f2-f1) — DESIGN.md §8).

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
  python -m repro.launch.dryrun --arch llama3-8b --topology 32x8:two-level
  python -m repro.launch.dryrun --arch llama3-8b --topology 2x16x8

``--topology [Px]CxL[:hierarchy]`` overrides the production mesh with an
explicit topology (clusters on the `data` axis, lanes on `model`; a third
leading size adds the outermost `pod` ring level) — the same
:class:`repro.topology.Topology` value the sim layer prices, so the
fig6/fig7 factorisation sweeps and the compile surface stay in lock-step.
"""
# The VERY FIRST lines — before ANY other import (jax locks device count on
# first init).
import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", ""))

import argparse
import dataclasses
import json
import pathlib
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import ModelConfig, ShapeSpec
from repro.launch.mesh import (make_production_mesh, parse_launch_topology,
                               production_topology, topology_tag)
from repro.launch.specs import input_shardings, input_specs
from repro.models import lm
from repro.parallel.sharding import (abstract_params, default_rules,
                                     param_shardings)
from repro.roofline.analysis import (HW, collective_bytes,
                                     collective_level_bytes,
                                     exposed_level_seconds, extrapolate,
                                     level_wire_seconds, memory_model_bytes,
                                     parse_collectives, resident_model_bytes,
                                     roofline_terms, wire_seconds)
from repro.testing.timing import now
from repro.topology import Topology
from repro.train import OptConfig, TrainState, make_train_step
from repro.train.optimizer import opt_state_defs

#: memory-bound giants keep m/v + grad accumulators in bf16
#: (EXPERIMENTS.md records the trade)
OPT_BF16 = {"qwen3-moe-235b-a22b", "jamba-1.5-large-398b"}

#: target local microbatch (sequences per device per accumulation step)
TARGET_LOCAL_MB = 2
LOSS_CHUNK = 512


def _dp_size(mesh) -> int:
    return int(np_prod(mesh.shape.get(a, 1) for a in ("pod", "data")))


def np_prod(it):
    out = 1
    for x in it:
        out *= x
    return out


def n_microbatches(cfg: ModelConfig, shape: ShapeSpec, mesh) -> int:
    if shape.kind != "train":
        return 1
    local = max(1, shape.global_batch // _dp_size(mesh))
    n = max(1, local // TARGET_LOCAL_MB)
    while shape.global_batch % n:
        n -= 1
    return n


def build_rules(cfg: ModelConfig, shape: ShapeSpec, mesh):
    return default_rules(
        mesh,
        kv_heads=cfg.n_kv_heads,
        cache_seq="model" if shape.is_decode else None,
        act_seq=not shape.is_decode,
        batch=shape.global_batch)


def _opt_cfg(cfg: ModelConfig) -> OptConfig:
    if cfg.name in OPT_BF16:
        # HBM-bound giants: bf16 states, bf16 update math, no fp32 master
        # (8-bit-Adam-class trade; EXPERIMENTS.md documents it)
        return OptConfig(state_dtype=jnp.bfloat16, master_fp32=False,
                         math_dtype=jnp.bfloat16)
    return OptConfig()


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
               n_micro: int | None = None, rules=None, grad_sync=None):
    """Returns (lowered, compiled) for one cell on one mesh.

    ``rules`` overrides the default sharding rules (a plain argument — the
    §Perf strategies pass their rule tables here instead of monkey-patching
    :func:`build_rules`); ``grad_sync`` is an optional gradient-sync hook
    forwarded to :func:`repro.train.make_train_step`.
    """
    cfg = dataclasses.replace(cfg, loss_chunk=LOSS_CHUNK)
    if rules is None:
        rules = build_rules(cfg, shape, mesh)
    specs = input_specs(cfg, shape)
    shard = input_shardings(cfg, shape, rules)
    pdefs = lm.model_defs(cfg)
    p_abs = abstract_params(pdefs)
    p_sh = param_shardings(pdefs, rules)

    with mesh:
        if shape.kind == "train":
            ocfg = _opt_cfg(cfg)
            acc_dt = jnp.bfloat16 if cfg.name in OPT_BF16 else jnp.float32
            odefs = opt_state_defs(pdefs, ocfg)
            state = TrainState(p_abs, abstract_params(odefs))
            state_sh = TrainState(p_sh, param_shardings(odefs, rules))
            nm = n_micro if n_micro is not None else \
                n_microbatches(cfg, shape, mesh)
            step = make_train_step(cfg, rules, ocfg, n_microbatches=nm,
                                   acc_dtype=acc_dt, grad_sync=grad_sync)
            fn = jax.jit(step, in_shardings=(state_sh, shard),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
            lowered = fn.lower(state, specs)
        elif shape.kind == "prefill":
            def pre(params, batch):
                return lm.prefill(params, batch["tokens"], cfg, rules,
                                  shape.seq_len, batch.get("ctx"))
            fn = jax.jit(pre, in_shardings=(p_sh, shard))
            lowered = fn.lower(p_abs, specs)
        else:
            def dec(params, batch):
                return lm.decode_step(params, batch["token"], batch["cache"],
                                      batch["pos"], cfg, rules)
            fn = jax.jit(dec, in_shardings=(p_sh, shard),
                         donate_argnums=(1,))
            lowered = fn.lower(p_abs, specs)
        compiled = lowered.compile()
    return lowered, compiled


def _variant(cfg: ModelConfig, n: int) -> ModelConfig:
    """n-period reduced-depth variant with layers UNROLLED (python loop):
    XLA's cost_analysis counts a while body once regardless of trip count,
    so cost extrapolation must come from unrolled 1- vs 2-period compiles."""
    kw = dict(n_layers=n * len(cfg.layer_period), unroll_layers=True)
    if cfg.n_enc_layers:
        kw["n_enc_layers"] = max(1, cfg.n_enc_layers * n // cfg.n_periods)
    return dataclasses.replace(cfg, **kw)


def _cost_shape(shape: ShapeSpec, nm: int) -> ShapeSpec:
    """Per-microbatch shape for the cost variants (totals are scaled back
    by n_microbatches)."""
    if nm == 1:
        return shape
    return dataclasses.replace(shape, global_batch=shape.global_batch // nm)


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch          # one token


def analyse_cell(cfg: ModelConfig, shape: ShapeSpec, mesh,
                 mesh_name: str, *, topology: Topology | None = None,
                 rules=None, n_micro: int | None = None,
                 grad_sync=None) -> dict:
    """Lower + compile one cell and derive its roofline record.

    ``topology`` prices the collectives per level (the record gains
    ``roofline.collective_s_by_level`` and ``per_device.wire_bytes_by_level``;
    without one the historical flat pricing applies).  ``rules`` /
    ``n_micro`` / ``grad_sync`` are explicit strategy overrides (no
    module-global mutation): sharding-rule table, microbatch count, and the
    trainer's gradient-sync hook.
    """
    n_dev = mesh.devices.size
    t0 = now()
    rec = {"arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
           "devices": int(n_dev), "kind": shape.kind}
    if topology is not None:
        rec["topology"] = topology.describe()

    # full compile: memory truth + sharding coherence
    nm = n_micro if n_micro is not None else n_microbatches(cfg, shape, mesh)
    rec["n_microbatches"] = nm
    lowered, compiled = lower_cell(cfg, shape, mesh, n_micro=nm, rules=rules,
                                   grad_sync=grad_sync)
    ma = compiled.memory_analysis()
    # CPU backend's peak_memory_in_bytes omits the temp arena; the honest
    # per-device residency is args + temps + (outputs - donated aliases).
    live = (ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes)
    rec["mem_per_device"] = {
        "arguments_gib": ma.argument_size_in_bytes / 2**30,
        "outputs_gib": ma.output_size_in_bytes / 2**30,
        "temps_gib": ma.temp_size_in_bytes / 2**30,
        "aliased_gib": ma.alias_size_in_bytes / 2**30,
        # this jax's CPU CompiledMemoryStats has no peak; fall back to the
        # live-set estimate rather than dying on the backend difference
        "peak_gib": getattr(ma, "peak_memory_in_bytes", live) / 2**30,
        "total_gib": live / 2**30,
    }
    # CPU arenas double-buffer where TPU aliases donated state: report the
    # measured arena as the upper bound and analytic TPU residency as the
    # fit criterion (EXPERIMENTS.md §Dry-run documents both).
    resident = resident_model_bytes(cfg, shape, n_dev, nm,
                                    ma.argument_size_in_bytes,
                                    topology=topology)
    rec["mem_per_device"]["resident_model_gib"] = resident / 2**30
    rec["fits_16gib_hbm"] = bool(resident < 16 * 2**30)
    rec["cpu_arena_exceeds"] = bool(live >= 16 * 2**30)
    rec["compile_s_full"] = round(now() - t0, 1)
    del compiled, lowered

    # 1- and 2-period UNROLLED variants at per-microbatch shape:
    # per-device cost extrapolation (x n_microbatches for train)
    costs = {}
    cshape = _cost_shape(shape, nm)
    for n in (1, 2):
        lo, co = lower_cell(_variant(cfg, n), cshape, mesh, n_micro=1,
                            rules=rules, grad_sync=grad_sync)
        ca = co.cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jax: one dict per device
            ca = ca[0]
        colls = parse_collectives(co.as_text())
        costs[n] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "wire": collective_bytes(colls),
        }
        if topology is not None:
            costs[n]["wire_levels"] = collective_level_bytes(colls, topology)
        del co, lo
    L = cfg.n_periods
    flops = nm * extrapolate(costs[1]["flops"], costs[2]["flops"], L)
    bytes_ = nm * extrapolate(costs[1]["bytes"], costs[2]["bytes"], L)
    wire = nm * extrapolate(costs[1]["wire"]["total"],
                            costs[2]["wire"]["total"], L)
    rec["per_device"] = {"flops": flops, "bytes": bytes_, "wire_bytes": wire}
    rec["collectives_p2"] = {k: v for k, v in costs[2]["wire"].items()}
    coll_s = None
    if topology is not None:
        # per-level wire bytes extrapolate level by level (each level's
        # traffic scales with depth exactly like the total does)
        wire_by_level = {
            lab: nm * extrapolate(costs[1]["wire_levels"][lab],
                                  costs[2]["wire_levels"][lab], L)
            for lab in topology.wire_labels()}
        secs = level_wire_seconds(wire_by_level, topology)
        coll_s = secs.pop("total")
        rec["per_device"]["wire_bytes_by_level"] = wire_by_level
    rec["roofline"] = roofline_terms(flops, bytes_, wire, collective_s=coll_s)
    if topology is not None:
        rec["roofline"]["collective_s_by_level"] = secs
        # the historical single-class price, for the flat-vs-level ablation
        rec["roofline"]["collective_s_flat_hw"] = wire_seconds(wire)
    # fusion-aware analytic memory second opinion (the CPU HLO byte count
    # has no TPU fusion: treat it as an upper bound, the model as the
    # realistic term; bottleneck classification uses the model)
    mm = memory_model_bytes(cfg, shape, n_dev, nm, topology=topology)
    rec["roofline"]["memory_s_hlo_upper"] = rec["roofline"]["memory_s"]
    rec["roofline"]["memory_s"] = mm / HW["hbm_bw"]
    terms = {k: rec["roofline"][k]
             for k in ("compute_s", "memory_s", "collective_s")}
    rec["roofline"]["bottleneck"] = max(terms, key=terms.get)
    rec["roofline"]["step_s_lower_bound"] = max(terms.values())
    if topology is not None:
        # overlap-aware exposure: the additive per-level seconds stay as
        # recorded above; these fields say how much of them an ideally
        # double-buffered schedule could NOT hide behind the compute
        exp = exposed_level_seconds(rec["roofline"]["collective_s_by_level"],
                                    terms["compute_s"], topology)
        rec["roofline"]["exposed_collective_s"] = exp.pop("total")
        rec["roofline"]["exposed_collective_s_by_level"] = exp
        rec["roofline"]["step_s_overlap_aware"] = max(
            terms["memory_s"],
            terms["compute_s"] + rec["roofline"]["exposed_collective_s"])
    mf = model_flops(cfg, shape)
    rec["model_flops_global"] = mf
    hlo_global = flops * n_dev
    rec["model_vs_hlo_flops"] = mf / hlo_global if hlo_global else 0.0
    rec["roofline"]["mfu_upper_bound"] = (
        mf / n_dev / HW["peak_flops"] / rec["roofline"]["step_s_lower_bound"]
        if rec["roofline"]["step_s_lower_bound"] else 0.0)
    rec["elapsed_s"] = round(now() - t0, 1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None)
    ap.add_argument("--shape", action="append", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--topology", default=None,
                    metavar="[P x]CxL[:hierarchy]",
                    help="override the mesh with an explicit Topology "
                         "(clusters on `data`, lanes on `model`; a third "
                         "leading size adds the `pod` ring level, e.g. "
                         "2x16x8:three-level)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()

    archs = args.arch or (list_archs() if args.all else ["llama3-8b"])
    shapes = args.shape or list(SHAPES)
    outdir = pathlib.Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    if args.topology is not None:
        if args.mesh != "single":
            ap.error("--topology replaces the pod mesh entirely; drop "
                     "--mesh (or run the pod meshes in a separate invocation)")
        topo = parse_launch_topology(args.topology)
        mesh_plan = [(make_production_mesh(topology=topo),
                      topology_tag(topo), topo)]
    else:
        meshes = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.mesh]
        # every cell carries its Topology: `--mesh multi` prices the true
        # three-level production_topology(multi_pod=True) per level
        mesh_plan = [(make_production_mesh(multi_pod=m),
                      "pod2x16x16" if m else "pod16x16",
                      production_topology(multi_pod=m)) for m in meshes]

    failures = []
    for mesh, mname, topo in mesh_plan:
        for arch in archs:
            cfg = get_config(arch)
            for sname in shapes:
                shape = SHAPES[sname]
                path = outdir / f"{arch}__{sname}__{mname}.json"
                if not cfg.runnable(sname):
                    rec = {"arch": arch, "shape": sname, "mesh": mname,
                           "skipped": cfg.skip_shapes[sname]}
                    path.write_text(json.dumps(rec, indent=2))
                    print(f"[skip] {arch} x {sname} ({cfg.skip_shapes[sname]})")
                    continue
                if path.exists():
                    print(f"[cached] {path}")
                    continue
                try:
                    rec = analyse_cell(cfg, shape, mesh, mname,
                                       topology=topo)
                    path.write_text(json.dumps(rec, indent=2))
                    r = rec["roofline"]
                    print(f"[ok] {arch} x {sname} x {mname}: "
                          f"mem={rec['mem_per_device']['total_gib']:.2f}GiB "
                          f"compute={r['compute_s']:.4f}s "
                          f"mem={r['memory_s']:.4f}s "
                          f"coll={r['collective_s']:.4f}s "
                          f"bound={r['bottleneck']} "
                          f"({rec['elapsed_s']}s)", flush=True)
                except Exception as e:
                    failures.append((arch, sname, mname, repr(e)))
                    print(f"[FAIL] {arch} x {sname} x {mname}: {e}",
                          flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print(" ", f)
        raise SystemExit(1)
    print("\nall requested dry-run cells passed")


if __name__ == "__main__":
    main()
