"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, zero allocation — the dry-run contract.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec
from repro.models import lm
from repro.parallel.sharding import ShardingRules, abstract_params, \
    param_shardings


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, Any]:
    """Abstract model inputs for one shape cell.

    train:   {"tokens": (B,S) i32[, "ctx": (B,T,d_ctx)]}
    prefill: same as train
    decode:  {"token": (B,1) i32, "cache": <pytree>, "pos": scalar i32
              [, "cache_ctx" via the cache tree]}
    """
    B, S = shape.global_batch, shape.seq_len
    ctx_needed = cfg.family in ("encdec", "vlm")
    if shape.kind in ("train", "prefill"):
        out = {"tokens": sds((B, S), jnp.int32)}
        if ctx_needed:
            out["ctx"] = sds((B, lm.context_len(cfg, S), cfg.d_ctx),
                             jnp.float32)
        return out
    # decode: one new token against a seq_len-deep cache
    cache = abstract_params(lm.cache_defs(cfg, B, S))
    return {"token": sds((B, 1), jnp.int32), "cache": cache,
            "pos": sds((), jnp.int32)}


def input_shardings(cfg: ModelConfig, shape: ShapeSpec, rules: ShardingRules):
    if rules.mesh is None:
        return None
    if shape.kind in ("train", "prefill"):
        out = {"tokens": rules.sharding(("batch", ""))}
        if cfg.family in ("encdec", "vlm"):
            out["ctx"] = rules.sharding(("batch", "", ""))
        return out
    cache_defs = lm.cache_defs(cfg, shape.global_batch, shape.seq_len)
    return {"token": rules.sharding(("batch", "")),
            "cache": param_shardings(cache_defs, rules),
            "pos": rules.sharding(())}
