from .ckpt import (CheckpointManager, SimulatedCrash, latest_step,
                   restore_checkpoint, save_checkpoint, tear_checkpoint,
                   valid_steps)

__all__ = ["CheckpointManager", "SimulatedCrash", "latest_step",
           "restore_checkpoint", "save_checkpoint", "tear_checkpoint",
           "valid_steps"]
