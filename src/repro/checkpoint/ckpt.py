"""Sharded, elastic, async checkpointing (no external deps).

Format: one directory per step containing
    manifest.json        - tree structure, shapes, dtypes, mesh shape, step
    <leaf-id>.npy        - one host-local file per leaf (gathered shard-0
                           addressable data in this single-host environment;
                           on a real pod each host writes its own slice files
                           and the manifest records the global layout)

Fault-tolerance properties:
* atomic publish: writes go to ``<dir>.tmp`` then os.replace -> a crashed
  writer never corrupts the latest checkpoint;
* elastic restore: ``restore_checkpoint(..., shardings=...)`` re-shards onto
  ANY mesh (more/fewer devices than the writer) — restore is jax.device_put
  against the target sharding, so a 512-chip checkpoint restarts on 256;
* async: ``CheckpointManager.save_async`` snapshots to host memory on the
  train thread, serialises on a worker thread — the step loop never blocks
  on disk;
* retention: keeps the newest ``keep`` checkpoints, deletes older ones only
  after the newest is durable.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(path: str | pathlib.Path, tree: Any, step: int,
                    extra: dict | None = None) -> pathlib.Path:
    path = pathlib.Path(path)
    final = path / f"step_{step:08d}"
    tmp = path / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "extra": extra or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if logical_dtype == "bfloat16":
            arr = arr.view(np.uint16)          # npy-portable container
        np.save(tmp / f"leaf_{i:05d}.npy", arr)
        manifest["leaves"].append({"shape": list(arr.shape),
                                   "dtype": logical_dtype})
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                    # atomic publish
    return final


def latest_step(path: str | pathlib.Path) -> int | None:
    path = pathlib.Path(path)
    if not path.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in path.glob("step_*")
             if not p.name.endswith(".tmp") and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore_checkpoint(path: str | pathlib.Path, tree_like: Any,
                       step: int | None = None, shardings: Any = None) -> Any:
    """Restore into the structure of ``tree_like``; if ``shardings`` is given
    (a matching pytree of NamedSharding), leaves are placed sharded — this is
    the elastic-rescale path (any target mesh)."""
    path = pathlib.Path(path)
    step = latest_step(path) if step is None else step
    assert step is not None, f"no checkpoint under {path}"
    d = path / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves), "tree structure changed"
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    import ml_dtypes
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(d / f"leaf_{i:05d}.npy")
        stored = manifest["leaves"][i]["dtype"]
        if stored == "bfloat16" and arr.dtype == np.uint16:
            arr = arr.view(ml_dtypes.bfloat16)
        want = getattr(ref, "dtype", None)
        if want is not None and str(want) != str(arr.dtype):
            arr = arr.astype(want)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), step, manifest["extra"]


class CheckpointManager:
    """Async writer with retention. Snapshot on the caller thread (device ->
    host copy), serialise on a worker thread."""

    def __init__(self, path: str | pathlib.Path, keep: int = 3):
        self.path = pathlib.Path(path)
        self.keep = keep
        self._worker: threading.Thread | None = None
        self._err: Exception | None = None

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._err:
            raise self._err

    def save_async(self, tree: Any, step: int, extra: dict | None = None):
        self.wait()                                  # one in flight
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def run():
            try:
                save_checkpoint(self.path, host, step, extra)
                self._gc()
            except Exception as e:                   # surfaced on next wait()
                self._err = e

        self._worker = threading.Thread(target=run, daemon=True)
        self._worker.start()

    def _gc(self):
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.path.glob("step_*")
                       if not p.name.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.path / f"step_{s:08d}", ignore_errors=True)
