"""Sharded, elastic, async checkpointing (no external deps).

Format: one directory per step containing
    manifest.json        - tree structure, shapes, dtypes, mesh shape, step
    <leaf-id>.npy        - one host-local file per leaf (gathered shard-0
                           addressable data in this single-host environment;
                           on a real pod each host writes its own slice files
                           and the manifest records the global layout)

Fault-tolerance properties:
* atomic publish: writes go to ``<dir>.tmp`` then os.replace -> a crashed
  writer never corrupts the latest checkpoint;
* elastic restore: ``restore_checkpoint(..., shardings=...)`` re-shards onto
  ANY mesh (more/fewer devices than the writer) — restore is jax.device_put
  against the target sharding, so a 512-chip checkpoint restarts on 256;
* async: ``CheckpointManager.save_async`` snapshots to host memory on the
  train thread, serialises on a worker thread — the step loop never blocks
  on disk;
* retention: keeps the newest ``keep`` checkpoints, deletes older ones only
  after the newest is durable.
"""
from __future__ import annotations

import io
import json
import os
import pathlib
import shutil
import threading
from typing import Any, Callable

import jax
import numpy as np


class SimulatedCrash(RuntimeError):
    """Raised by ``save_checkpoint(crash_after_leaves=...)`` — the chaos
    harness's stand-in for a writer dying mid-save.  Because the write goes
    to ``<dir>.tmp`` and publishes via os.replace, a crash at any point
    before publish leaves only a ``.tmp`` turd that every reader ignores."""


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _fsync_dir(d: pathlib.Path) -> None:
    """Make a directory entry durable (the rename itself lives in the
    directory, not the file — without this a crash can survive the file
    write yet lose the name)."""
    fd = os.open(d, os.O_RDONLY | getattr(os, "O_DIRECTORY", 0))
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _publish_bytes(dest: pathlib.Path, data: bytes) -> None:
    """Crash-atomic single-file write: same-directory temp name, flush +
    fsync the *data*, then ``os.replace`` the *name*.  A SIGKILL (or power
    loss) at any instant leaves either no ``dest`` or a complete one —
    never a ``dest`` with the right name and torn bytes, which is exactly
    the state that would fool ``_step_dir_valid``'s byte-size gate."""
    tmp = dest.with_name(dest.name + ".part")
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, dest)


def save_checkpoint(path: str | pathlib.Path, tree: Any, step: int,
                    extra: dict | None = None,
                    crash_after_leaves: int | None = None,
                    after_leaf: Callable[[int], None] | None = None,
                    ) -> pathlib.Path:
    """Write one step directory with two layers of crash-atomicity: every
    file (leaves and manifest) goes through :func:`_publish_bytes`, and the
    whole directory is staged as ``<dir>.tmp`` and published by a final
    ``os.replace``.  ``after_leaf(i)`` (if given) runs once leaf ``i`` is
    durable — the multi-process chaos harness parks the writer there so a
    real SIGKILL lands between leaf writes with the manifest unpublished."""
    path = pathlib.Path(path)
    final = path / f"step_{step:08d}"
    tmp = path / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(leaves),
        "extra": extra or {},
        "leaves": [],
    }
    for i, leaf in enumerate(leaves):
        if crash_after_leaves is not None and i >= crash_after_leaves:
            raise SimulatedCrash(
                f"simulated writer crash after {i} of {len(leaves)} leaves "
                f"(step {step}; only {tmp.name} exists, never {final.name})")
        arr = np.asarray(jax.device_get(leaf))
        logical_dtype = str(arr.dtype)
        if logical_dtype == "bfloat16":
            arr = arr.view(np.uint16)          # npy-portable container
        buf = io.BytesIO()
        np.save(buf, arr)
        data = buf.getvalue()
        _publish_bytes(tmp / f"leaf_{i:05d}.npy", data)
        manifest["leaves"].append({"shape": list(arr.shape),
                                   "dtype": logical_dtype,
                                   "nbytes": len(data)})
        if after_leaf is not None:
            after_leaf(i)
    _publish_bytes(tmp / "manifest.json", json.dumps(manifest).encode())
    _fsync_dir(tmp)                           # leaf names durable pre-publish
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)                    # atomic publish
    _fsync_dir(path)
    return final


def _step_dir_valid(d: pathlib.Path) -> bool:
    """Crash-consistency gate for one published ``step_*`` directory: the
    manifest must parse and every leaf file must exist with its recorded
    byte size.  Catches torn writes that slip past the atomic-publish
    discipline (non-atomic network filesystems, partial object-store
    uploads, post-publish corruption) — a torn step is *skipped*, never a
    crash at restore time.  Pre-``nbytes`` manifests (older checkpoints)
    fall back to an existence check."""
    try:
        manifest = json.loads((d / "manifest.json").read_text())
    except (OSError, json.JSONDecodeError):
        return False
    for i, meta in enumerate(manifest.get("leaves", [])):
        f = d / f"leaf_{i:05d}.npy"
        if not f.exists():
            return False
        want = meta.get("nbytes")
        if want is not None and f.stat().st_size != want:
            return False
    return len(manifest.get("leaves", [])) == manifest.get("n_leaves", -1)


def valid_steps(path: str | pathlib.Path) -> list:
    """Sorted steps whose checkpoint directory passes the torn-write gate."""
    path = pathlib.Path(path)
    if not path.exists():
        return []
    return sorted(int(p.name.split("_")[1]) for p in path.glob("step_*")
                  if not p.name.endswith(".tmp") and _step_dir_valid(p))


def latest_step(path: str | pathlib.Path) -> int | None:
    """Newest *valid* step — a torn newest checkpoint is skipped in favour
    of the previous durable one (the restart path's contract)."""
    steps = valid_steps(path)
    return steps[-1] if steps else None


def tear_checkpoint(path: str | pathlib.Path, step: int,
                    leaf: int = 0) -> pathlib.Path:
    """Deliberately corrupt a *published* checkpoint by truncating one leaf
    file to half its size — the chaos injector's ``ckpt_crash`` event (a
    torn write surviving past os.replace, e.g. a lying network filesystem).
    ``latest_step``/``valid_steps`` must subsequently skip the step."""
    d = pathlib.Path(path) / f"step_{step:08d}"
    f = d / f"leaf_{leaf:05d}.npy"
    data = f.read_bytes()
    f.write_bytes(data[: max(1, len(data) // 2)])
    return d


def restore_checkpoint(path: str | pathlib.Path, tree_like: Any,
                       step: int | None = None, shardings: Any = None) -> Any:
    """Restore into the structure of ``tree_like``; if ``shardings`` is given
    (a matching pytree of NamedSharding), leaves are placed sharded — this is
    the elastic-rescale path: the target mesh may be any size (the chaos
    harness restores an 8-device checkpoint onto the 4 survivors), because
    placement is just ``device_put`` against shardings re-derived from the
    logical rules (``ft.rescale_rules``).  ``step=None`` picks the newest
    checkpoint that passes the torn-write gate."""
    path = pathlib.Path(path)
    step = latest_step(path) if step is None else step
    assert step is not None, f"no valid checkpoint under {path}"
    d = path / f"step_{step:08d}"
    if not _step_dir_valid(d):
        raise ValueError(
            f"checkpoint step {step} under {path} is torn or missing; "
            f"valid steps: {valid_steps(path)}")
    manifest = json.loads((d / "manifest.json").read_text())
    leaves, treedef = _flatten(tree_like)
    assert manifest["n_leaves"] == len(leaves), "tree structure changed"
    shard_leaves = (jax.tree.flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves))
    import ml_dtypes
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
        arr = np.load(d / f"leaf_{i:05d}.npy")
        stored = manifest["leaves"][i]["dtype"]
        if stored == "bfloat16" and arr.dtype == np.uint16:
            arr = arr.view(ml_dtypes.bfloat16)
        want = getattr(ref, "dtype", None)
        if want is not None and str(want) != str(arr.dtype):
            arr = arr.astype(want)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), step, manifest["extra"]


class CheckpointManager:
    """Async writer with retention. Snapshot on the caller thread (device ->
    host copy), serialise on a worker thread."""

    def __init__(self, path: str | pathlib.Path, keep: int = 3):
        self.path = pathlib.Path(path)
        self.keep = keep
        self._worker: threading.Thread | None = None
        self._err: Exception | None = None

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._err:
            raise self._err

    def save_async(self, tree: Any, step: int, extra: dict | None = None):
        self.wait()                                  # one in flight
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def run():
            try:
                save_checkpoint(self.path, host, step, extra)
                self._gc()
            except Exception as e:                   # surfaced on next wait()
                self._err = e

        self._worker = threading.Thread(target=run, daemon=True)
        self._worker.start()

    def _gc(self):
        # retention counts *valid* checkpoints only — a torn newer step must
        # never push the last durable one out of the keep window
        valid = valid_steps(self.path)
        for s in valid[:-self.keep]:
            shutil.rmtree(self.path / f"step_{s:08d}", ignore_errors=True)
        if valid:
            # torn dirs older than the newest durable step are garbage
            all_steps = [int(p.name.split("_")[1])
                         for p in self.path.glob("step_*")
                         if not p.name.endswith(".tmp")]
            for s in all_steps:
                if s < valid[-1] and s not in valid:
                    shutil.rmtree(self.path / f"step_{s:08d}",
                                  ignore_errors=True)
