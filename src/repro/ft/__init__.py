from .resilience import (ElasticPlan, HeartbeatMonitor, RestartPolicy,
                         StragglerMitigator, plan_rescale)

__all__ = ["ElasticPlan", "HeartbeatMonitor", "RestartPolicy",
           "StragglerMitigator", "plan_rescale"]
