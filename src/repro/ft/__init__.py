from .chaos import (ChaosEvent, ChaosSchedule, ChaosStatus, FaultInjector,
                    VirtualClock)
from .cluster import ClusterSupervisor, WorkerSpec, drill
from .resilience import (ElasticPlan, HeartbeatMonitor, RescaleError,
                         RestartPolicy, StragglerMitigator, plan_rescale,
                         rescale_rules, survivor_devices)

__all__ = ["ChaosEvent", "ChaosSchedule", "ChaosStatus", "ClusterSupervisor",
           "ElasticPlan", "FaultInjector", "HeartbeatMonitor",
           "RescaleError", "RestartPolicy", "StragglerMitigator",
           "VirtualClock", "WorkerSpec", "drill", "plan_rescale",
           "rescale_rules", "survivor_devices"]
