from .chaos import (ChaosEvent, ChaosSchedule, ChaosStatus, FaultInjector,
                    VirtualClock)
from .resilience import (ElasticPlan, HeartbeatMonitor, RescaleError,
                         RestartPolicy, StragglerMitigator, plan_rescale,
                         rescale_rules, survivor_devices)

__all__ = ["ChaosEvent", "ChaosSchedule", "ChaosStatus", "ElasticPlan",
           "FaultInjector", "HeartbeatMonitor", "RescaleError",
           "RestartPolicy", "StragglerMitigator", "VirtualClock",
           "plan_rescale", "rescale_rules", "survivor_devices"]
