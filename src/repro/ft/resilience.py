"""Fault tolerance: heartbeats, straggler mitigation, elastic rescale.

At thousand-node scale the paper's latency-tolerance argument becomes the
fault-tolerance argument: the job must tolerate slow and dead clusters the
way AraXL tolerates register cuts.  Mechanisms (all host-side; the device
program stays a pure SPMD step):

* HeartbeatMonitor — every host stamps a heartbeat each step; the controller
  (host 0 / an external supervisor) marks hosts dead after ``timeout`` and
  triggers the restart policy.  In this single-host container the monitor is
  exercised by tests with simulated clocks.
* RestartPolicy — exponential-backoff restart budget; decides restore step
  (latest durable checkpoint) and whether to shrink the mesh (ElasticPlan).
* StragglerMitigator — per-step duration EWMA per host; hosts persistently
  > ``threshold`` x median are reported for eviction (checkpoint-restart
  without them), the standard mitigation when within-step work stealing
  is impossible under SPMD.
* plan_rescale — maps a checkpoint written on mesh A to a new mesh B:
  parameter shardings are re-derived from the same logical rules, so restore
  is just device_put (see repro.checkpoint) — elasticity without format
  migration.  Data order is preserved because the pipeline is a pure
  function of (seed, step).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.testing.timing import now


@dataclasses.dataclass
class HostState:
    last_beat: float
    step: float = 0.0
    ewma_step_s: float = 0.0


class HeartbeatMonitor:
    """Controller-side liveness: a host is dead when its last beat is
    *strictly* older than ``timeout_s`` (a beat exactly at the boundary is
    alive — slow-but-barely is the straggler path's business, not this
    one's).  A beat from a host already past the timeout revives it: the
    monitor has no memory beyond ``last_beat``, so flapping hosts are the
    restart policy's problem to rate-limit, by design.

    ``hosts`` names the fleet explicitly (e.g. the survivors after a
    rescale, in the original id space); ``n_hosts`` keeps the historical
    ``range(n)`` form."""

    def __init__(self, n_hosts: int | None = None, timeout_s: float = 60.0,
                 clock: Callable[[], float] = now, hosts=None):
        assert (n_hosts is None) != (hosts is None), \
            "pass exactly one of n_hosts= / hosts="
        ids = range(n_hosts) if hosts is None else sorted(hosts)
        self.timeout = timeout_s
        self.clock = clock
        self.hosts = {h: HostState(last_beat=clock()) for h in ids}

    def beat(self, host: int, step: int, step_s: float | None = None):
        st = self.hosts[host]
        st.last_beat = self.clock()
        st.step = step
        if step_s is not None:
            st.ewma_step_s = (0.9 * st.ewma_step_s + 0.1 * step_s
                              if st.ewma_step_s else step_s)

    def dead_hosts(self) -> list[int]:
        now = self.clock()
        return [h for h, st in self.hosts.items()
                if now - st.last_beat > self.timeout]

    def healthy(self) -> bool:
        return not self.dead_hosts()


class StragglerMitigator:
    """Flag hosts whose EWMA step time exceeds threshold x median for
    ``patience`` consecutive checks (transient slowness is tolerated, the
    AraXL way; persistent stragglers are evicted)."""

    def __init__(self, threshold: float = 1.5, patience: int = 3):
        self.threshold = threshold
        self.patience = patience
        self._counts: dict[int, int] = {}

    def update(self, ewma_by_host: dict[int, float]) -> list[int]:
        vals = sorted(v for v in ewma_by_host.values() if v > 0)
        if not vals:
            return []
        median = vals[len(vals) // 2]
        flagged = []
        for h, v in ewma_by_host.items():
            if v > self.threshold * median:
                self._counts[h] = self._counts.get(h, 0) + 1
                if self._counts[h] >= self.patience:
                    flagged.append(h)
            else:
                self._counts[h] = 0
        return flagged


@dataclasses.dataclass
class ElasticPlan:
    old_devices: int
    new_devices: int
    new_mesh_shape: tuple
    new_global_batch: int
    restore_step: int
    notes: str = ""


class RescaleError(ValueError):
    """The surviving devices cannot host the job (no survivors, or too few
    to keep the model axis intact) — the caller must abort, not retry."""


def plan_rescale(old_devices: int, lost_hosts: int, devices_per_host: int,
                 mesh_axes: tuple, global_batch: int,
                 restore_step: int) -> ElasticPlan:
    """Shrink policy: drop whole data-parallel rows (clusters) so the model
    axis stays intact — AraXL loses clusters, never lanes.  Batch is kept
    divisible by the new dp size (gradient noise scale changes are logged,
    not silently absorbed).  Raises :class:`RescaleError` when nothing
    survives or the survivors cannot hold one model-axis replica."""
    remaining = old_devices - lost_hosts * devices_per_host
    model = mesh_axes[-1]
    if remaining <= 0:
        raise RescaleError(
            f"no survivors: {lost_hosts} lost hosts x {devices_per_host} "
            f"devices >= {old_devices} total")
    if remaining < model:
        raise RescaleError(
            f"cannot keep the model axis intact: {remaining} surviving "
            f"devices < model axis {model}")
    dp = remaining // model
    new_devices = dp * model
    gb = global_batch
    while gb % dp:
        gb -= 1
    return ElasticPlan(
        old_devices=old_devices, new_devices=new_devices,
        new_mesh_shape=(dp, model), new_global_batch=gb,
        restore_step=restore_step,
        notes=f"dropped to {dp} data rows; batch {global_batch}->{gb}")


def survivor_devices(lost_hosts, devices_per_host: int, devices=None) -> list:
    """The devices that remain when the hosts in ``lost_hosts`` (original
    host ids; host h owns the contiguous device block
    ``[h*devices_per_host, (h+1)*devices_per_host)``) are gone."""
    import jax
    devices = list(jax.devices()) if devices is None else list(devices)
    lost = set(lost_hosts)
    return [d for i, d in enumerate(devices)
            if i // devices_per_host not in lost]


def rescale_rules(plan: ElasticPlan, lost_hosts, devices_per_host: int,
                  devices=None, **rule_kw):
    """The rescale → rules plumbing: build the survivor mesh prescribed by
    ``plan`` and re-derive the sharding rules from the *logical* rule table
    (``parallel.sharding.default_rules``) on it.

    This is the whole elasticity trick: nothing about the checkpoint format
    or the model code changes across a rescale — parameter shardings are a
    pure function of (logical axes, mesh), so restore onto the new mesh is
    just ``device_put`` against the re-derived shardings (see
    ``repro.checkpoint.restore_checkpoint``).  Returns ``(mesh, rules)``.
    """
    import numpy as np
    from jax.sharding import Mesh

    from repro.parallel.sharding import default_rules

    keep = survivor_devices(lost_hosts, devices_per_host, devices)
    if len(keep) < plan.new_devices:
        raise RescaleError(f"plan wants {plan.new_devices} devices but only "
                           f"{len(keep)} survived")
    arr = np.array(keep[: plan.new_devices]).reshape(plan.new_mesh_shape)
    mesh = Mesh(arr, ("data", "model"))
    rule_kw.setdefault("batch", plan.new_global_batch)
    return mesh, default_rules(mesh, **rule_kw)


class RestartPolicy:
    """Exponential-backoff restart budget.

    ``max_backoff_s`` caps the delay (default 5 min — beyond that a
    flapping job should page a human, not wait longer), and the exponent
    itself is clamped *before* the float multiply: a long-lived supervisor
    that keeps calling :meth:`next_delay` past exhaustion (to log the
    would-be delay, say) must never hit ``OverflowError`` from
    ``2 ** restarts`` at restart count ~1024."""

    def __init__(self, max_restarts: int = 10, backoff_s: float = 5.0,
                 clock: Callable[[], float] = now,
                 max_backoff_s: float = 300.0):
        self.max_restarts = max_restarts
        self.backoff = backoff_s
        self.max_backoff = max_backoff_s
        self.clock = clock
        self.restarts = 0
        self._last = 0.0

    def should_restart(self) -> bool:
        return self.restarts < self.max_restarts

    def next_delay(self) -> float:
        d = self.backoff * (2.0 ** min(self.restarts, 62))
        self.restarts += 1
        return min(d, self.max_backoff)
