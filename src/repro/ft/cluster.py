"""Multi-process chaos: real worker death, socket heartbeats, real clocks.

PR 8's chaos harness (``launch.train --chaos``, ``ft/chaos.py``) proved the
whole elastic stack — detection, rescale arithmetic, torn-checkpoint
fallback, bit-exact replay — but every "host" lived inside one process on a
virtual clock.  This module is the follow-on the ROADMAP names: the same
restart state machine (``HeartbeatMonitor`` / ``RestartPolicy`` /
``plan_rescale`` -> ``rescale_rules``; see docs/RESILIENCE.md) driven by
**actual OS process death**:

* each simulated host is a separate worker process (spawned with the
  ``repro.testing.subproc`` pinned env — same fake-device discipline as
  every other multi-device check);
* every worker stamps heartbeats over a localhost TCP socket
  (newline-delimited JSON) from a dedicated timer thread, so liveness is
  decoupled from jit-compile stalls;
* ``kill@S:hH`` delivers a real ``SIGKILL`` to the victim's PID, and
  ``ckpt_crash@S`` SIGKILLs the checkpoint *writer* parked mid-save
  (leaf files durable, manifest unpublished) — the torn state the
  crash-atomic write discipline in ``repro.checkpoint`` must survive;
* the supervisor detects the loss by **missed heartbeats on a real
  monotonic clock** (``repro.testing.timing.monotonic`` — the sanctioned
  liveness deadline clock, L4), then backs off, rescales, and respawns the
  survivors on the shrunk mesh.

Single-controller emulation keeps compute at 1x: only the elected primary
(lowest alive host id) trains, on *all* the fake devices the survivors
own; standby hosts are real killable PIDs that only heartbeat.  Losing a
standby still costs its devices — exactly the dp-row arithmetic of
``plan_rescale``.

Determinism under a real clock uses one trick: a ``kill@S`` makes the
primary emit step ``S``'s records, send a ``fence``, and *stall* —
modelling the SPMD survivors blocking at the next all-reduce when a peer
dies.  The SIGKILL, the socket going quiet, and the heartbeat-timeout
detection are all real and really timed, but *which step* the fleet had
reached is pinned, so two seeded runs replay identically
(``repro.testing.check_chaos_procs`` asserts exactly that).

Wire format (worker -> supervisor; one JSON object per ``\\n`` line)::

    {"kind": "hello", "host": 1, "pid": 4242, "role": "standby"}
    {"kind": "beat",  "host": 1, "n": 17}
    {"kind": "epoch", "host": 0, "restore_step": 4, "mesh_shape": [3, 2]}
    {"kind": "step",  "host": 0, "step": 5, "loss": 6.91, "fp": 123456}
    {"kind": "ckpt",  "host": 0, "step": 8}
    {"kind": "ckpt_mid", "host": 0, "step": 8}      # parked mid-save
    {"kind": "fence", "host": 0, "step": 3}         # stalled at collective
    {"kind": "done",  "host": 0, "steps": 10}
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import selectors
import socket
import subprocess
import sys
import tempfile
import threading
import time

from repro.ft.chaos import CKPT_CRASH, KILL, STRAGGLE, ChaosSchedule
from repro.ft.resilience import HeartbeatMonitor, RestartPolicy, plan_rescale
from repro.testing.subproc import pinned_env
from repro.testing.timing import monotonic

_LOOPBACK = "127.0.0.1"
ROLE_PRIMARY = "primary"
ROLE_STANDBY = "standby"


# ---------------------------------------------------------------------------
# Wire protocol: newline-delimited JSON over a localhost socket
# ---------------------------------------------------------------------------

def encode_msg(msg: dict) -> bytes:
    """One wire frame: compact JSON + ``\\n`` (no newlines inside JSON)."""
    return json.dumps(msg, separators=(",", ":")).encode() + b"\n"


class Framer:
    """Reassemble newline-delimited JSON from an arbitrary byte stream —
    TCP gives no message boundaries, so frames split/merge under load."""

    def __init__(self):
        self._buf = b""

    def feed(self, data: bytes) -> list[dict]:
        self._buf += data
        *lines, self._buf = self._buf.split(b"\n")
        return [json.loads(line) for line in lines if line]


class Channel:
    """Worker-side sender.  The heartbeat timer thread and the training
    thread share one socket; the lock keeps frames from interleaving."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._lock = threading.Lock()

    def send(self, msg: dict) -> None:
        with self._lock:
            self.sock.sendall(encode_msg(msg))


# ---------------------------------------------------------------------------
# Worker process
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class WorkerSpec:
    """Everything one worker process needs, shipped as argv JSON.

    ``fence_steps`` and ``ckpt_hold_step`` are the determinism anchors:
    the primary stalls after those steps (modelling the collective stall)
    so the supervisor's real SIGKILL always lands at the same point in the
    step stream.  ``failed`` is the all-time lost-host set (original id
    space) from which the worker derives the survivor mesh."""
    host: int
    n_hosts: int
    port: int
    role: str = ROLE_STANDBY
    devices_per_host: int = 1
    model_axis: int = 1
    arch: str = "llama3-8b"
    steps: int = 0
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 32
    lr: float = 3e-3
    n_microbatches: int = 1
    ckpt_dir: str = ""
    ckpt_every: int = 4
    failed: list = dataclasses.field(default_factory=list)
    fence_steps: list = dataclasses.field(default_factory=list)
    ckpt_hold_step: int | None = None
    beat_interval_s: float = 0.1

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))

    @classmethod
    def from_json(cls, s: str) -> "WorkerSpec":
        return cls(**json.loads(s))


def spawn_worker(spec: WorkerSpec, logdir: str | pathlib.Path,
                 devices: int = 8) -> tuple[subprocess.Popen, pathlib.Path]:
    """Launch one worker OS process under the pinned fake-device env;
    stdout+stderr go to a per-worker log whose tail is surfaced on
    abnormal death."""
    log_path = pathlib.Path(logdir) / f"worker_h{spec.host}.log"
    log = open(log_path, "ab")
    try:
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.ft.cluster", "--worker",
             spec.to_json()],
            env=pinned_env(devices), stdout=log, stderr=subprocess.STDOUT)
    finally:
        log.close()
    return proc, log_path


def _beat_loop(chan: Channel, spec: WorkerSpec,
               stop: threading.Event) -> None:
    """Dedicated heartbeat thread: liveness must keep flowing while the
    main thread sits in a multi-second jit compile or a (simulated)
    collective stall."""
    n = 0
    while not stop.is_set():
        try:
            chan.send({"kind": "beat", "host": spec.host, "n": n})
        except OSError:
            return                     # supervisor gone; main thread exits
        n += 1
        time.sleep(spec.beat_interval_s)


def _await_supervisor(chan: Channel) -> None:
    """Park forever (heartbeats continue from the timer thread).  The
    supervisor never sends, so a read returning means EOF: it is gone and
    this worker must not linger as an orphan."""
    chan.sock.settimeout(None)
    try:
        while chan.sock.recv(4096):
            pass
    except OSError:
        pass
    os._exit(1)


def _save_ckpt(chan: Channel, spec: WorkerSpec, state, ckpt_step: int,
               cursor: int, mesh_shape: list) -> None:
    import jax
    import numpy as np

    from repro.checkpoint import save_checkpoint

    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    hook = None
    if spec.ckpt_hold_step == ckpt_step:
        def hook(i: int) -> None:
            if i == 0:       # first leaf durable; the manifest never lands
                chan.send({"kind": "ckpt_mid", "host": spec.host,
                           "step": ckpt_step})
                _await_supervisor(chan)
    save_checkpoint(spec.ckpt_dir, host_tree, ckpt_step,
                    extra={"mesh_shape": mesh_shape,
                           "global_batch": spec.global_batch,
                           "data_cursor": cursor},
                    after_leaf=hook)
    chan.send({"kind": "ckpt", "host": spec.host, "step": ckpt_step})


def _train_epoch(chan: Channel, spec: WorkerSpec) -> None:
    """The primary's epoch: survivor mesh, newest-valid-checkpoint restore
    (or deterministic init), replay from the cursor, per-step loss + batch
    fingerprint records — the in-process ``run_chaos`` loop, relocated
    into a killable worker.  jax imports are deliberately lazy: standby
    workers never pay them."""
    import jax
    import jax.numpy as jnp

    from repro.checkpoint import restore_checkpoint
    from repro.checkpoint.ckpt import latest_step
    from repro.configs import get_smoke_config
    from repro.data import DataConfig, Pipeline
    from repro.ft.resilience import survivor_devices
    from repro.launch.train import _fingerprint, _host_mesh, _place_state
    from repro.parallel.sharding import default_rules
    from repro.train import (OptConfig, abstract_train_state, make_train_step,
                             train_state_shardings)

    cfg = get_smoke_config(spec.arch)
    opt_cfg = OptConfig(lr=spec.lr, warmup_steps=max(2, spec.steps // 10),
                        total_steps=spec.steps)
    keep = survivor_devices(spec.failed, spec.devices_per_host, jax.devices())
    dp = len(keep) // spec.model_axis
    mesh = _host_mesh(keep, dp, spec.model_axis)
    rules = default_rules(mesh, batch=spec.global_batch)
    if latest_step(spec.ckpt_dir) is not None:
        state, rstep, _ = restore_checkpoint(
            spec.ckpt_dir, abstract_train_state(cfg, opt_cfg),
            shardings=train_state_shardings(cfg, opt_cfg, rules))
        rstep = int(rstep)
    else:
        state, rstep = _place_state(cfg, opt_cfg, spec.seed, rules), 0
    chan.send({"kind": "epoch", "host": spec.host, "restore_step": rstep,
               "mesh_shape": [dp, spec.model_axis]})

    step_fn = jax.jit(make_train_step(cfg, rules, opt_cfg,
                                      n_microbatches=spec.n_microbatches))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=spec.seq_len,
                      global_batch=spec.global_batch, seed=spec.seed)
    pipe = Pipeline(dcfg, start_step=rstep)
    fences = set(spec.fence_steps)
    for step in range(rstep, spec.steps):
        batch_np = next(pipe)
        state, metrics = step_fn(state, {"tokens": jnp.asarray(batch_np)})
        chan.send({"kind": "step", "host": spec.host, "step": step,
                   "loss": float(metrics["loss"]),
                   "fp": _fingerprint(batch_np)})
        if (step + 1) % spec.ckpt_every == 0:
            _save_ckpt(chan, spec, state, step + 1, pipe.cursor,
                       [dp, spec.model_axis])
        if step in fences:
            # a peer is about to be SIGKILLed: real SPMD survivors would
            # block at the next collective — model that stall for real
            chan.send({"kind": "fence", "host": spec.host, "step": step})
            pipe.close()
            _await_supervisor(chan)
    pipe.close()
    chan.send({"kind": "done", "host": spec.host, "steps": spec.steps})


def worker_main(spec_json: str) -> int:
    spec = WorkerSpec.from_json(spec_json)
    sock = socket.create_connection((_LOOPBACK, spec.port), timeout=10.0)
    chan = Channel(sock)
    chan.send({"kind": "hello", "host": spec.host, "pid": os.getpid(),
               "role": spec.role})
    stop = threading.Event()
    threading.Thread(target=_beat_loop, args=(chan, spec, stop),
                     daemon=True).start()
    if spec.role == ROLE_PRIMARY:
        _train_epoch(chan, spec)
        stop.set()
        sock.close()
        return 0
    _await_supervisor(chan)            # standby: heartbeat until killed
    return 1


# ---------------------------------------------------------------------------
# Supervisor
# ---------------------------------------------------------------------------

class _EpochIO:
    """Supervisor-side socket plumbing for one epoch: accept connections,
    reassemble frames, swallow EOFs (a SIGKILLed worker's socket closes
    instantly, but *detection authority stays with the heartbeat
    timeout* — that is the mechanism under test)."""

    def __init__(self, listener: socket.socket):
        self.listener = listener
        self.sel = selectors.DefaultSelector()
        self.sel.register(listener, selectors.EVENT_READ, "listener")
        self._framers: dict[socket.socket, Framer] = {}

    def poll(self, timeout: float = 0.05) -> list[dict]:
        out: list[dict] = []
        for key, _ in self.sel.select(timeout):
            if key.data == "listener":
                conn, _ = self.listener.accept()
                conn.setblocking(False)
                self.sel.register(conn, selectors.EVENT_READ, "conn")
                self._framers[conn] = Framer()
                continue
            conn = key.fileobj
            try:
                data = conn.recv(65536)
            except (BlockingIOError, InterruptedError):
                continue
            except OSError:
                data = b""
            if not data:
                self.sel.unregister(conn)
                conn.close()
                self._framers.pop(conn, None)
                continue
            out.extend(self._framers[conn].feed(data))
        return out

    def close(self) -> None:
        for conn in list(self._framers):
            try:
                self.sel.unregister(conn)
            except (KeyError, ValueError):
                pass
            conn.close()
        self._framers.clear()
        self.sel.close()


def _tail(log_path: pathlib.Path, n: int = 20) -> str:
    try:
        lines = log_path.read_text(errors="replace").splitlines()
    except OSError:
        return f"<no log at {log_path}>"
    return "\n".join(lines[-n:])


def _reap(procs, grace_s: float = 10.0) -> None:
    """SIGTERM every still-running worker, escalate to SIGKILL after the
    grace period — the supervisor never leaves orphans."""
    for p in procs:
        if p.poll() is None:
            p.terminate()
    for p in procs:
        try:
            p.wait(timeout=grace_s)
        except subprocess.TimeoutExpired:
            p.kill()
            p.wait()


class ClusterSupervisor:
    """Drive one elastic training run across real worker processes.

    Per epoch: spawn one worker per alive host (lowest id is the primary
    trainer, the rest heartbeat-only standbys), collect hellos, then arm
    the ``HeartbeatMonitor`` on the real monotonic clock.  Faults from the
    schedule are delivered as real SIGKILLs — at a ``fence`` for plain
    kills, at ``ckpt_mid`` (writer parked mid-save by the ``after_leaf``
    hook) for ``ckpt_crash``.  When every expected victim has missed its
    heartbeat deadline, the epoch is torn down and the PR 8 state machine
    runs for real: ``RestartPolicy`` backoff (a real sleep),
    ``plan_rescale`` over the survivors, respawn, newest-valid-checkpoint
    restore, bit-exact replay.  ``run()`` returns the ``run_chaos`` result
    shape plus real detection latencies.
    """

    def __init__(self, arch: str = "llama3-8b", *, steps: int = 10,
                 n_hosts: int = 4, n_devices: int = 8, model_axis: int = 2,
                 global_batch: int = 8, seq_len: int = 32, lr: float = 3e-3,
                 seed: int = 0, ckpt_dir: str | None = None,
                 ckpt_every: int = 4, chaos_spec: str | None = None,
                 timeout_s: float = 2.5, beat_interval_s: float = 0.1,
                 max_restarts: int = 3, backoff_s: float = 0.05,
                 max_backoff_s: float = 1.0, n_microbatches: int = 1,
                 spawn_timeout_s: float = 300.0, logdir: str | None = None,
                 verbose: bool = True):
        if n_devices % n_hosts:
            raise ValueError(f"{n_devices} devices not divisible into "
                             f"{n_hosts} hosts")
        self.dph = n_devices // n_hosts
        if n_devices % model_axis or self.dph % model_axis:
            raise ValueError(
                f"model axis {model_axis} must divide both the device count "
                f"{n_devices} and devices/host {self.dph} (hosts own whole "
                f"dp rows — AraXL loses clusters, never lanes)")
        self.schedule = ChaosSchedule.parse(chaos_spec or "")
        bad = [e.kind for e in self.schedule.events if e.kind == STRAGGLE]
        if bad:
            raise ValueError(
                "straggle events are virtual-clock-only (deterministic real "
                "slowness cannot be injected into an OS process); --procs "
                "supports kill and ckpt_crash")
        self.arch, self.steps, self.seed = arch, steps, seed
        self.n_hosts, self.n_devices = n_hosts, n_devices
        self.model_axis = model_axis
        self.global_batch, self.seq_len, self.lr = global_batch, seq_len, lr
        self.n_microbatches = n_microbatches
        self.ckpt_dir = ckpt_dir or tempfile.mkdtemp(
            prefix="repro_chaos_procs_ckpt_")
        self.ckpt_every = ckpt_every
        self.timeout_s = timeout_s
        self.beat_interval_s = beat_interval_s
        self.max_restarts, self.backoff_s = max_restarts, backoff_s
        self.max_backoff_s = max_backoff_s
        self.spawn_timeout_s = spawn_timeout_s
        self.logdir = logdir or tempfile.mkdtemp(prefix="repro_chaos_procs_")
        self.verbose = verbose

    # -- fault bookkeeping --------------------------------------------------

    def _consume_ckpt_crash(self) -> None:
        for e in self._pending:
            if e.kind == CKPT_CRASH:
                self._pending.remove(e)
                return

    def _next_hold_step(self) -> int | None:
        """The checkpoint step the next pending ``ckpt_crash`` tears: the
        first save strictly after the event step (same semantics as the
        virtual injector's tear-next-save)."""
        for e in self._pending:
            if e.kind == CKPT_CRASH:
                return (e.step // self.ckpt_every + 1) * self.ckpt_every
        return None

    # -- epoch --------------------------------------------------------------

    def _run_epoch(self, listener, procs, logs, alive, primary,
                   expected_restore, expected_mesh):
        """Returns ``None`` when the primary finishes, else
        ``(lost_hosts, detect_s, last_step)`` once every expected victim
        has missed its heartbeat deadline."""
        io = _EpochIO(listener)
        monitor = None
        hello: set[int] = set()
        expected_dead: set[int] = set()
        kill_at = None
        last_step = None
        hello_deadline = monotonic() + self.spawn_timeout_s
        try:
            while True:
                for msg in io.poll():
                    kind, h = msg["kind"], msg.get("host")
                    if kind == "hello":
                        hello.add(h)
                        if monitor is None and hello >= set(alive):
                            monitor = HeartbeatMonitor(
                                hosts=alive, timeout_s=self.timeout_s,
                                clock=monotonic)
                    elif kind == "beat":
                        if monitor is not None and h in monitor.hosts:
                            monitor.beat(h, msg["n"])
                    elif kind == "epoch":
                        assert msg["restore_step"] == expected_restore, \
                            (msg, expected_restore)
                        assert msg["mesh_shape"] == expected_mesh, \
                            (msg, expected_mesh)
                        self._timeline.append(
                            {"event": "epoch", "host": h,
                             "restore_step": msg["restore_step"],
                             "mesh_shape": msg["mesh_shape"]})
                    elif kind == "step":
                        s = msg["step"]
                        prev = self._fps.get(s)
                        assert prev is None or prev == msg["fp"], \
                            f"replay diverged at step {s}: " \
                            f"{prev} != {msg['fp']}"
                        self._fps[s] = msg["fp"]
                        self._losses[s] = msg["loss"]
                        self._steps_executed += 1
                        last_step = s
                    elif kind == "ckpt":
                        self._timeline.append({"event": "ckpt",
                                               "step": msg["step"]})
                    elif kind == "ckpt_mid":
                        # the writer is parked mid-save: kill it for real
                        self._consume_ckpt_crash()
                        self._timeline.append({"event": "ckpt_mid_kill",
                                               "ckpt_step": msg["step"],
                                               "host": h})
                        procs[h].kill()
                        expected_dead.add(h)
                        kill_at = monotonic()
                    elif kind == "fence":
                        victims = [e.host for e in self._pending
                                   if e.kind == KILL
                                   and e.step == msg["step"]
                                   and e.host in alive]
                        self._pending = [
                            e for e in self._pending
                            if not (e.kind == KILL and e.step == msg["step"]
                                    and e.host in alive)]
                        self._timeline.append({"event": "fence",
                                               "step": msg["step"],
                                               "kills": victims})
                        for v in victims:
                            procs[v].kill()
                            expected_dead.add(v)
                        kill_at = monotonic()
                    elif kind == "done":
                        return None
                if monitor is None:
                    if monotonic() > hello_deadline:
                        raise RuntimeError(
                            f"workers failed to connect within "
                            f"{self.spawn_timeout_s}s; logs: " +
                            "; ".join(str(p) for p in logs.values()))
                    for h2, p in procs.items():
                        if h2 not in hello and p.poll() is not None:
                            raise RuntimeError(
                                f"worker h{h2} died before hello "
                                f"(rc={p.returncode})\n{_tail(logs[h2])}")
                    continue
                dead = set(monitor.dead_hosts())
                if dead and expected_dead <= dead:
                    detect_s = (monotonic() - kill_at
                                if kill_at is not None else None)
                    if not expected_dead:
                        # died without an injected fault: surface the logs,
                        # then drive the restart machine anyway — that IS
                        # the production path
                        self._timeline.append(
                            {"event": "unexpected_loss",
                             "hosts": sorted(dead),
                             "logs": {h3: _tail(logs[h3]) for h3 in dead}})
                    return dead, detect_s, last_step
        finally:
            io.close()
            _reap(procs.values())

    # -- run ----------------------------------------------------------------

    def run(self) -> dict:
        from repro.checkpoint.ckpt import latest_step

        listener = socket.socket()
        listener.bind((_LOOPBACK, 0))
        listener.listen(self.n_hosts + 2)
        port = listener.getsockname()[1]

        self._pending = list(self.schedule.events)
        self._losses: dict[int, float] = {}
        self._fps: dict[int, int] = {}
        self._timeline: list[dict] = []
        self._steps_executed = 0
        restarts: list[dict] = []
        policy = RestartPolicy(max_restarts=self.max_restarts,
                               backoff_s=self.backoff_s, clock=monotonic,
                               max_backoff_s=self.max_backoff_s)
        failed: set[int] = set()
        expected_restore = latest_step(self.ckpt_dir) or 0
        expected_mesh = [self.n_devices // self.model_axis, self.model_axis]
        epochs = 0
        try:
            while True:
                epochs += 1
                alive = sorted(set(range(self.n_hosts)) - failed)
                primary = alive[0]
                kill_steps = sorted({e.step for e in self._pending
                                     if e.kind == KILL and e.host in alive})
                hold = self._next_hold_step()
                if self.verbose:
                    print(f"[cluster] epoch {epochs}: hosts {alive}, "
                          f"primary h{primary}, mesh {expected_mesh}, "
                          f"restore {expected_restore}", flush=True)
                procs, logs = {}, {}
                for h in alive:
                    is_primary = h == primary
                    spec = WorkerSpec(
                        host=h, n_hosts=self.n_hosts, port=port,
                        role=ROLE_PRIMARY if is_primary else ROLE_STANDBY,
                        devices_per_host=self.dph,
                        model_axis=self.model_axis, arch=self.arch,
                        steps=self.steps, seed=self.seed,
                        global_batch=self.global_batch,
                        seq_len=self.seq_len, lr=self.lr,
                        n_microbatches=self.n_microbatches,
                        ckpt_dir=self.ckpt_dir, ckpt_every=self.ckpt_every,
                        failed=sorted(failed),
                        fence_steps=kill_steps if is_primary else [],
                        ckpt_hold_step=hold if is_primary else None,
                        beat_interval_s=self.beat_interval_s)
                    procs[h], logs[h] = spawn_worker(spec, self.logdir,
                                                     devices=self.n_devices)
                outcome = self._run_epoch(listener, procs, logs, alive,
                                          primary, expected_restore,
                                          expected_mesh)
                if outcome is None:
                    break
                lost, detect_s, last_step = outcome
                if not policy.should_restart():
                    raise RuntimeError(
                        f"restart budget exhausted after {policy.restarts} "
                        f"restarts (lost {sorted(lost)}); worker logs under "
                        f"{self.logdir}")
                delay = policy.next_delay()
                time.sleep(delay)              # real backoff on a real clock
                failed |= set(lost)
                plan = plan_rescale(
                    old_devices=len(alive) * self.dph,
                    lost_hosts=len(lost), devices_per_host=self.dph,
                    mesh_axes=tuple(expected_mesh),
                    global_batch=self.global_batch,
                    restore_step=latest_step(self.ckpt_dir) or 0)
                if plan.new_global_batch != self.global_batch:
                    raise ValueError(
                        f"global batch {self.global_batch} not divisible by "
                        f"the rescaled dp={plan.new_mesh_shape[0]} — "
                        f"bit-identical replay needs a batch divisible by "
                        f"every survivable dp size ({plan.notes})")
                restarts.append({
                    "detected_at_step": last_step,
                    "lost_hosts": sorted(lost),
                    "restore_step": plan.restore_step,
                    "new_mesh_shape": list(plan.new_mesh_shape),
                    "new_devices": plan.new_devices, "notes": plan.notes,
                    "detect_s": detect_s, "backoff_s": delay})
                self._timeline.append({"event": "restart",
                                       "lost": sorted(lost),
                                       "restore_step": plan.restore_step})
                if self.verbose:
                    det = (f"detected in {detect_s:.2f}s"
                           if detect_s is not None else "uninjected loss")
                    print(f"[cluster] RESTART #{len(restarts)}: lost "
                          f"{sorted(lost)} ({det}), restore step "
                          f"{plan.restore_step} onto {plan.new_mesh_shape}",
                          flush=True)
                expected_restore = plan.restore_step
                expected_mesh = list(plan.new_mesh_shape)
        finally:
            listener.close()
        losses = [self._losses[s] for s in range(self.steps)]
        return {"losses": losses, "losses_by_step": self._losses,
                "final_loss": losses[-1] if losses else None,
                "fingerprints": self._fps, "restarts": restarts,
                "n_restarts": len(restarts), "timeline": self._timeline,
                "chaos_spec": self.schedule.to_spec(),
                "ckpt_dir": self.ckpt_dir, "logdir": self.logdir,
                "steps_executed": self._steps_executed,
                "final_mesh_shape": expected_mesh, "epochs": epochs,
                "mode": "procs"}


# ---------------------------------------------------------------------------
# Heartbeat drill: the docs' executable core, no jax in any process
# ---------------------------------------------------------------------------

def drill(n_workers: int = 2, kill_host: int = 1, *, timeout_s: float = 1.0,
          beat_interval_s: float = 0.05, deadline_s: float = 120.0) -> dict:
    """SIGKILL one heartbeat-only worker and time the monitor detecting it.

    The tentpole's mechanism in isolation: real processes, real socket
    beats, a real SIGKILL, detection purely by missed-heartbeat deadline
    on the monotonic clock.  Workers are standby-role (no jax import), so
    the whole drill runs in a couple of seconds — docs/RESILIENCE.md
    executes it in CI.  Returns ``{"dead": [...], "detect_s": ...}``."""
    assert 0 <= kill_host < n_workers
    listener = socket.socket()
    listener.bind((_LOOPBACK, 0))
    listener.listen(n_workers + 2)
    port = listener.getsockname()[1]
    logdir = tempfile.mkdtemp(prefix="repro_drill_")
    procs, logs = {}, {}
    for h in range(n_workers):
        spec = WorkerSpec(host=h, n_hosts=n_workers, port=port,
                          role=ROLE_STANDBY, beat_interval_s=beat_interval_s)
        procs[h], logs[h] = spawn_worker(spec, logdir, devices=1)
    io = _EpochIO(listener)
    monitor = None
    kill_at = None
    deadline = monotonic() + deadline_s
    try:
        hello: set[int] = set()
        while monotonic() < deadline:
            for msg in io.poll():
                if msg["kind"] == "hello":
                    hello.add(msg["host"])
                    if monitor is None and len(hello) == n_workers:
                        monitor = HeartbeatMonitor(
                            hosts=range(n_workers), timeout_s=timeout_s,
                            clock=monotonic)
                elif msg["kind"] == "beat" and monitor is not None:
                    monitor.beat(msg["host"], msg["n"])
            if monitor is None:
                continue
            if kill_at is None:
                procs[kill_host].kill()        # a real SIGKILL
                kill_at = monotonic()
            dead = monitor.dead_hosts()
            if kill_host in dead:
                return {"dead": sorted(dead),
                        "detect_s": monotonic() - kill_at}
        raise RuntimeError(
            f"drill timed out after {deadline_s}s; logs under {logdir}: " +
            "; ".join(_tail(p, 5) for p in logs.values()))
    finally:
        io.close()
        _reap(procs.values())
        listener.close()


# ---------------------------------------------------------------------------
# CLI (`python -m repro.ft.cluster`; `--worker` is the child entry point)
# ---------------------------------------------------------------------------

def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="multi-process chaos supervisor (see docs/RESILIENCE.md)")
    ap.add_argument("--worker", metavar="SPEC_JSON", default=None,
                    help=argparse.SUPPRESS)   # internal child entry point
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--hosts", type=int, default=4)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--model-axis", type=int, default=2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=4)
    ap.add_argument("--chaos-spec", default=None,
                    metavar="kill@S:hH,ckpt_crash@S")
    ap.add_argument("--timeout", type=float, default=2.5,
                    help="heartbeat timeout (real seconds)")
    ap.add_argument("--max-restarts", type=int, default=3)
    args = ap.parse_args(argv)
    if args.worker is not None:
        return worker_main(args.worker)
    sup = ClusterSupervisor(
        args.arch, steps=args.steps, n_hosts=args.hosts,
        n_devices=args.devices, model_axis=args.model_axis,
        global_batch=args.batch, seq_len=args.seq, seed=args.seed,
        ckpt_dir=args.ckpt, ckpt_every=args.ckpt_every,
        chaos_spec=args.chaos_spec, timeout_s=args.timeout,
        max_restarts=args.max_restarts)
    out = sup.run()
    print(f"[cluster] done: {out['n_restarts']} restart(s) across "
          f"{out['epochs']} epoch(s), final mesh {out['final_mesh_shape']}, "
          f"first loss {out['losses'][0]:.4f} final {out['final_loss']:.4f} "
          f"(schedule: {out['chaos_spec'] or 'none'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
