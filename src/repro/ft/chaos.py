"""Deterministic fault injection for the chaos-tested elastic trainer.

AraXL's physical-scalability claim has a software twin: the training job
must keep working as hosts die and straggle, the way the machine keeps
working as lanes and clusters multiply.  This module is the *adversary*
side of that story — a seeded, replayable schedule of faults that drives
``repro.ft.resilience`` (HeartbeatMonitor / StragglerMitigator /
RestartPolicy / plan_rescale) through ``launch.train --chaos``.

Everything here is pure Python + numpy (no jax import) and runs on a
**virtual clock**: the injector advances time by the simulated step
duration instead of sleeping, so a 12-step chaos run with a 3.5 s heartbeat
timeout executes in milliseconds and is bit-reproducible from
``(chaos_seed,)`` alone.  Wall-clock discipline (lint L4) is moot by
construction — no raw clock is ever read.

Schedule format (one string, CLI- and manifest-friendly)::

    kill@5:h0,straggle@1:h1:x2.5:d2,ckpt_crash@5

comma-separated events, each ``kind@step`` plus fields:

    kill@S:hH          host H stops heartbeating after step S
    straggle@S:hH:xF:dD   host H runs F x slower for D steps from step S
    ckpt_crash@S       the next checkpoint written after step S is torn
                       (crash mid-publish; restore must skip it)

``ChaosSchedule.from_seed`` draws an equivalent schedule deterministically
from a seed; ``to_spec`` round-trips it back to the string form so every
chaos run can record exactly what was injected.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .resilience import HeartbeatMonitor, StragglerMitigator

KILL = "kill"
STRAGGLE = "straggle"
CKPT_CRASH = "ckpt_crash"
_KINDS = (KILL, STRAGGLE, CKPT_CRASH)


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One injected fault.  ``host`` is the *original* host id (the id
    space never renumbers across rescales, exactly like slot ids in the
    serving engine)."""
    kind: str
    step: int
    host: int | None = None
    factor: float = 1.0        # straggle slowdown multiplier
    duration: int = 1          # straggle length in steps

    def spec(self) -> str:
        parts = [f"{self.kind}@{self.step}"]
        if self.host is not None:
            parts.append(f"h{self.host}")
        if self.kind == STRAGGLE:
            parts.append(f"x{self.factor:g}")
            parts.append(f"d{self.duration}")
        return ":".join(parts)


@dataclasses.dataclass(frozen=True)
class ChaosSchedule:
    events: tuple = ()

    @classmethod
    def parse(cls, spec: str) -> "ChaosSchedule":
        events = []
        for tok in (t.strip() for t in (spec or "").split(",")):
            if not tok:
                continue
            head, _, rest = tok.partition(":")
            kind, _, step_s = head.partition("@")
            if kind not in _KINDS:
                raise ValueError(f"unknown chaos event kind {kind!r} "
                                 f"(expected one of {_KINDS})")
            host, factor, duration = None, 1.0, 1
            for field in (f for f in rest.split(":") if f):
                if field[0] == "h":
                    host = int(field[1:])
                elif field[0] == "x":
                    factor = float(field[1:])
                elif field[0] == "d":
                    duration = int(field[1:])
                else:
                    raise ValueError(f"unknown chaos event field {field!r}")
            if kind != CKPT_CRASH and host is None:
                raise ValueError(f"{kind} event needs a :hH host field: "
                                 f"{tok!r}")
            events.append(ChaosEvent(kind, int(step_s), host, factor,
                                     duration))
        return cls(tuple(sorted(events, key=lambda e: (e.step, e.kind))))

    @classmethod
    def from_seed(cls, seed: int, *, steps: int, n_hosts: int,
                  n_kills: int = 1, n_straggles: int = 1,
                  n_ckpt_crashes: int = 0,
                  straggle_factor: float = 2.5) -> "ChaosSchedule":
        """A deterministic schedule: straggles land in the first half of the
        run (so EWMAs have steps to recover), kills in the middle window (so
        a checkpoint exists before and steps remain after), each kill on a
        distinct host.  The same ``(seed, steps, n_hosts, ...)`` always
        yields the identical schedule."""
        rng = np.random.default_rng(np.random.SeedSequence([seed, steps,
                                                            n_hosts]))
        events = []
        lo, hi = max(1, steps // 3), max(2, (2 * steps) // 3)
        kill_hosts = rng.choice(n_hosts, size=min(n_kills, n_hosts - 1),
                                replace=False)
        for h in kill_hosts:
            events.append(ChaosEvent(KILL, int(rng.integers(lo, hi + 1)),
                                     int(h)))
        for _ in range(n_straggles):
            events.append(ChaosEvent(
                STRAGGLE, int(rng.integers(1, max(2, steps // 2))),
                int(rng.integers(0, n_hosts)), straggle_factor,
                int(rng.integers(1, 3))))
        for _ in range(n_ckpt_crashes):
            events.append(ChaosEvent(CKPT_CRASH,
                                     int(rng.integers(lo, hi + 1))))
        return cls(tuple(sorted(events, key=lambda e: (e.step, e.kind))))

    def to_spec(self) -> str:
        return ",".join(e.spec() for e in self.events)

    def events_at(self, step: int) -> list:
        return [e for e in self.events if e.step == step]


class VirtualClock:
    """The harness's time source: monotone, advanced explicitly.  Injected
    as the ``clock`` of HeartbeatMonitor / RestartPolicy so timeout and
    backoff semantics are exercised without a single real second passing."""

    def __init__(self, t0: float = 0.0):
        self.t = float(t0)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        assert dt >= 0.0, dt
        self.t += dt
        return self.t


@dataclasses.dataclass(frozen=True)
class ChaosStatus:
    """What the injector observed after one step's tick."""
    step: int
    dead: tuple = ()           # hosts the heartbeat monitor timed out
    stragglers: tuple = ()     # hosts the mitigator wants evicted
    tear_next_save: bool = False   # a ckpt_crash event fired this step
    step_s: float = 0.0        # simulated duration of this step (slowest host)

    @property
    def lost(self) -> tuple:
        return tuple(sorted(set(self.dead) | set(self.stragglers)))


class FaultInjector:
    """Applies a :class:`ChaosSchedule` to a simulated host fleet and runs
    the detection stack (heartbeats + straggler EWMA) on a virtual clock.

    The SPMD contract sets the pacing: one training step takes as long as
    the *slowest alive host* (everyone waits at the collective), so the
    clock advances by ``base_step_s * max(straggle factors)`` each tick and
    every alive host beats once per step.  A killed host simply stops
    beating; the monitor times it out ``timeout_s`` of virtual time later —
    the harness therefore models *detection latency*: steps computed
    between kill and detection are lost work, rolled back at restore.
    """

    def __init__(self, schedule: ChaosSchedule, n_hosts: int, *,
                 timeout_s: float = 3.5, base_step_s: float = 1.0,
                 straggler_threshold: float = 1.5,
                 straggler_patience: int = 3,
                 clock: VirtualClock | None = None):
        self.schedule = schedule
        self.n_hosts = n_hosts
        self.base_step_s = base_step_s
        self.timeout_s = timeout_s
        self.clock = clock if clock is not None else VirtualClock()
        self.alive: set[int] = set(range(n_hosts))
        self.failed: set[int] = set()          # killed or evicted, all-time
        self._straggles: dict[int, list] = {}  # host -> [factor, steps_left]
        self._threshold = straggler_threshold
        self._patience = straggler_patience
        self.monitor = HeartbeatMonitor(hosts=self.alive,
                                        timeout_s=timeout_s,
                                        clock=self.clock)
        self.mitigator = StragglerMitigator(threshold=straggler_threshold,
                                            patience=straggler_patience)

    def tick(self, step: int) -> ChaosStatus:
        tear = False
        for e in self.schedule.events_at(step):
            if e.kind == KILL and e.host in self.alive:
                self.alive.discard(e.host)
                self.failed.add(e.host)
            elif e.kind == STRAGGLE and e.host in self.alive:
                self._straggles[e.host] = [e.factor, e.duration]
            elif e.kind == CKPT_CRASH:
                tear = True
        # per-host durations; the slowest alive host paces the SPMD step
        durations = {}
        for h in self.alive:
            f = self._straggles.get(h, (1.0,))[0]
            durations[h] = self.base_step_s * f
        step_s = max(durations.values()) if durations else self.base_step_s
        self.clock.advance(step_s)
        for h in self.alive:
            self.monitor.beat(h, step, durations[h])
        for h in list(self._straggles):
            self._straggles[h][1] -= 1
            if self._straggles[h][1] <= 0:
                del self._straggles[h]
        dead = tuple(self.monitor.dead_hosts())
        flagged = tuple(self.mitigator.update(
            {h: self.monitor.hosts[h].ewma_step_s for h in self.alive}))
        return ChaosStatus(step=step, dead=dead, stragglers=flagged,
                           tear_next_save=tear, step_s=step_s)

    def evict(self, hosts) -> None:
        """Remove ``hosts`` from the fleet (restart path) and reset the
        detection state for the survivors — a fresh monitor epoch, beats
        starting now, straggler strike counts cleared."""
        self.alive -= set(hosts)
        self.failed |= set(hosts)
        self._straggles = {h: s for h, s in self._straggles.items()
                           if h in self.alive}
        self.monitor = HeartbeatMonitor(hosts=self.alive,
                                        timeout_s=self.timeout_s,
                                        clock=self.clock)
        self.mitigator = StragglerMitigator(threshold=self._threshold,
                                            patience=self._patience)
