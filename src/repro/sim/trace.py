"""TraceMachine — the data-free twin of ``repro.core.isa.AraXLMachine``.

Exposes the same instruction surface but only records
:class:`repro.core.isa.InstrRecord`s with *real register dependencies*
(every virtual register / scalar result carries an id), so the pipeline
model chains exactly the way the hardware would, not by program order.

When constructed with a :class:`repro.topology.Topology`, every slide is
additionally tagged with the wire level its critical path crosses
(``meta["level"]`` — ``"intra"``/``"inter"`` on the paper's two-level
machine, the level's own name, e.g. ``"pod"``, further out) so the engine's
per-level hop pricing and the hierarchy ablations can attribute RINGI
traffic to the right wires.
"""
from __future__ import annotations

import itertools

from repro.core.isa import InstrRecord
from repro.topology import Topology


class _TraceReg:
    __slots__ = ("vl", "id")

    def __init__(self, vl: int, rid: int):
        self.vl = vl
        self.id = rid


class _ScalarResult(float):
    """A float that remembers which instruction produced it (reduction
    results consumed by later vector ops through the scalar core)."""
    def __new__(cls, rid: int):
        obj = super().__new__(cls, 0.0)
        obj.id = rid
        return obj


def _dep(x):
    rid = getattr(x, "id", None)
    return (rid,) if rid is not None else ()


class TraceMachine:
    _EXP_FLOPS = 28.0

    def __init__(self, vlen_bits: int = 65536, sew_bits: int = 64,
                 topology: Topology | None = None):
        self.vlen_bits = vlen_bits
        self.sew_bits = sew_bits
        self.topology = topology
        self.trace: list[InstrRecord] = []
        self._ids = itertools.count(1)

    def _slide_meta(self, hops: int) -> dict:
        meta = {"hops": hops}
        if self.topology is not None:
            meta["level"] = self.topology.slide_level(hops)
        return meta

    @property
    def vlmax(self) -> int:
        return self.vlen_bits // self.sew_bits

    def _rec(self, op, vl, unit, fpe=0.0, deps=(), **meta):
        rid = next(self._ids)
        m = dict(meta) if meta else {}
        m["out"] = rid
        m["deps"] = tuple(d for d in deps if d is not None)
        self.trace.append(InstrRecord(op, int(vl), unit, fpe, m))
        return _TraceReg(int(vl), rid)

    # scalar-core side events (issue model input)
    def scalar_load(self, n: int = 1):
        self.trace.append(InstrRecord("ld", n, "scalar"))

    def scalar_op(self, n: int = 1):
        self.trace.append(InstrRecord("sop", n, "scalar"))

    # ISA surface ----------------------------------------------------------
    def vle(self, x=None, vl=None):
        vl = int(vl if vl is not None else len(x))
        return self._rec("vle64.v", vl, "vlsu")

    def vse(self, r):
        self._rec("vse64.v", r.vl, "vlsu", deps=_dep(r))
        return None

    def vbrd(self, value, vl):
        return self._rec("vmv.v.x", vl, "valu", deps=_dep(value))

    def vid(self, vl):
        return self._rec("vid.v", vl, "valu")

    def _ew(self, op, a, b=None, unit="fpu", fpe=1.0):
        return self._rec(op, a.vl, unit, fpe, deps=_dep(a) + _dep(b))

    def vadd(self, a, b):   return self._ew("vfadd", a, b)
    def vsub(self, a, b):   return self._ew("vfsub", a, b)
    def vmul(self, a, b):   return self._ew("vfmul", a, b)
    def vdiv(self, a, b):   return self._ew("vfdiv", a, b)
    def vmax(self, a, b):   return self._ew("vfmax", a, b)
    def vmin(self, a, b):   return self._ew("vfmin", a, b)

    def vfma(self, a, b, c):
        return self._rec("vfmacc", a.vl, "fpu", 2.0,
                         deps=_dep(a) + _dep(b) + _dep(c))

    def vfmacc_vf(self, acc, scalar, v):
        return self._rec("vfmacc.vf", v.vl, "fpu", 2.0,
                         deps=_dep(acc) + _dep(scalar) + _dep(v))

    def vexp(self, a):
        return self._rec("vexp(poly)", a.vl, "fpu", self._EXP_FLOPS,
                         deps=_dep(a))

    def vmslt(self, a, b):  return self._ew("vmslt", a, b, "masku", 0.0)
    def vmsge(self, a, b):  return self._ew("vmsge", a, b, "masku", 0.0)

    def vmerge(self, m, a, b):
        return self._rec("vmerge", a.vl, "masku",
                         deps=_dep(m) + _dep(a) + _dep(b))

    def vcpop(self, m):
        rid = self._rec("vcpop", m.vl, "masku", deps=_dep(m))
        return _ScalarResult(rid.id)

    def vslide1down(self, a, fill=0.0):
        return self._rec("vfslide1down", a.vl, "sldu", deps=_dep(a),
                         **self._slide_meta(1))

    def vslide1up(self, a, fill=0.0):
        return self._rec("vfslide1up", a.vl, "sldu", deps=_dep(a),
                         **self._slide_meta(1))

    def vslidedown(self, a, k):
        return self._rec("vslidedown.vx", a.vl, "sldu", deps=_dep(a),
                         **self._slide_meta(k))

    def vredsum(self, a):
        r = self._rec("vfredsum", a.vl, "redu", 1.0, deps=_dep(a))
        return _ScalarResult(r.id)

    def vredmax(self, a):
        r = self._rec("vfredmax", a.vl, "redu", 1.0, deps=_dep(a))
        return _ScalarResult(r.id)

    def stripmine(self, total, lmul: int = 1):
        step = self.vlmax * lmul
        off = 0
        while off < total:
            vl = min(step, total - off)
            yield off, vl
            off += vl
