"""Instruction-trace builders for the paper's benchmark kernels (Table I).

Each builder mirrors the structure of the Ara/AraXL assembly kernels (register
blocking, sliding input windows, stripmining) and emits the trace through a
:class:`TraceMachine`.  Problem sizes follow Table I: a matrix row is one long
vector of ``N = n_lanes * bytes_per_lane / 8`` DP elements (weak scaling keeps
bytes/lane constant as lanes grow).
"""
from __future__ import annotations

from typing import Callable

from .params import AraXLParams
from .trace import TraceMachine


def _vl(params: AraXLParams, bytes_per_lane: int) -> int:
    return params.n_lanes * bytes_per_lane // (params.sew_bits // 8)


def fmatmul_trace(v: TraceMachine, params: AraXLParams, bytes_per_lane: int,
                  M: int = 64, K: int = 256, rows_blk: int = 8) -> None:
    """C[M,N] = A[M,K] @ B[K,N]; B rows streamed, ``rows_blk`` accumulators
    resident (the paper's LMUL register grouping)."""
    N = _vl(params, bytes_per_lane)
    for i0 in range(0, M, rows_blk):
        accs = [v.vbrd(0.0, N) for _ in range(rows_blk)]
        for k in range(K):
            b = v.vle(vl=N)
            for r in range(rows_blk):
                v.scalar_load()                     # A[i0+r, k] through d-cache
                accs[r] = v.vfmacc_vf(accs[r], 0.0, b)
        for r in range(rows_blk):
            v.vse(accs[r])


def fconv2d_trace(v: TraceMachine, params: AraXLParams, bytes_per_lane: int,
                  rows: int = 256, fr: int = 7, fc: int = 7) -> None:
    """7x7 convolution, rows as long vectors; a sliding window of ``fr`` input
    rows stays VRF-resident, each output row loads one new input row; column
    taps via chained slide-by-1 (RINGI traffic)."""
    N = _vl(params, bytes_per_lane)
    for r in range(fr):                              # prologue: fill the window
        v.vle(vl=N)
    for i in range(rows - fr + 1):
        if i > 0:
            v.vle(vl=N)                              # one new row
        acc = v.vbrd(0.0, N)
        for r in range(fr):
            shifted = None
            for c in range(fc):
                if c == 0:
                    shifted = v._rec("vmv.v.v", N, "valu")
                else:
                    shifted = v.vslide1down(shifted)
                v.scalar_load()                      # filter coefficient
                acc = v.vfmacc_vf(acc, 0.0, shifted)
        v.vse(acc)


def jacobi2d_trace(v: TraceMachine, params: AraXLParams, bytes_per_lane: int,
                   rows: int = 256) -> None:
    """5-point stencil; 3-row sliding window; horizontal taps by slide-by-1."""
    N = _vl(params, bytes_per_lane)
    top = v.vle(vl=N)
    mid = v.vle(vl=N)
    for i in range(1, rows - 1):
        bot = v.vle(vl=N)
        left = v.vslide1up(mid)
        right = v.vslide1down(mid)
        s = v.vadd(top, bot)
        s = v.vadd(s, left)
        s = v.vadd(s, right)
        res = v.vmul(s, None)
        v.vse(res)
        top, mid = mid, bot


def fdotproduct_trace(v: TraceMachine, params: AraXLParams, bytes_per_lane: int,
                      ) -> None:
    """dot(a, b) with LMUL=8 strips and the 4-stage reduction per strip."""
    total = _vl(params, bytes_per_lane)
    for off, vl in v.stripmine(total, lmul=8):
        a = v.vle(vl=vl)
        b = v.vle(vl=vl)
        p = v.vmul(a, b)
        v.vredsum(p)
        v.scalar_op()                                # accumulate partial


def exp_trace(v: TraceMachine, params: AraXLParams, bytes_per_lane: int) -> None:
    """Elementwise exp: range-reduction masks + polynomial (28 FLOP/elem)."""
    total = _vl(params, bytes_per_lane)
    for off, vl in v.stripmine(total, lmul=1):
        a = v.vle(vl=vl)
        m = v.vmsge(a, None)
        a = v.vmerge(m, a, None)
        e = v.vexp(a)
        v.vse(e)


def softmax_trace(v: TraceMachine, params: AraXLParams, bytes_per_lane: int,
                  rows: int = 64) -> None:
    N = _vl(params, bytes_per_lane)
    for i in range(rows):
        r = v.vle(vl=N)
        m = v.vredmax(r)
        s = v.vsub(r, m)
        e = v.vexp(s)
        d = v.vredsum(e)
        v.vdiv(e, d)
        v.vse(e)


KERNEL_BUILDERS: dict[str, Callable] = {
    "fmatmul": fmatmul_trace,
    "fconv2d": fconv2d_trace,
    "jacobi2d": jacobi2d_trace,
    "fdotproduct": fdotproduct_trace,
    "exp": exp_trace,
    "softmax": softmax_trace,
}

#: peak DP-FLOP/cycle per (lane count) for each kernel — Table I "Max Perf".
def max_perf_flop_per_cycle(kernel: str, n_lanes: int) -> float:
    return {
        "fmatmul": 2.0 * n_lanes,
        "fconv2d": 2.0 * n_lanes,
        "jacobi2d": 1.0 * n_lanes,
        "fdotproduct": 1.0 * n_lanes,
        "exp": 28.0 / 21.0 * n_lanes,
        "softmax": 32.0 / 25.0 * n_lanes,
    }[kernel]


def build_trace(kernel: str, params: AraXLParams, bytes_per_lane: int,
                **kw) -> list:
    # The trace machine carries the shared Topology so slides are tagged with
    # the wire level (intra/inter-cluster) their critical path crosses.
    v = TraceMachine(params.vlen_bits, params.sew_bits,
                     topology=params.topology)
    KERNEL_BUILDERS[kernel](v, params, bytes_per_lane, **kw)
    return v.trace
