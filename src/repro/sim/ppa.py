"""Analytical PPA model (paper §IV-D, Tables II/III).

No physical synthesis is possible in this environment, so the area/power
model is fitted to the paper's published 22-nm numbers and used to check the
*scaling* claims (near-perfect 2x area per lane doubling; interfaces <= ~3%
of area; flat ~40 GFLOPs/W energy efficiency).

Fit notes (all least squares on the paper's three configurations):
* cluster area is strictly linear in cluster count (the paper's point);
* GLSU grows slightly super-linearly, area ~ C*(a + b*log2 C) — the extra
  align/shuffle levels of the deeper power-of-2 network;
* RINGI ~ C^0.80, REQI ~ C^1.04 (fitted exponents);
* mm^2 = kGE * 2.014e-7 — the constant reproduces all three area-efficiency
  rows of Table III to <0.3%;
* power ~ (0.017 + 0.0489 * n_lanes) W/GHz reproduces Table III's
  energy-efficiency rows to ~1.5%.
"""
from __future__ import annotations

import math

from .params import AraXLParams

KGE_PER_CLUSTER = 11354.0 / 4.0       # 16L AraXL = 4 clusters (Table II)
KGE_CVA6 = 936.0
MM2_PER_KGE = 2.014e-7 * 1e3          # mm^2 per kGE
W_PER_GHZ_BASE = 0.017
W_PER_GHZ_PER_LANE = 0.0489


def glsu_kge(n_clusters: int) -> float:
    return n_clusters * (63.75 + 4.5 * math.log2(max(2, n_clusters)))


def ringi_kge(n_clusters: int) -> float:
    return 8.23 * n_clusters ** 0.80


def reqi_kge(n_clusters: int) -> float:
    return 8.05 * n_clusters ** 1.04


def area_breakdown_kge(params: AraXLParams) -> dict[str, float]:
    c = params.n_clusters
    parts = {
        "clusters": KGE_PER_CLUSTER * c,
        "cva6": KGE_CVA6,
        "glsu": glsu_kge(c),
        "ringi": ringi_kge(c),
        "reqi": reqi_kge(c),
    }
    parts["total"] = sum(parts.values())
    return parts


def area_mm2(params: AraXLParams) -> float:
    return area_breakdown_kge(params)["total"] * MM2_PER_KGE


def power_w(params: AraXLParams) -> float:
    """Power running fmatmul in the long-vector regime (TT, 0.8 V, 25 C)."""
    return (W_PER_GHZ_BASE + W_PER_GHZ_PER_LANE * params.n_lanes) * params.freq_ghz


def peak_gflops(params: AraXLParams, utilization: float = 1.0) -> float:
    return 2.0 * params.n_lanes * params.freq_ghz * utilization


def energy_eff_gflops_per_w(params: AraXLParams, utilization: float) -> float:
    return peak_gflops(params, utilization) / power_w(params)


def area_eff_gflops_per_mm2(params: AraXLParams, utilization: float) -> float:
    return peak_gflops(params, utilization) / area_mm2(params)


def interface_area_fraction(params: AraXLParams) -> float:
    parts = area_breakdown_kge(params)
    return (parts["glsu"] + parts["ringi"] + parts["reqi"]) / parts["total"]
