"""The paper's published numbers, verbatim — ground truth for validation.

AraXL (Kunhi Purayil, Perotti, Fischer, Benini; 2025), 22 nm, TT/0.8V/25C.
"""

# Table II — area breakdown [kGE] per configuration (16/32/64 lanes)
TABLE_II_KGE = {
    16: {"clusters": 11354, "cva6": 936, "glsu": 291, "ringi": 25, "reqi": 34,
         "total": 12641},
    32: {"clusters": 22708, "cva6": 901, "glsu": 618, "ringi": 44, "reqi": 81,
         "total": 24352},
    64: {"clusters": 45415, "cva6": 931, "glsu": 1385, "ringi": 76, "reqi": 144,
         "total": 47950},
}

# Table III — PPA comparison (AraXL rows)
TABLE_III = {
    # lanes: (freq GHz, max perf GFLOPs, energy eff GFLOPs/W, area eff GFLOPs/mm2)
    16: (1.40, 44.3, 39.6, 17.4),
    32: (1.40, 87.2, 40.4, 17.8),
    64: (1.15, 146.0, 40.1, 15.1),
}
ARA2_16 = (1.08, 34.2, 30.3, 11.6)
VITRUVIUS_8 = (1.40, 22.4, 47.3, 17.23)

# §IV-B / Fig. 6 headline numbers
FMATMUL_UTIL_64L_LONG = 0.99       # ">99% utilization" / "up to 99%"
FCONV2D_UTIL_64L_LONG = 0.97
SOFTMAX_SCALE_64L = 7.3            # normalized to 8-lane Ara2, 512 B/lane
FDOT_SCALE_64L = 6.1
FDOT_SCALE_64L_16KIB = 7.6         # 16384 B/lane, 16 strip iterations
LONG_VECTOR_REGIME_B_PER_LANE = 128

# §IV-C / Fig. 7 — utilization drop upper bounds with interface cuts
GLSU_CUT_REGS = 4                  # +8 cycles request-response
GLSU_MAX_DROP = 0.015
REQI_CUT_REGS = 1                  # +2 cycles ack
REQI_DROP_FCONV_128 = 0.05
REQI_DROP_JACOBI_128 = 0.03
RINGI_CUT_REGS = 1                 # +1 cycle/hop
RINGI_MAX_DROP_LONG = 0.014
OVERALL_LONG_VECTOR_DROP = 0.02    # "less than 2% in the long-vector regime"

# §V conclusions
ENERGY_EFF_64L = 40.1
FREQ_64L = 1.15
