"""Microarchitectural parameters of the simulated machines.

Structural numbers (lanes/cluster, reduction stages, interface registers) come
straight from the paper; a handful of latency constants are calibrated once so
the model hits the paper's reported operating points (Fig. 6/7) and then kept
frozen — see tests/test_sim_paper.py for the asserted bands and
benchmarks/run.py fig6 for the full curves.

Machine *geometry* lives in :class:`repro.topology.Topology` — the same type
the emulation layer (`repro.core.machine.make_machine`) and the launch layer
consume.  ``AraXLParams`` composes one (``params.topology``) from its lane
grid and interface latencies, and every geometry-dependent price
(``red_tree_lat``, ``slide_cost``, per-level ``hop_cost``) routes through it,
so the analytical model and the JAX emulator always price the same
interconnect.

The topology is an ordered list of levels.  With the default ``n_pods=1`` it
is the paper's two-level (cluster, lane) machine and ``hierarchy`` selects
between the §III-B.4 design (``"two-level"``, the calibrated default: intra-
cluster and inter-cluster wires priced separately) and the flattened ring the
paper argues against (``"flat"``: every hop a long-wire RINGI hop).  Setting
``n_pods > 1`` grows a third, outermost (pod, cluster, lane) level priced at
``pod_hop`` cycles/hop — the beyond-paper multi-pod scaling surface; all
pricing methods dispatch over the level list, so deeper hierarchies need no
new code here.
"""
from __future__ import annotations

import dataclasses
import functools
import math

from repro.topology import Level, Topology, check_hierarchy, hier_name


@dataclasses.dataclass(frozen=True)
class AraXLParams:
    name: str = "araxl"
    n_lanes: int = 64                 # total FPUs (= lanes; 1 DP-FPU per lane)
    lanes_per_cluster: int = 4        # the max-efficiency Ara2 building block
    n_pods: int = 1                   # >1 adds an outermost (pod) ring level
    hierarchy: str = "two-level"      # §III-B.4 interconnect (vs "flat" ring)
    vlen_bits: int = 65536            # 64 Kibit/vreg (RVV 1.0 maximum)
    sew_bits: int = 64                # DP evaluation, as in the paper
    freq_ghz: float = 1.15            # 64L typical corner (1.4 up to 32L)

    # --- scalar / dispatch side ------------------------------------------
    issue_gap: float = 3.5            # CVA6 -> sequencer accept, cycles/instr
    reqi_regs: int = 0                # Fig 7(b): +1 reg => ack +2 cycles
    scalar_op_gap: float = 1.0        # bookkeeping scalar ops between vector instrs
    dcache_lat: float = 6.0           # scalar load (e.g. A[i,k]) through d-cache
    inflight: int = 8                 # dispatch window (outstanding vector instrs)

    # --- vector units ------------------------------------------------------
    chain_lat: float = 6.0            # producer->consumer chaining delay
    fpu_lat: float = 5.0              # FPU pipeline depth (drain per instr)
    vlsu_setup: float = 14.0          # AXI request + L2 access latency
    glsu_regs: int = 0                # Fig 7(a): +4 regs => +8 cycles req-resp
    ringi_regs: int = 0               # Fig 7(c): +1 reg => +1 cycle/hop
    ring_hop: float = 4.0             # base inter-cluster hop latency
    intra_hop: float = 2.0            # short-wire intra-cluster sldu hop
    pod_hop: float = 8.0              # inter-pod ring hop (n_pods > 1 only)
    interlane_lat: float = 6.0        # intra-cluster A2A stage latency
    simd_red_cycles: float = 4.0      # final SIMD reduction stage

    def __post_init__(self):
        if self.n_lanes < 1 or self.lanes_per_cluster < 1 or self.n_pods < 1:
            raise ValueError(f"need n_lanes/lanes_per_cluster/n_pods >= 1, "
                             f"got {self.n_lanes}/{self.lanes_per_cluster}/"
                             f"{self.n_pods}")
        if self.n_lanes % self.lanes_per_cluster:
            raise ValueError(
                f"n_lanes ({self.n_lanes}) must be a multiple of "
                f"lanes_per_cluster ({self.lanes_per_cluster}); use "
                f"with_lanes()/with_grid() which keep the grid consistent")
        if self.n_clusters % self.n_pods:
            raise ValueError(
                f"n_pods ({self.n_pods}) must divide the cluster count "
                f"({self.n_clusters})")
        # "flat", or the hierarchical model spelled at this machine's depth
        # (with_pods/with_lanes respell it when the depth changes)
        check_hierarchy(self.hierarchy, self.n_levels)

    # --- derived -----------------------------------------------------------
    @property
    def n_levels(self) -> int:
        """Topology depth: (cluster, lane), plus a pod level when grouped."""
        return 2 if self.n_pods == 1 else 3

    @property
    def n_clusters(self) -> int:
        """Total clusters across every pod (= the innermost level's group
        count; the Topology folds pods in the same way)."""
        return self.n_lanes // self.lanes_per_cluster

    @property
    def clusters_per_pod(self) -> int:
        return self.n_clusters // self.n_pods

    @property
    def vlmax(self) -> int:
        return self.vlen_bits // self.sew_bits

    @property
    def glsu_lat(self) -> float:
        """Memory request-response latency through the GLSU pipeline."""
        return self.vlsu_setup + 2.0 * self.glsu_regs

    @property
    def reqi_lat(self) -> float:
        return 2.0 * self.reqi_regs

    @property
    def hop_lat(self) -> float:
        """One inter-cluster RINGI hop (base + Fig 7(c) register cuts)."""
        return self.ring_hop + self.ringi_regs

    @functools.cached_property
    def topology(self) -> Topology:
        """The shared machine geometry — the *same* value
        ``repro.core.machine.make_machine(topology=...)`` consumes.  Two
        levels (cluster, lane) for the paper's machine; (pod, cluster,
        lane) once ``n_pods > 1``.  Cached: the engine prices every sldu
        record through it."""
        if self.n_pods == 1:
            return Topology(self.n_clusters, self.lanes_per_cluster,
                            hierarchy=self.hierarchy,
                            intra_hop_lat=self.intra_hop,
                            inter_hop_lat=self.hop_lat)
        levels = (Level("pod", self.n_pods, self.pod_hop),
                  Level("cluster", self.clusters_per_pod, self.hop_lat),
                  Level("lane", self.lanes_per_cluster, self.intra_hop))
        return Topology(levels=levels, hierarchy=self.hierarchy)

    def slide_cost(self, hops: int) -> float:
        """Ring cycles before a slide by ``hops`` can stream (critical-path
        priced per wire level by the topology)."""
        return self.topology.slide_cost(hops)

    def hop_cost(self, src: int, dst: int) -> float:
        """Per-level price of one transfer between flattened ring positions
        (each link priced by the outermost boundary it crosses)."""
        return self.topology.hop_cost(src, dst)

    def red_tree_lat(self) -> float:
        """Inter-lane + inter-cluster (+ inter-pod) log-tree latency
        (vl-independent; this is exactly why reductions break weak scaling
        in Fig. 6).

        Hierarchical (§III-B.4, recursing outward): log2(L) intra-cluster
        A2A stages (the calibrated ``interlane_lat`` stage, not a bare wire
        hop), then one log-tree per outer level — log2(size) stages on that
        level's own ring, stage s riding s hops — so the wires that scale
        with the machine never see inner-level traffic.  flat: the same
        log-tree run over the whole flattened ring, every stage at the
        longest-wire price, which is what makes it strictly more expensive
        than the hierarchy whenever L > 1 (the paper's scalability claim).
        The wire cycles come from the shared Topology; this method only
        adds the per-stage FPU and final-SIMD terms.
        """
        topo = self.topology
        if self.hierarchy == "flat":
            n_stages = sum(1 for _ in Topology.tree_stages(self.n_lanes))
            return (topo.tree_wire_cycles() + n_stages * self.fpu_lat
                    + self.simd_red_cycles)
        inner = topo.levels[-1]
        n_lane_stages = sum(1 for _ in Topology.tree_stages(inner.size))
        total = (n_lane_stages * (self.interlane_lat + self.fpu_lat)
                 + self.simd_red_cycles)
        for lvl in topo.levels[:-1]:
            stages = list(Topology.tree_stages(lvl.size))
            total += sum(s * lvl.hop_lat for s in stages)
            total += len(stages) * self.fpu_lat
        return total

    def _respelled_hierarchy(self, n_pods: int) -> str:
        """The hierarchy spelling for a machine of ``n_pods`` depth (flat
        stays flat; the hierarchical model is renamed to the new depth)."""
        if self.hierarchy == "flat":
            return "flat"
        return hier_name(2 if n_pods == 1 else 3)

    def with_lanes(self, n_lanes: int) -> "AraXLParams":
        freq = 1.4 if n_lanes <= 32 else 1.15
        # Clamp the cluster size for tiny configs (n_lanes < lanes_per_cluster
        # used to keep lpc=4 and misprice n_clusters/red_tree_lat); gcd both
        # clamps and guarantees the divisibility the constructor validates.
        lpc = math.gcd(n_lanes, self.lanes_per_cluster)
        pods = math.gcd(n_lanes // lpc, self.n_pods)
        return dataclasses.replace(
            self, n_lanes=n_lanes, lanes_per_cluster=lpc, n_pods=pods,
            hierarchy=self._respelled_hierarchy(pods), freq_ghz=freq)

    def with_grid(self, n_clusters: int, lanes_per_cluster: int
                  ) -> "AraXLParams":
        """Re-factorise the machine as C x L (total lanes = C*L)."""
        pods = math.gcd(n_clusters, self.n_pods)
        return dataclasses.replace(
            self, n_lanes=n_clusters * lanes_per_cluster,
            lanes_per_cluster=lanes_per_cluster, n_pods=pods,
            hierarchy=self._respelled_hierarchy(pods))

    def with_pods(self, n_pods: int) -> "AraXLParams":
        """Group the clusters into ``n_pods`` pods (1 restores the paper's
        two-level machine).  The hierarchy spelling follows the depth."""
        return dataclasses.replace(
            self, n_pods=n_pods,
            hierarchy=self._respelled_hierarchy(n_pods))

    def with_hierarchy(self, hierarchy: str) -> "AraXLParams":
        return dataclasses.replace(self, hierarchy=hierarchy)

    def with_cuts(self, glsu: int = 0, reqi: int = 0, ringi: int = 0) -> "AraXLParams":
        return dataclasses.replace(self, glsu_regs=glsu, reqi_regs=reqi,
                                   ringi_regs=ringi)


def araxl_params(n_lanes: int = 64, *, lanes_per_cluster: int | None = None,
                 hierarchy: str | None = None,
                 n_pods: int | None = None) -> AraXLParams:
    p = AraXLParams().with_lanes(n_lanes)
    if lanes_per_cluster is not None:
        if n_lanes % lanes_per_cluster:
            raise ValueError(f"lanes_per_cluster ({lanes_per_cluster}) must "
                             f"divide n_lanes ({n_lanes})")
        p = p.with_grid(n_lanes // lanes_per_cluster, lanes_per_cluster)
    if n_pods is not None:
        p = p.with_pods(n_pods)
    if hierarchy is not None:
        p = p.with_hierarchy(hierarchy)
    return p


def ara2_params(n_lanes: int = 8) -> AraXLParams:
    """The original Ara2 as the paper's baseline: a single 'cluster' of n
    lanes (flat all-to-all units — no ring, no GLSU pipeline), VLEN=16 Kibit,
    1.08 GHz typical (16L; 8L also timed ~1.08-1.26, we use the paper's
    normalisation machine: 8-lane Ara2)."""
    return AraXLParams(
        name="ara2", n_lanes=n_lanes, lanes_per_cluster=n_lanes,
        vlen_bits=16384, freq_ghz=1.08,
        vlsu_setup=10.0,              # single-cycle A2A align/shuffle, short path
        ring_hop=0.0, intra_hop=0.0, interlane_lat=2.0,
    )
