"""Microarchitectural parameters of the simulated machines.

Structural numbers (lanes/cluster, reduction stages, interface registers) come
straight from the paper; a handful of latency constants are calibrated once so
the model hits the paper's reported operating points (Fig. 6/7) and then kept
frozen — see tests/test_sim_paper.py for the asserted bands and
benchmarks/fig6_scaling.py for the full curves.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class AraXLParams:
    name: str = "araxl"
    n_lanes: int = 64                 # total FPUs (= lanes; 1 DP-FPU per lane)
    lanes_per_cluster: int = 4        # the max-efficiency Ara2 building block
    vlen_bits: int = 65536            # 64 Kibit/vreg (RVV 1.0 maximum)
    sew_bits: int = 64                # DP evaluation, as in the paper
    freq_ghz: float = 1.15            # 64L typical corner (1.4 up to 32L)

    # --- scalar / dispatch side ------------------------------------------
    issue_gap: float = 3.5            # CVA6 -> sequencer accept, cycles/instr
    reqi_regs: int = 0                # Fig 7(b): +1 reg => ack +2 cycles
    scalar_op_gap: float = 1.0        # bookkeeping scalar ops between vector instrs
    dcache_lat: float = 6.0           # scalar load (e.g. A[i,k]) through d-cache
    inflight: int = 8                 # dispatch window (outstanding vector instrs)

    # --- vector units ------------------------------------------------------
    chain_lat: float = 6.0            # producer->consumer chaining delay
    fpu_lat: float = 5.0              # FPU pipeline depth (drain per instr)
    vlsu_setup: float = 14.0          # AXI request + L2 access latency
    glsu_regs: int = 0                # Fig 7(a): +4 regs => +8 cycles req-resp
    ringi_regs: int = 0               # Fig 7(c): +1 reg => +1 cycle/hop
    ring_hop: float = 4.0             # base inter-cluster hop latency
    interlane_lat: float = 6.0        # intra-cluster A2A stage latency
    simd_red_cycles: float = 4.0      # final SIMD reduction stage

    # --- derived -----------------------------------------------------------
    @property
    def n_clusters(self) -> int:
        return max(1, self.n_lanes // self.lanes_per_cluster)

    @property
    def vlmax(self) -> int:
        return self.vlen_bits // self.sew_bits

    @property
    def glsu_lat(self) -> float:
        """Memory request-response latency through the GLSU pipeline."""
        return self.vlsu_setup + 2.0 * self.glsu_regs

    @property
    def reqi_lat(self) -> float:
        return 2.0 * self.reqi_regs

    @property
    def hop_lat(self) -> float:
        return self.ring_hop + self.ringi_regs

    def red_tree_lat(self) -> float:
        """Inter-lane + inter-cluster log-tree latency (vl-independent; this
        is exactly why reductions break weak scaling in Fig. 6)."""
        interlane = math.log2(self.lanes_per_cluster) * \
            (self.interlane_lat + self.fpu_lat) if self.lanes_per_cluster > 1 else 0.0
        intercluster = 0.0
        c = self.n_clusters
        s = 1
        while s < c:                   # stage s crosses s ring hops
            intercluster += s * self.hop_lat + self.fpu_lat
            s *= 2
        return interlane + intercluster + self.simd_red_cycles

    def with_lanes(self, n_lanes: int) -> "AraXLParams":
        freq = 1.4 if n_lanes <= 32 else 1.15
        return dataclasses.replace(self, n_lanes=n_lanes, freq_ghz=freq)

    def with_cuts(self, glsu: int = 0, reqi: int = 0, ringi: int = 0) -> "AraXLParams":
        return dataclasses.replace(self, glsu_regs=glsu, reqi_regs=reqi,
                                   ringi_regs=ringi)


def araxl_params(n_lanes: int = 64) -> AraXLParams:
    return AraXLParams().with_lanes(n_lanes)


def ara2_params(n_lanes: int = 8) -> AraXLParams:
    """The original Ara2 as the paper's baseline: a single 'cluster' of n
    lanes (flat all-to-all units — no ring, no GLSU pipeline), VLEN=16 Kibit,
    1.08 GHz typical (16L; 8L also timed ~1.08-1.26, we use the paper's
    normalisation machine: 8-lane Ara2)."""
    return AraXLParams(
        name="ara2", n_lanes=n_lanes, lanes_per_cluster=n_lanes,
        vlen_bits=16384, freq_ghz=1.08,
        vlsu_setup=10.0,              # single-cycle A2A align/shuffle, short path
        ring_hop=0.0, interlane_lat=2.0,
    )
