"""Chained-unit pipeline model replaying ISA traces (the paper's §IV rig).

The model captures the mechanisms the paper's evaluation turns on:

* every vector instruction streams ``ceil(vl/n) * cycles_per_elem`` cycles
  through its unit (one element per lane per cycle);
* units chain: a consumer starts ``chain_lat`` cycles behind its producer
  (program-order proxy for the dependence graph);
* the CVA6 front end issues one vector instruction per ``issue_gap +
  reqi_lat`` cycles (REQI ack round trip), with a bounded in-flight window,
  and pays d-cache latency for interleaved scalar operands;
* vector loads see the GLSU request-response latency (``glsu_lat``) before
  the first element lands;
* slides pay ``params.slide_cost(hops)`` before streaming — priced per wire
  level by the shared :class:`repro.topology.Topology` (each link at the
  outermost boundary it crosses: intra-cluster short wires, the inter-
  cluster RINGI ring, and the pod ring beyond it for ``n_pods > 1``; every
  hop at the longest-wire price under ``"flat"``); traces tag each slide
  with the wire level its critical path crosses;
* reductions stream their intra-lane phase on the FPU, then pay the
  vl-independent log-tree latency of every topology level
  (``params.red_tree_lat()``, hierarchy-dependent) — the exact term the
  paper blames for the softmax / fdotproduct scaling gap;
* FPU utilization = FPU-busy cycles / total cycles, the paper's metric.

Overlap model (``overlap=``)
----------------------------

Wire latencies (slide hops, reduction log-trees) can ride the interconnect
while the FPUs stream — AraXL's headline claim.  ``simulate`` accounts for
this in both modes:

* every wire wait is split into **hidden** cycles (spent behind issue /
  unit occupancy or backfilled work, costing nothing extra) and
  **exposed** cycles (wire latency that actually delays the dependent
  instruction), tallied per wire-class label in
  :attr:`SimResult.wire_exposed` / :attr:`SimResult.wire_hidden` (slides
  under their topology level, reduction trees under ``"tree"``);

* ``overlap=False`` (default, the paper-calibrated machine) keeps every
  unit strictly in program order, so a wire wait leaves a bubble later
  instructions cannot fill — the calibration is bit-identical to the
  historical engine;

* ``overlap=True`` models the double-buffered schedules (this repo's
  beyond-paper machine): a wire wait opens a *gap* on the stalled unit and
  later, independent instructions may backfill it — a slide / tree issued
  at least its latency before the dependent op costs nothing, otherwise
  only the exposed remainder is paid.  True register dependencies are
  never violated; only unit head-of-line blocking is relaxed.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

from repro.core.isa import InstrRecord
from .params import AraXLParams

#: extra cycles per element-group beyond 1 (vexp: 28 FLOP over 21 cycles/elem)
CYCLES_PER_ELEM = {"vexp(poly)": 21.0}

#: which units' streaming counts as "FPU producing valid results"
FPU_UNITS = {"fpu", "redu"}

#: wire-class label for reduction log-tree latency in the exposed/hidden tally
TREE_LABEL = "tree"


@dataclasses.dataclass
class SimResult:
    cycles: float
    fpu_busy: float
    flops: float
    n_instrs: int
    unit_busy: dict
    #: wire cycles that delayed a dependent instruction, by wire class
    #: (slide topology levels + "tree" for reduction log-trees)
    wire_exposed: dict = dataclasses.field(default_factory=dict)
    #: wire cycles hidden behind issue / occupancy / backfilled work
    wire_hidden: dict = dataclasses.field(default_factory=dict)

    @property
    def utilization(self) -> float:
        return self.fpu_busy / self.cycles if self.cycles else 0.0

    @property
    def flop_per_cycle(self) -> float:
        return self.flops / self.cycles if self.cycles else 0.0

    def gflops(self, freq_ghz: float) -> float:
        return self.flop_per_cycle * freq_ghz

    @property
    def wire_exposed_total(self) -> float:
        return sum(self.wire_exposed.values())

    @property
    def wire_hidden_total(self) -> float:
        return sum(self.wire_hidden.values())


class _GapUnit:
    """One execution unit with backfillable idle gaps (overlap mode).

    ``place(earliest, dur)`` returns the start of the first window of
    ``dur`` cycles at or after ``earliest`` — either inside a previously
    opened gap or at the end of the unit's schedule; ``commit`` books it.
    The sequential engine is the degenerate case where gaps are never
    reused (every op starts at ``max(earliest, end)``).
    """

    __slots__ = ("end", "gaps")

    def __init__(self):
        self.end = 0.0
        self.gaps: list[tuple[float, float]] = []

    def place(self, earliest: float, dur: float) -> float:
        for g0, g1 in self.gaps:
            s = max(g0, earliest)
            if s + dur <= g1:
                return s
        return max(self.end, earliest)

    def commit(self, start: float, dur: float) -> None:
        for i, (g0, g1) in enumerate(self.gaps):
            if g0 <= start and start + dur <= g1:
                repl = []
                if start > g0:
                    repl.append((g0, start))
                if start + dur < g1:
                    repl.append((start + dur, g1))
                self.gaps[i:i + 1] = repl
                return
        if start > self.end:
            self.gaps.append((self.end, start))
        self.end = start + dur


def simulate(trace: Sequence[InstrRecord], params: AraXLParams, *,
             overlap: bool = False) -> SimResult:
    """Replay ``trace`` through the pipeline model.

    ``overlap=False`` is the paper-calibrated sequential-unit machine
    (bit-identical to the historical engine).  ``overlap=True`` lets
    independent instructions backfill wire-wait bubbles (the double-
    buffered schedules); both modes tally exposed vs hidden wire cycles.
    """
    n = params.n_lanes
    issue_t = 0.0                  # sequencer clock
    pending_scalar = 0.0           # scalar-side cost accrued since last vector op
    unit_free: dict[str, float] = {}           # sequential mode
    units: dict[str, _GapUnit] = {}            # overlap mode
    ready: dict[int, float] = {}   # reg id -> chain-from time (true RAW deps)
    #: reg id -> (wire cycles riding behind the value, wire-class label):
    #: the part of ``ready`` a double-buffered consumer could still hide
    wire_tail: dict[int, tuple[float, str]] = {}
    starts: list[float] = []       # start times (for the in-flight window)
    fpu_busy = 0.0
    flops = 0.0
    unit_busy: dict[str, float] = {}
    wire_exposed: dict[str, float] = {}
    wire_hidden: dict[str, float] = {}
    end = 0.0
    n_vec = 0
    max_finish = 0.0               # latest streaming finish (no wire tails)
    tree_tails: list[tuple[float, float, int]] = []  # (complete, tree, out id)
    consumed: set[int] = set()     # reg ids some later instruction depends on

    def avail(unit: str, earliest: float, dur: float) -> float:
        if overlap:
            return units.setdefault(unit, _GapUnit()).place(earliest, dur)
        return max(unit_free.get(unit, 0.0), earliest)

    def book(unit: str, start: float, dur: float) -> None:
        if overlap:
            units[unit].commit(start, dur)
        else:
            unit_free[unit] = start + dur

    def tally(label: str, wire: float, exposed: float) -> None:
        exposed = min(max(exposed, 0.0), wire)
        if exposed:
            wire_exposed[label] = wire_exposed.get(label, 0.0) + exposed
        hidden = wire - exposed
        if hidden:
            wire_hidden[label] = wire_hidden.get(label, 0.0) + hidden

    for rec in trace:
        if rec.unit == "scalar":
            pending_scalar += (params.dcache_lat if rec.op == "ld"
                               else params.scalar_op_gap) * rec.vl
            continue
        if rec.unit == "seq":      # vsetvli etc: pure issue-side cost
            pending_scalar += params.scalar_op_gap
            continue

        n_vec += 1
        cpe = CYCLES_PER_ELEM.get(rec.op, 1.0)
        dur = math.ceil(rec.vl / n) * cpe
        meta = rec.meta or {}

        # ---- front end -----------------------------------------------------
        issue_t = issue_t + params.issue_gap + params.reqi_lat + pending_scalar
        pending_scalar = 0.0
        if len(starts) >= params.inflight:
            issue_t = max(issue_t, starts[-params.inflight])

        # ---- unit occupancy + true-dependency chaining -----------------------
        # Loads and stores take the VLSU's independent AXI R / W paths.
        if rec.op.startswith("vle"):
            unit = "vldu"
        elif rec.op.startswith("vse"):
            unit = "vstu"
        elif rec.unit == "redu":
            unit = "fpu"
        else:
            unit = rec.unit
        deps = meta.get("deps", ())
        consumed.update(deps)
        dep_t = max((ready.get(d, 0.0) for d in deps), default=0.0)
        # the wire tail still riding behind the binding dependency (a
        # reduction's log-tree, an upstream slide's hop): the overlap
        # machine could hide it, the sequential machine exposes whatever
        # is not already behind issue / unit occupancy
        dep_wire, dep_label, dep_rid = 0.0, None, None
        for d in deps:
            if d in wire_tail and ready.get(d, 0.0) == dep_t:
                dep_wire, dep_label = wire_tail[d]
                dep_rid = d
        if rec.op.startswith("vle"):
            # GLSU requests pipeline: the request->first-beat latency is only
            # exposed when the load path was idle (back-to-back bursts hide it
            # behind the previous transfer) — this is the latency *tolerance*
            # mechanism of Fig. 7(a).
            earliest_wire = max(issue_t + params.glsu_lat, dep_t)
            earliest_base = earliest_wire
            hop, hop_label = 0.0, None
        elif rec.unit == "sldu":
            hop = params.slide_cost(max(1, meta.get("hops", 1)))
            hop_label = meta.get("level", "inter")
            earliest_wire = max(issue_t, dep_t + hop)
            earliest_base = max(issue_t, dep_t)
        else:
            earliest_wire = max(issue_t, dep_t)
            earliest_base = max(issue_t, dep_t - dep_wire)
            hop, hop_label = 0.0, None

        start = avail(unit, earliest_wire, dur)
        if hop_label is not None and hop:
            # slide: its own hop is exposed insofar as the slide starts
            # later than it would on a zero-latency wire
            tally(hop_label, hop, start - avail(unit, earliest_base, dur))
        elif dep_label is not None and dep_wire:
            # consumer of a wire-carried value (a reduction tree): exposed =
            # the delay the tail actually causes here; charged once — later
            # consumers of the same value see an already-paid wire
            tally(dep_label, dep_wire,
                  start - avail(unit, earliest_base, dur))
            del wire_tail[dep_rid]
        book(unit, start, dur)
        unit_busy[unit] = unit_busy.get(unit, 0.0) + dur

        finish = start + dur
        max_finish = max(max_finish, finish)
        if rec.unit == "redu":
            tree = params.red_tree_lat()
            complete = finish + tree
            res_ready = complete                       # scalar result: no chaining
            if "out" in meta:
                wire_tail[meta["out"]] = (tree, TREE_LABEL)
                tree_tails.append((complete, tree, meta["out"]))
        else:
            # slides charge their hop at their own start (above), so the
            # value they produce carries no further wire tail downstream
            complete = finish
            res_ready = start + params.chain_lat       # stream-chainable
        if "out" in meta:
            ready[meta["out"]] = res_ready

        if rec.unit in FPU_UNITS:
            fpu_busy += dur
        flops += rec.flops_per_elem * rec.vl
        end = max(end, complete)
        starts.append(start)

    # Reduction trees never consumed by a tracked vector instruction (their
    # scalar lands in the core) still gate completion: whatever part of the
    # latest such tree sticks out past every streaming finish is exposed;
    # the rest — and every earlier unconsumed tree — rode the wires behind
    # ongoing work and is hidden.
    loose = sorted((c, t) for c, t, rid in tree_tails if rid not in consumed)
    for i, (complete, tree) in enumerate(loose):
        below = max(max_finish, loose[i - 1][0] if i else 0.0)
        tally(TREE_LABEL, tree,
              complete - below if complete == loose[-1][0] else 0.0)

    return SimResult(cycles=end, fpu_busy=fpu_busy, flops=flops,
                     n_instrs=n_vec, unit_busy=unit_busy,
                     wire_exposed=wire_exposed, wire_hidden=wire_hidden)
