"""Chained-unit pipeline model replaying ISA traces (the paper's §IV rig).

The model captures the mechanisms the paper's evaluation turns on:

* every vector instruction streams ``ceil(vl/n) * cycles_per_elem`` cycles
  through its unit (one element per lane per cycle);
* units chain: a consumer starts ``chain_lat`` cycles behind its producer
  (program-order proxy for the dependence graph);
* the CVA6 front end issues one vector instruction per ``issue_gap +
  reqi_lat`` cycles (REQI ack round trip), with a bounded in-flight window,
  and pays d-cache latency for interleaved scalar operands;
* vector loads see the GLSU request-response latency (``glsu_lat``) before
  the first element lands;
* slides pay ``params.slide_cost(hops)`` before streaming — priced per wire
  level by the shared :class:`repro.topology.Topology` (each link at the
  outermost boundary it crosses: intra-cluster short wires, the inter-
  cluster RINGI ring, and the pod ring beyond it for ``n_pods > 1``; every
  hop at the longest-wire price under ``"flat"``); traces tag each slide
  with the wire level its critical path crosses;
* reductions stream their intra-lane phase on the FPU, then pay the
  vl-independent log-tree latency of every topology level
  (``params.red_tree_lat()``, hierarchy-dependent) — the exact term the
  paper blames for the softmax / fdotproduct scaling gap;
* FPU utilization = FPU-busy cycles / total cycles, the paper's metric.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Sequence

from repro.core.isa import InstrRecord
from .params import AraXLParams

#: extra cycles per element-group beyond 1 (vexp: 28 FLOP over 21 cycles/elem)
CYCLES_PER_ELEM = {"vexp(poly)": 21.0}

#: which units' streaming counts as "FPU producing valid results"
FPU_UNITS = {"fpu", "redu"}


@dataclasses.dataclass
class SimResult:
    cycles: float
    fpu_busy: float
    flops: float
    n_instrs: int
    unit_busy: dict

    @property
    def utilization(self) -> float:
        return self.fpu_busy / self.cycles if self.cycles else 0.0

    @property
    def flop_per_cycle(self) -> float:
        return self.flops / self.cycles if self.cycles else 0.0

    def gflops(self, freq_ghz: float) -> float:
        return self.flop_per_cycle * freq_ghz


def simulate(trace: Sequence[InstrRecord], params: AraXLParams) -> SimResult:
    n = params.n_lanes
    issue_t = 0.0                  # sequencer clock
    pending_scalar = 0.0           # scalar-side cost accrued since last vector op
    unit_free: dict[str, float] = {}
    ready: dict[int, float] = {}   # reg id -> chain-from time (true RAW deps)
    starts: list[float] = []       # start times (for the in-flight window)
    fpu_busy = 0.0
    flops = 0.0
    unit_busy: dict[str, float] = {}
    end = 0.0
    n_vec = 0

    for rec in trace:
        if rec.unit == "scalar":
            pending_scalar += (params.dcache_lat if rec.op == "ld"
                               else params.scalar_op_gap) * rec.vl
            continue
        if rec.unit == "seq":      # vsetvli etc: pure issue-side cost
            pending_scalar += params.scalar_op_gap
            continue

        n_vec += 1
        cpe = CYCLES_PER_ELEM.get(rec.op, 1.0)
        dur = math.ceil(rec.vl / n) * cpe
        meta = rec.meta or {}

        # ---- front end -----------------------------------------------------
        issue_t = issue_t + params.issue_gap + params.reqi_lat + pending_scalar
        pending_scalar = 0.0
        if len(starts) >= params.inflight:
            issue_t = max(issue_t, starts[-params.inflight])

        # ---- unit occupancy + true-dependency chaining -----------------------
        # Loads and stores take the VLSU's independent AXI R / W paths.
        if rec.op.startswith("vle"):
            unit = "vldu"
        elif rec.op.startswith("vse"):
            unit = "vstu"
        elif rec.unit == "redu":
            unit = "fpu"
        else:
            unit = rec.unit
        dep_t = max((ready.get(d, 0.0) for d in meta.get("deps", ())),
                    default=0.0)
        if rec.op.startswith("vle"):
            # GLSU requests pipeline: the request->first-beat latency is only
            # exposed when the load path was idle (back-to-back bursts hide it
            # behind the previous transfer) — this is the latency *tolerance*
            # mechanism of Fig. 7(a).
            start = max(issue_t + params.glsu_lat, unit_free.get(unit, 0.0),
                        dep_t)
        elif rec.unit == "sldu":
            hop = params.slide_cost(max(1, meta.get("hops", 1)))
            start = max(issue_t, unit_free.get(unit, 0.0), dep_t + hop)
        else:
            start = max(issue_t, unit_free.get(unit, 0.0), dep_t)

        finish = start + dur
        unit_free[unit] = finish
        unit_busy[unit] = unit_busy.get(unit, 0.0) + dur

        if rec.unit == "redu":
            complete = finish + params.red_tree_lat()
            res_ready = complete                       # scalar result: no chaining
        else:
            complete = finish
            res_ready = start + params.chain_lat       # stream-chainable
        if "out" in meta:
            ready[meta["out"]] = res_ready

        if rec.unit in FPU_UNITS:
            fpu_busy += dur
        flops += rec.flops_per_elem * rec.vl
        end = max(end, complete)
        starts.append(start)

    return SimResult(cycles=end, fpu_busy=fpu_busy, flops=flops,
                     n_instrs=n_vec, unit_busy=unit_busy)
