"""Cycle-approximate AraXL performance model.

Reproduces the paper's evaluation without RTL: weak-scaling performance
(Fig. 6), interface latency tolerance (Fig. 7) and PPA scaling (Tables
II/III), from instruction traces of the paper's kernels replayed through a
chained-unit pipeline model.
"""
from repro.topology import Topology
from .params import AraXLParams, ara2_params, araxl_params
from .engine import simulate, SimResult
from .kernels import build_trace, KERNEL_BUILDERS
from .trace import TraceMachine

__all__ = ["AraXLParams", "Topology", "ara2_params", "araxl_params",
           "simulate", "SimResult", "build_trace", "KERNEL_BUILDERS",
           "TraceMachine"]
