"""Model sublayers: GQA/SWA/cross attention, SwiGLU, MoE (EP), Mamba2 SSD.

All pure functions over param pytrees built from `PV` definitions
(`repro.parallel.sharding`).  Math in f32, storage in cfg.dtype.  Every
function has a train/prefill form and, where stateful, a decode form.

Sharding is by logical axes: batch -> (pod,data), heads/ff/experts/vocab ->
model (TP/EP), params FSDP over (pod,data).  Communication patterns map onto
the AraXL interconnects as described in DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import substrate
from repro.configs.base import ATTN, MAMBA, MLP, MOE, XATTN, ModelConfig
from repro.kernels import ops as kops
from repro.parallel.sharding import PV, ShardingRules, constraint


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, g: jax.Array, eps: float) -> jax.Array:
    # routed through kernels.ops so tuned block configs apply on TPU; the
    # off-TPU ref path is the same f32 rsqrt expression, bit for bit
    return kops.rmsnorm(x, g, eps=eps)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, H, Dh), positions (..., S) or (S,)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freqs      # (..., S, half)
    ang = ang[..., :, None, :]                                     # broadcast heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attn_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    dt = cfg.dtype
    return {
        "norm": PV((d,), jnp.float32, ("",), "ones"),
        "wq": PV((d, cfg.n_heads * hd), dt, ("fsdp", "model")),
        "wk": PV((d, cfg.n_kv_heads * hd), dt, ("fsdp", "model")),
        "wv": PV((d, cfg.n_kv_heads * hd), dt, ("fsdp", "model")),
        "wo": PV((cfg.n_heads * hd, d), dt, ("model", "fsdp")),
    }


def _qkv(p, x, cfg: ModelConfig, rules, positions, rotate: bool):
    B, S, _ = x.shape
    hd = cfg.head_dim
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    # constrain the flat projections (always divisible by |model|), then
    # reshape to heads — kv-head counts below |model| (glm4: kv=2) stay
    # shardable on the fused dim.
    qf = constraint(kops.dense(xn, p["wq"]), rules, "batch", None, "model")
    kf = constraint(kops.dense(xn, p["wk"]), rules, "batch", None, "model")
    vf = constraint(kops.dense(xn, p["wv"]), rules, "batch", None, "model")
    q = qf.reshape(B, S, cfg.n_heads, hd)
    k = kf.reshape(B, S, cfg.n_kv_heads, hd)
    v = vf.reshape(B, S, cfg.n_kv_heads, hd)
    if rotate:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k, H: int, rules: ShardingRules):
    """Repeat kv heads up to H so the head dim shards cleanly over `model`
    even for sub-|model| kv counts (glm4: kv=2)."""
    Hkv = k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
    return constraint(k, rules, "batch", None, "model", None)


def _sdpa_chunked(q, k, v, cfg: ModelConfig, rules: ShardingRules, *,
                  causal: bool, q_offset: int = 0,
                  q_chunk: int | None = None) -> jax.Array:
    """Exact chunked attention: scan over q blocks against full K/V.

    f32 softmax; causal + sliding-window masks; the chunk body is
    checkpointed so backward recomputes score blocks instead of saving
    every softmax matrix (flash-style memory behaviour in pure XLA).
    The q-block size comes from the autotune table via
    `kernels.ops.attention_q_chunk` (chunking is per-q-row independent, so
    any block size is bit-identical).
    q (B,S,H,Dh), k/v (B,T,Hkv,Dh) -> (B,S,H,Dh)."""
    B, S, H, Dh = q.shape
    T = k.shape[1]
    scale = 1.0 / math.sqrt(Dh)
    q = constraint(q, rules, "batch", None, "model", None)
    k = _expand_kv(k, H, rules)
    v = _expand_kv(v, H, rules)
    if q_chunk is not None:                   # explicit caller choice wins
        cq = min(q_chunk, S)
        while S % cq:
            cq -= 1
    else:
        cq = kops.attention_q_chunk(S, T, H, Dh, q.dtype)
    n_chunks = S // cq
    k_pos = jnp.arange(T)

    def block(carry, qc_off):
        qc, off = qc_off
        s = jnp.einsum("bqhd,bthd->bhqt", qc.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        q_pos = off + q_offset + jnp.arange(cq)
        mask = jnp.ones((cq, T), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if cfg.window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :]) < cfg.window
        s = jnp.where(mask[None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqt,bthd->bqhd", pr, v.astype(jnp.float32))
        return carry, o.astype(q.dtype)

    qs = q.reshape(B, n_chunks, cq, H, Dh).transpose(1, 0, 2, 3, 4)
    offs = jnp.arange(n_chunks) * cq
    _, outs = jax.lax.scan(jax.checkpoint(block), None, (qs, offs))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, Dh)
    return out


def attn_layer(p, x, cfg: ModelConfig, rules: ShardingRules, positions,
               *, causal: bool = True) -> jax.Array:
    """Training / prefill self-attention (residual included)."""
    B, S, d = x.shape
    q, k, v = _qkv(p, x, cfg, rules, positions, rotate=True)
    o = _sdpa_chunked(q, k, v, cfg, rules, causal=causal)
    o = kops.dense(o.reshape(B, S, cfg.n_heads * cfg.head_dim), p["wo"])
    o = constraint(o, rules, "batch", None, None)
    return x + o.astype(x.dtype)


class AttnCache(NamedTuple):
    k: jax.Array          # (B, W, Hkv, Dh) — pre-rotated keys
    v: jax.Array


def attn_cache_len(cfg: ModelConfig, seq_len: int) -> int:
    return min(seq_len, cfg.window) if cfg.window else seq_len


def attn_cache_defs(cfg: ModelConfig, batch: int, seq_len: int) -> AttnCache:
    W = attn_cache_len(cfg, seq_len)
    shp = (batch, W, cfg.n_kv_heads, cfg.head_dim)
    return AttnCache(
        PV(shp, cfg.dtype, ("batch", "cache_seq", "kv", ""), "zeros"),
        PV(shp, cfg.dtype, ("batch", "cache_seq", "kv", ""), "zeros"))


def attn_layer_decode(p, x, cache: AttnCache, pos, cfg: ModelConfig,
                      rules: ShardingRules):
    """One-token step. pos: scalar int32 (shared position) or (B,) int32
    (per-slot true positions — the serving engine's continuous batch, where
    slots sit at different depths).

    Full-attention caches index directly; SWA caches are ring buffers of
    length `window` (entry i holds the newest position ≡ i mod W).  For a
    batch whose per-slot positions are all equal, the vector path is
    bit-identical to the scalar path (same writes, same masks, same
    reduction order)."""
    B, S1, d = x.shape                      # S1 == 1
    W = cache.k.shape[1]
    hd = cfg.head_dim
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    if per_slot:
        positions = pos[:, None]            # (B, 1) — rope broadcasts
    else:
        positions = (jnp.full((S1,), 0) + pos)[None, :]
    q, k, v = _qkv(p, x, cfg, rules, positions, rotate=True)
    slot = pos % W
    mesh = rules.mesh
    dist_cache = mesh is not None and rules.axis("cache_seq") == "model"
    if dist_cache and per_slot:
        raise NotImplementedError(
            "per-slot decode positions are not supported with the "
            "model-sharded (cache_seq) distributed cache path")
    if not dist_cache:
        if per_slot:
            # scatter each batch row at its own ring slot (rows distinct
            # by construction: one write per batch element)
            ck = cache.k.at[jnp.arange(B), slot].set(
                k[:, 0].astype(cache.k.dtype))
            cv = cache.v.at[jnp.arange(B), slot].set(
                v[:, 0].astype(cache.v.dtype))
        else:
            ck = jax.lax.dynamic_update_slice(cache.k, k, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache.v, v, (0, slot, 0, 0))
        ck = constraint(ck, rules, "batch", "cache_seq", "kv", None)
        cv = constraint(cv, rules, "batch", "cache_seq", "kv", None)

    def _scores_out(qg, ckb, cvb, idx, pos_):
        """Local masked scores + (m, l, o) partials for index slice idx.

        pos_ may be a scalar (mask over (W,)) or a (B,) vector (per-slot
        mask over (B, W))."""
        pos_c = pos_[:, None] if pos_.ndim == 1 else pos_
        if cfg.window:
            k_pos = pos_c - ((pos_c - idx) % W)  # newest position ≡ i (mod W)
            valid = k_pos >= 0
        else:
            k_pos = idx
            valid = k_pos <= pos_c
        s = jnp.einsum("bqhgd,bthd->bhgqt", qg.astype(jnp.float32),
                       ckb.astype(jnp.float32)) / math.sqrt(hd)
        mask = valid & (k_pos <= pos_c)
        if cfg.window:
            mask &= (pos_c - k_pos) < cfg.window
        if mask.ndim == 2:                  # (B, W) per-slot mask
            s = jnp.where(mask[:, None, None, None, :], s, -1e30)
        else:
            s = jnp.where(mask[None, None, None, None, :], s, -1e30)
        return s, cvb.astype(jnp.float32)

    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, S1, cfg.n_kv_heads, G, hd)
    if dist_cache:
        # distributed decode attention: each model shard WRITES the new
        # token into its cache slice if the slot falls in range (no
        # replicate-and-reshard of the cache), scores its slice, and the
        # softmax is merged with tiny pmax/psum collectives — AraXL's
        # inter-cluster log-tree reduction (never gather the cache).
        W_loc = W // mesh.shape["model"]
        cspec = rules.spec(("batch", "cache_seq", "kv", ""))

        def body(qg_, ckb, cvb, kb, vb, pos_):
            base = substrate.axis_index("model") * W_loc
            sl = pos_ % W
            ls = jnp.clip(sl - base, 0, W_loc - 1)
            inrange = (sl >= base) & (sl < base + W_loc)
            ck_new = jnp.where(
                inrange,
                jax.lax.dynamic_update_slice(ckb, kb, (0, ls, 0, 0)), ckb)
            cv_new = jnp.where(
                inrange,
                jax.lax.dynamic_update_slice(cvb, vb, (0, ls, 0, 0)), cvb)
            idx = base + jnp.arange(W_loc)
            s, cvf = _scores_out(qg_, ck_new, cv_new, idx, pos_)
            m = jax.lax.pmax(jnp.max(s, axis=-1, keepdims=True), "model")
            pr = jnp.exp(s - m)
            l = jax.lax.psum(jnp.sum(pr, axis=-1, keepdims=True), "model")
            o = jax.lax.psum(
                jnp.einsum("bhgqt,bthd->bqhgd", pr, cvf), "model")
            ln = jnp.maximum(l, 1e-20).squeeze(-1).transpose(0, 3, 1, 2)
            return o / ln[..., None], ck_new, cv_new

        bq = rules.spec(("batch", "", "", "", ""))
        bk = rules.spec(("batch", "", "", ""))
        o, ck, cv = substrate.shard_map(
            body, mesh=mesh,
            in_specs=(bq, cspec, cspec, bk, bk, P()),
            out_specs=(bq, cspec, cspec))(
                qg, cache.k, cache.v, k.astype(cache.k.dtype),
                v.astype(cache.v.dtype), jnp.asarray(pos, jnp.int32))
    else:
        s, cvf = _scores_out(qg, ck, cv, jnp.arange(W), pos)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhgqt,bthd->bqhgd", pr, cvf)
    o = kops.dense(o.reshape(B, S1, cfg.n_heads * hd).astype(x.dtype),
                   p["wo"])
    return x + o.astype(x.dtype), AttnCache(ck, cv)


def attn_layer_prefill(p, x, cfg: ModelConfig, rules, positions, cache_len):
    """Prefill: run attention AND return the populated cache."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, rules, positions, rotate=True)
    o = _sdpa_chunked(q, k, v, cfg, rules, causal=True)
    o = kops.dense(o.reshape(B, S, cfg.n_heads * cfg.head_dim), p["wo"])
    W = cache_len
    if W >= S:
        pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
        ck, cv = jnp.pad(k, pad), jnp.pad(v, pad)
    else:                                   # SWA ring buffer: last W tokens,
        tail_k, tail_v = k[:, S - W:], v[:, S - W:]   # placed at slot pos%W
        roll = (S - W) % W
        ck = jnp.roll(tail_k, shift=roll, axis=1)
        cv = jnp.roll(tail_v, shift=roll, axis=1)
    return x + o.astype(x.dtype), AttnCache(ck, cv)


# -- paged attention (block-table KV pool) -----------------------------------
#
# The serving analogue of AraXL's VRF chunk map: K/V live in a shared pool
# of fixed-size token blocks, each request holds a table of block ids, and
# attention gathers through the table.  Block 0 is a permanent zero block —
# unallocated table entries gather exact zeros, which is what the dense
# cache's unwritten rows hold, so paged decode is bit-identical to the
# dense engine.  Full attention only (no SWA ring) — the paged engine
# rejects windowed configs.

def attn_layer_decode_paged(p, x, pk, pv, tables, pos, live,
                            cfg: ModelConfig, rules: ShardingRules):
    """One-token decode against a block-table paged KV pool.

    pk/pv (NB, bt, Hkv, Dh) — the shared block pool (block 0 is the
    reserved zero block, never written by a live slot); tables
    (B, max_blocks) int32; pos (B,) per-slot positions; live (B,) bool.
    Dead slots write a predicated no-op (they re-write the zero block's
    current value) so the batched step stays shape-stable.  The gathered
    view ``pk[tables].reshape(B, W, ...)`` is elementwise identical to the
    dense cache rows, and the math below is the same expression as
    :func:`attn_layer_decode`'s vector-pos path — bit-identical streams."""
    B, S1, d = x.shape                      # S1 == 1
    NB, bt, Hkv, hd = pk.shape
    W = tables.shape[1] * bt
    q, k, v = _qkv(p, x, cfg, rules, pos[:, None], rotate=True)
    blk = jnp.take_along_axis(tables, (pos // bt)[:, None], axis=1)[:, 0]
    off = pos % bt
    cur_k, cur_v = pk[blk, off], pv[blk, off]          # (B, Hkv, Dh)
    nk = jnp.where(live[:, None, None], k[:, 0].astype(pk.dtype), cur_k)
    nv = jnp.where(live[:, None, None], v[:, 0].astype(pv.dtype), cur_v)
    pk = pk.at[blk, off].set(nk)
    pv = pv.at[blk, off].set(nv)
    ck = pk[tables].reshape(B, W, Hkv, hd)
    cv = pv[tables].reshape(B, W, Hkv, hd)
    ck = constraint(ck, rules, "batch", None, "kv", None)
    cv = constraint(cv, rules, "batch", None, "kv", None)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, S1, Hkv, G, hd)
    idx = jnp.arange(W)
    s = jnp.einsum("bqhgd,bthd->bhgqt", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) / math.sqrt(hd)
    mask = idx <= pos[:, None]                         # (B, W) causal
    s = jnp.where(mask[:, None, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqt,bthd->bqhgd", pr, cv.astype(jnp.float32))
    o = kops.dense(o.reshape(B, S1, cfg.n_heads * hd).astype(x.dtype),
                   p["wo"])
    return x + o.astype(x.dtype), pk, pv


def attn_layer_prefill_paged(p, x, pk, pv, table_row, start, valid,
                             cfg: ModelConfig, rules: ShardingRules):
    """One prefill *chunk* (B == 1) against the paged pool.

    x (1, c, d) is the embedded chunk, padded to the fixed chunk length c;
    ``valid`` counts real tokens, ``start`` is the chunk's base position
    (a multiple of the block size).  The chunk's K/V are scattered whole
    blocks at a time into the pre-allocated blocks of ``table_row``
    (padding rows zeroed first, so the zero block stays zero even when the
    tail of the slice lands on unallocated entries), then the chunk
    attends causally over the full gathered view — earlier chunks' blocks
    are already resident, which is what makes chunked prefill exact."""
    B, c, d = x.shape                       # B == 1
    NB, bt, Hkv, hd = pk.shape
    W = table_row.shape[0] * bt
    positions = start + jnp.arange(c)
    q, k, v = _qkv(p, x, cfg, rules, positions[None, :], rotate=True)
    ok = (jnp.arange(c) < valid)[None, :, None, None]
    kz = jnp.where(ok, k, 0).astype(pk.dtype)
    vz = jnp.where(ok, v, 0).astype(pv.dtype)
    nblk = c // bt
    bids = jax.lax.dynamic_slice(table_row, (start // bt,), (nblk,))
    pk = pk.at[bids].set(kz[0].reshape(nblk, bt, Hkv, hd))
    pv = pv.at[bids].set(vz[0].reshape(nblk, bt, Hkv, hd))
    ck = pk[table_row].reshape(1, W, Hkv, hd)
    cv = pv[table_row].reshape(1, W, Hkv, hd)
    ck = constraint(ck, rules, "batch", None, "kv", None)
    cv = constraint(cv, rules, "batch", None, "kv", None)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, c, Hkv, G, hd)
    mask = jnp.arange(W)[None, :] <= positions[:, None]   # (c, W) causal
    s = jnp.einsum("bqhgd,bthd->bhgqt", qg.astype(jnp.float32),
                   ck.astype(jnp.float32)) / math.sqrt(hd)
    s = jnp.where(mask[None, None, None], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqt,bthd->bqhgd", pr, cv.astype(jnp.float32))
    o = kops.dense(o.reshape(B, c, cfg.n_heads * hd).astype(x.dtype),
                   p["wo"])
    return x + o.astype(x.dtype), pk, pv


# -- cross attention ---------------------------------------------------------

def xattn_defs(cfg: ModelConfig) -> dict:
    return attn_defs(cfg, cross=True)


def xattn_layer(p, x, ctx, cfg: ModelConfig, rules: ShardingRules):
    """Cross-attention to a context (encoder output / image embeddings).
    ctx (B, T, d); no positional rotation (learned content addressing)."""
    B, S, d = x.shape
    hd = cfg.head_dim
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    q = kops.dense(xn, p["wq"]).reshape(B, S, cfg.n_heads, hd)
    k = kops.dense(ctx, p["wk"]).reshape(B, ctx.shape[1], cfg.n_kv_heads, hd)
    v = kops.dense(ctx, p["wv"]).reshape(B, ctx.shape[1], cfg.n_kv_heads, hd)
    o = _sdpa_chunked(q, k, v, cfg, rules, causal=False)
    o = kops.dense(o.reshape(B, S, cfg.n_heads * hd), p["wo"])
    return x + o.astype(x.dtype)


class XAttnCache(NamedTuple):
    k: jax.Array          # (B, T, Hkv, Dh) — projected context, fixed
    v: jax.Array


def xattn_cache_defs(cfg: ModelConfig, batch: int) -> XAttnCache:
    shp = (batch, cfg.n_ctx_tokens, cfg.n_kv_heads, cfg.head_dim)
    return XAttnCache(PV(shp, cfg.dtype, ("batch", "", "kv", ""), "zeros"),
                      PV(shp, cfg.dtype, ("batch", "", "kv", ""), "zeros"))


def xattn_prefill_cache(p, ctx, cfg: ModelConfig) -> XAttnCache:
    B, T, _ = ctx.shape
    hd = cfg.head_dim
    k = kops.dense(ctx, p["wk"]).reshape(B, T, cfg.n_kv_heads, hd)
    v = kops.dense(ctx, p["wv"]).reshape(B, T, cfg.n_kv_heads, hd)
    return XAttnCache(k, v)


def xattn_layer_decode(p, x, cache: XAttnCache, cfg: ModelConfig,
                       rules: ShardingRules):
    B, S1, d = x.shape
    hd = cfg.head_dim
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    q = kops.dense(xn, p["wq"]).reshape(B, S1, cfg.n_heads, hd)
    G = cfg.n_heads // cfg.n_kv_heads
    qg = q.reshape(B, S1, cfg.n_kv_heads, G, hd)
    s = jnp.einsum("bqhgd,bthd->bhgqt", qg.astype(jnp.float32),
                   cache.k.astype(jnp.float32)) / math.sqrt(hd)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqt,bthd->bqhgd", pr, cache.v.astype(jnp.float32))
    o = kops.dense(o.reshape(B, S1, cfg.n_heads * hd).astype(x.dtype),
                   p["wo"])
    return x + o.astype(x.dtype), cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_defs(cfg: ModelConfig) -> dict:
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    return {
        "norm": PV((d,), jnp.float32, ("",), "ones"),
        "wi": PV((d, f), dt, ("fsdp", "model")),
        "wg": PV((d, f), dt, ("fsdp", "model")),
        "wo": PV((f, d), dt, ("model", "fsdp")),
    }


def mlp_layer(p, x, cfg: ModelConfig, rules: ShardingRules):
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    h = silu(kops.dense(xn, p["wg"])) * kops.dense(xn, p["wi"])
    h = constraint(h, rules, "batch", None, "model")
    o = kops.dense(h, p["wo"])
    return x + o.astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE — top-k routing, capacity dispatch, expert parallelism over `model`
# ---------------------------------------------------------------------------

def moe_defs(cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    E = cfg.n_experts
    ffe = cfg.d_ff_expert or cfg.d_ff
    # expert dim over `model` when divisible (EP), else ff dim (expert-TP)
    return {
        "norm": PV((d,), jnp.float32, ("",), "ones"),
        "router": PV((d, E), jnp.float32, ("fsdp", "")),
        "wi": PV((E, d, ffe), dt, ("model", "fsdp", "")),
        "wg": PV((E, d, ffe), dt, ("model", "fsdp", "")),
        "wo": PV((E, ffe, d), dt, ("model", "", "fsdp")),
    }


def moe_defs_tp(cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    E = cfg.n_experts
    ffe = cfg.d_ff_expert or cfg.d_ff
    return {
        "norm": PV((d,), jnp.float32, ("",), "ones"),
        "router": PV((d, E), jnp.float32, ("fsdp", "")),
        "wi": PV((E, d, ffe), dt, ("", "fsdp", "model")),
        "wg": PV((E, d, ffe), dt, ("", "fsdp", "model")),
        "wo": PV((E, ffe, d), dt, ("", "model", "fsdp")),
    }


def _model_axes(rules: ShardingRules) -> tuple:
    """Mesh axes the logical `model` (TP/EP) axis maps to, flattened.  A
    plain production mesh gives ("model",); a Topology-driven hierarchical
    mesh may map `model` to several level axes (e.g. ("pod", "data",
    "model")) treated as one outer-major expert ring."""
    if rules.mesh is None:
        return ()
    ax = rules.axis("model")
    if ax is None:
        return ()
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    return tuple(a for a in axes if a in rules.mesh.shape)


def _model_size(rules: ShardingRules) -> int:
    return math.prod(rules.mesh.shape[a] for a in _model_axes(rules))


def moe_mode(cfg: ModelConfig, rules: ShardingRules) -> str:
    maxes = _model_axes(rules)
    if not maxes:
        return "local"
    if cfg.moe_tp:
        return "tp"
    msize = _model_size(rules)
    assert cfg.n_experts % msize == 0, \
        f"{cfg.name}: E={cfg.n_experts} not divisible by model={msize}; " \
        "set moe_tp=True"
    if cfg.moe_impl == "a2a" and rules.axis("act_seq"):
        return "ep_a2a"
    return "ep"


def _dispatch_ffn(xf, top_idx, top_gate, wi, wg, wo, e_base, E_loc, C):
    """Capacity-dispatch N tokens to E_loc local experts and combine.

    xf (N, d) f32; top_idx/top_gate (N, k); expert weights (E_loc, d, f) etc.
    Returns the local experts' combined contribution (N, d) f32.
    """
    N, d = xf.shape
    wdt = wi.dtype
    out = jnp.zeros((N, d), jnp.float32)
    for j in range(E_loc):                       # static, small (<= E/|model|)
        e = e_base + j
        sel = (top_idx == e)                     # (N, k)
        gate = jnp.sum(jnp.where(sel, top_gate, 0.0), axis=-1)    # (N,)
        chosen = sel.any(axis=-1)
        pos = jnp.cumsum(chosen.astype(jnp.int32)) - 1            # (N,)
        slot = jnp.where(chosen & (pos < C), pos, C)              # C = drop
        # FFN math stays fully in the weight dtype: any f32 operand (fwd OR
        # bwd cotangent) promotes the whole 94-layer expert stack to f32 via
        # XLA loop-invariant hoisting — 7 GiB of converts in the dry-run.
        buf = jnp.zeros((C + 1, d), wdt).at[slot].set(xf.astype(wdt))[:C]
        h = silu(buf @ wg[j]) * (buf @ wi[j])
        y = (h @ wo[j]).astype(jnp.float32)                       # (C, d)
        back = jnp.where(slot < C, slot, C)
        gathered = jnp.concatenate([y, jnp.zeros((1, d), y.dtype)])[back]
        out = out + gate[:, None] * gathered
    return out


def moe_layer(p, x, cfg: ModelConfig, rules: ShardingRules, topology=None):
    """Top-k MoE with per-shard capacity.  EP mode: experts sharded over
    the `model` axes via shard_map (tokens replicated on the model axes —
    the GLSU "shuffle stage" becomes a local scatter + cross-lane psum
    combine).  TP mode (n_experts < |model|): all experts everywhere, ff
    dim sharded.

    ``topology`` (a :class:`repro.topology.Topology` whose level axes are
    the `model` mesh axes) makes the ep_a2a dispatch hierarchical: the
    token all-to-all runs level by level, intra-level ring first — see
    :func:`_moe_ep_a2a`.
    """
    B, S, d = x.shape
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    k = cfg.experts_per_token
    E = cfg.n_experts
    logits = (xn.astype(jnp.float32) @ p["router"])            # (B,S,E)
    top_gate, top_idx = jax.lax.top_k(logits, k)
    top_gate = jax.nn.softmax(top_gate, axis=-1)               # normalised
    mode = moe_mode(cfg, rules)

    def run_local(xn_, ti_, tg_, wi, wg, wo, e_base, E_loc):
        N = xn_.shape[0] * xn_.shape[1]
        C = max(1, int(math.ceil(N * k / E * cfg.capacity_factor)))
        xf = xn_.reshape(N, d).astype(jnp.float32)
        y = _dispatch_ffn(xf, ti_.reshape(N, k), tg_.reshape(N, k),
                          wi, wg, wo, e_base, E_loc, C)
        return y.reshape(xn_.shape)

    if mode == "local":
        y = run_local(xn, top_idx, top_gate, p["wi"], p["wg"], p["wo"], 0, E)
        return x + y.astype(x.dtype)

    mesh = rules.mesh
    maxes = _model_axes(rules)
    msize = _model_size(rules)
    mspec = maxes if len(maxes) > 1 else maxes[0]
    bspec = rules.spec(("batch", "", ""))   # respects batch divisibility

    if mode == "tp":
        # every shard runs all experts on its token shard, ff sharded
        def body(xn_, ti_, tg_, wi, wg, wo):
            y = run_local(xn_, ti_, tg_, wi, wg, wo, 0, E)
            return jax.lax.psum(y, maxes)

        y = substrate.shard_map(
            body, mesh=mesh,
            in_specs=(bspec, bspec, bspec,
                      P(None, None, mspec), P(None, None, mspec),
                      P(None, mspec, None)),
            out_specs=bspec)(xn, top_idx, top_gate, p["wi"], p["wg"], p["wo"])
        return x + y.astype(x.dtype)

    if mode == "ep_a2a" and S % msize == 0:
        return x + _moe_ep_a2a(p, xn, top_idx, top_gate, cfg, rules,
                               topology).astype(x.dtype)

    # EP (replicated-token variant): experts sharded over the model axes,
    # tokens replicated on them, combine via psum.  Simple but pays a
    # token-space all-reduce per layer — §Perf replaces it with ep_a2a.
    E_loc = E // msize

    def body(xn_, ti_, tg_, wi, wg, wo):
        e_base = substrate.axis_index(maxes) * E_loc
        # e_base is traced; shift indices so the static loop sees local ids
        ti_loc = ti_ - e_base
        y = run_local(xn_, ti_loc, tg_, wi, wg, wo, 0, E_loc)
        return jax.lax.psum(y, maxes)

    y = substrate.shard_map(
        body, mesh=mesh,
        in_specs=(bspec, bspec, bspec,
                  P(mspec, None, None), P(mspec, None, None),
                  P(mspec, None, None)),
        out_specs=bspec)(xn, top_idx, top_gate, p["wi"], p["wg"], p["wo"])
    return x + y.astype(x.dtype)


def _a2a_stages(rules: ShardingRules, topology) -> list:
    """The expert-dispatch exchange as (axes, size) stages, innermost
    first.

    Flat (``topology=None``): one all-to-all over every model axis at once.
    With a Topology whose level axes are the model axes, one stage per
    level — the intra-level (lane) exchange runs first and each outer
    (cluster / pod) stage only moves already-aggregated level blocks, so
    the physically long wires never carry intra-level traffic (the
    §III-B.3 Align pipeline applied to token buffers).  Both schedules are
    exact inverses of themselves stage by stage, so the combine path
    restores placement bit-identically to the flat exchange.
    """
    maxes = _model_axes(rules)
    if topology is None:
        return [(maxes, _model_size(rules))]
    from repro.topology import mesh_levels
    levels = mesh_levels(topology, rules.mesh.shape)
    flat = tuple(a for axes, _ in levels for a in axes)
    if flat != maxes:
        raise ValueError(f"topology level axes {flat} must flatten to the "
                         f"model axes {maxes}")
    return list(reversed(levels))                     # innermost first


def _a2a_dispatch(buf, stages, E_loc: int):
    """(E, C, d) expert-major capacity buffers -> (E_loc, C*msize, d): every
    stage peels off the expert index's innermost remaining level digit and
    exchanges along that level's ring."""
    for axes, s in stages:
        ED, Ccur, d = buf.shape
        buf = buf.reshape(ED // (s * E_loc), s, E_loc, Ccur, d)
        buf = jax.lax.all_to_all(buf, axes, split_axis=1, concat_axis=3,
                                 tiled=True)
        buf = buf.reshape(ED // s, Ccur * s, d)
    return buf


def _a2a_combine(y, stages, E_loc: int):
    """Exact inverse of :func:`_a2a_dispatch` (stages unwound outermost
    first), restoring (E, C, d) placement."""
    for axes, s in reversed(stages):
        ED, Ccur, d = y.shape
        y = y.reshape(ED // E_loc, 1, E_loc, Ccur, d)
        y = jax.lax.all_to_all(y, axes, split_axis=3, concat_axis=1,
                               tiled=True)
        y = y.reshape(ED * s, Ccur // s, d)
    return y


def _moe_ep_a2a(p, xn, top_idx, top_gate, cfg: ModelConfig,
                rules: ShardingRules, topology=None):
    """All-to-all expert parallelism — the GLSU discipline: shuffle the
    (small) token buffers between expert shards instead of replicating
    tokens / gathering weights.

    Each model shard dispatches its OWN sequence slice (act_seq sharding)
    into per-expert capacity buffers for all E experts, a2a's buffers so
    shard i holds its E/msize experts' tokens from every source, runs the
    FFN, a2a's back and combines.  Wire per layer ~= 4 x dispatched-token
    bytes — two orders of magnitude below the psum-combine variant at
    qwen3 scale (measured in §Perf).

    Communicates across: every `model` mesh axis.  Flat by default (one
    all-to-all spanning them); with ``topology`` the exchange walks the
    topology levels innermost-first (see :func:`_a2a_stages`) and — because
    the FFN is row-independent and the combine inverts the dispatch stage
    by stage — produces bit-identical results to the flat exchange.
    """
    mesh = rules.mesh
    maxes = _model_axes(rules)
    msize = _model_size(rules)
    mspec = maxes if len(maxes) > 1 else maxes[0]
    stages = _a2a_stages(rules, topology)
    B, S, d = xn.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    E_loc = E // msize
    S_loc = S // msize
    bspec_tok = rules.spec(("batch", "act_seq", ""))
    bspec_idx = rules.spec(("batch", "act_seq", ""))
    wdt = p["wi"].dtype

    def body(xn_, ti_, tg_, wi, wg, wo):
        B_loc = xn_.shape[0]
        N = B_loc * S_loc
        C = max(1, int(math.ceil(N * k / E * cfg.capacity_factor)))
        xf = xn_.reshape(N, d).astype(wdt)
        ti = ti_.reshape(N * k)
        tg = tg_.reshape(N * k).astype(jnp.float32)
        tok = jnp.repeat(jnp.arange(N), k)

        # rank of each (token, choice) within its expert (stable by token)
        order = jnp.argsort(ti, stable=True)
        sorted_e = ti[order]
        start = jnp.searchsorted(sorted_e, jnp.arange(E))
        ranks_sorted = jnp.arange(N * k) - start[sorted_e]
        ranks = jnp.zeros(N * k, jnp.int32).at[order].set(
            ranks_sorted.astype(jnp.int32))
        keep = ranks < C
        slot = jnp.where(keep, ti * C + ranks, E * C)             # OOB drops
        buf = jnp.zeros((E * C + 1, d), wdt).at[slot].set(xf[tok])[:-1]
        buf = buf.reshape(E, C, d)

        # GLSU shuffle: expert-major blocks to their owning shard,
        # level by level
        recv = _a2a_dispatch(buf, stages, E_loc)      # (E_loc, C*msize, d)
        h = silu(jnp.einsum("ecd,edf->ecf", recv, wg)) \
            * jnp.einsum("ecd,edf->ecf", recv, wi)
        y = jnp.einsum("ecf,efd->ecd", h.astype(wdt), wo)
        back = _a2a_combine(y, stages, E_loc)         # (E, C, d)
        flat = jnp.concatenate([back.reshape(E * C, d),
                                jnp.zeros((1, d), y.dtype)])
        picked = flat[slot].astype(jnp.float32)                   # (N*k, d)
        w = jnp.where(keep, tg, 0.0)[:, None]
        out = jnp.zeros((N, d), jnp.float32).at[tok].add(w * picked)
        return out.reshape(B_loc, S_loc, d)

    y = substrate.shard_map(
        body, mesh=mesh,
        in_specs=(bspec_tok, bspec_idx, bspec_idx,
                  P(mspec, None, None), P(mspec, None, None),
                  P(mspec, None, None)),
        out_specs=bspec_tok)(xn, top_idx, top_gate,
                             p["wi"], p["wg"], p["wo"])
    return y


# ---------------------------------------------------------------------------
# Mamba2 (SSD, chunked) — arXiv:2405.21060
# ---------------------------------------------------------------------------

def mamba_defs(cfg: ModelConfig) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    di = cfg.d_inner_ssm
    N = cfg.ssm_state
    H = cfg.n_ssm_heads
    kc = cfg.ssm_conv
    return {
        "norm": PV((d,), jnp.float32, ("",), "ones"),
        "in_proj": PV((d, 2 * di + 2 * N + H), dt, ("fsdp", "model")),
        "conv_w": PV((kc, di + 2 * N), dt, ("", "model")),
        "conv_b": PV((di + 2 * N,), dt, ("model",), "zeros"),
        "A_log": PV((H,), jnp.float32, ("model",), "zeros"),
        "D": PV((H,), jnp.float32, ("model",), "ones"),
        "dt_bias": PV((H,), jnp.float32, ("model",), "zeros"),
        "gnorm": PV((di,), jnp.float32, ("model",), "ones"),
        "out_proj": PV((di, d), dt, ("model", "fsdp")),
    }


def _ssd_chunked(xh, dtv, Bm, Cm, A, chunk: int, state_in=None):
    """Chunked state-space dual form.

    xh (B,S,H,P) f32; dtv (B,S,H); Bm/Cm (B,S,N); A (H,) negative.
    Returns y (B,S,H,P), final state (B,H,P,N)."""
    Bsz, S, H, Pd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    while S % Q:
        Q -= 1
    nc = S // Q
    r = lambda t: t.reshape((Bsz, nc, Q) + t.shape[2:])
    xc, dtc, Bc, Cc = r(xh), r(dtv), r(Bm), r(Cm)

    dA = dtc * A[None, None, None, :]                 # (B,nc,Q,H) negative
    dA_cs = jnp.cumsum(dA, axis=2)                    # within-chunk cumsum
    # decay from q' to q (q >= q'): exp(dA_cs[q] - dA_cs[q'])
    seg = dA_cs[:, :, :, None, :] - dA_cs[:, :, None, :, :]      # (B,nc,Q,Q,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)

    xdt = xc * dtc[..., None]                         # (B,nc,Q,H,P)
    # intra-chunk (diagonal blocks)
    y_diag = jnp.einsum("bcqn,bckn,bcqkh,bckhp->bcqhp",
                        Cc, Bc, L.transpose(0, 1, 2, 3, 4), xdt)
    # chunk-final states
    decay_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)  # (B,nc,Q,H)
    S_c = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_end, xdt)
    # inter-chunk recurrence (the ring/slide stage when sequence-sharded)
    chunk_decay = jnp.exp(jnp.sum(dA, axis=2))        # (B,nc,H)

    def scan_fn(s_prev, inp):
        s_c, dec = inp                                # (B,H,P,N), (B,H)
        s_in = s_prev
        s_next = s_c + dec[:, :, None, None] * s_prev
        return s_next, s_in

    init = (jnp.zeros((Bsz, H, Pd, N), jnp.float32) if state_in is None
            else state_in)
    s_final, s_ins = jax.lax.scan(
        scan_fn, init,
        (S_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    s_ins = s_ins.transpose(1, 0, 2, 3, 4)            # (B,nc,H,P,N)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc, s_ins, jnp.exp(dA_cs))
    y = (y_diag + y_off).reshape(Bsz, S, H, Pd)
    return y, s_final


def _mamba_project(p, x, cfg: ModelConfig):
    di, N, H = cfg.d_inner_ssm, cfg.ssm_state, cfg.n_ssm_heads
    xn = rmsnorm(x, p["norm"], cfg.norm_eps)
    proj = kops.dense(xn, p["in_proj"])               # (B,S,2di+2N+H)
    z, xc, Bm, Cm, dtv = jnp.split(
        proj, [di, 2 * di, 2 * di + N, 2 * di + 2 * N], axis=-1)
    return z, jnp.concatenate([xc, Bm, Cm], -1), dtv


def mamba_layer(p, x, cfg: ModelConfig, rules: ShardingRules,
                conv_state=None, ssm_state=None, return_state: bool = False):
    """Train/prefill Mamba2 block (full sequence, chunked SSD)."""
    B, S, d = x.shape
    di, N, H = cfg.d_inner_ssm, cfg.ssm_state, cfg.n_ssm_heads
    kc = cfg.ssm_conv
    z, xbc, dtv = _mamba_project(p, x, cfg)
    # depthwise causal conv over (x, B, C)
    pad = jnp.zeros((B, kc - 1, xbc.shape[-1]), xbc.dtype) \
        if conv_state is None else conv_state
    xbc_p = jnp.concatenate([pad, xbc], axis=1)
    conv = sum(xbc_p[:, i:i + S] * p["conv_w"][i][None, None]
               for i in range(kc)) + p["conv_b"][None, None]
    conv = silu(conv)
    xc, Bm, Cm = jnp.split(conv, [di, di + N], axis=-1)
    xh = xc.reshape(B, S, H, cfg.ssm_head_dim).astype(jnp.float32)
    dtb = jax.nn.softplus(dtv.astype(jnp.float32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    y, s_final = _ssd_chunked(xh, dtb, Bm.astype(jnp.float32),
                              Cm.astype(jnp.float32), A, cfg.ssm_chunk,
                              ssm_state)
    y = y + p["D"][None, None, :, None] * xh          # skip
    y = y.reshape(B, S, di)
    y = rmsnorm(y.astype(x.dtype) * silu(z), p["gnorm"], cfg.norm_eps)
    out = kops.dense(y, p["out_proj"])
    res = x + out.astype(x.dtype)
    if return_state:
        new_conv = xbc_p[:, S:S + kc - 1] if kc > 1 else pad
        return res, (new_conv, s_final.astype(jnp.float32))
    return res


class MambaCache(NamedTuple):
    conv: jax.Array       # (B, kc-1, di+2N)
    state: jax.Array      # (B, H, P, N) f32


def mamba_cache_defs(cfg: ModelConfig, batch: int) -> MambaCache:
    di, N, H = cfg.d_inner_ssm, cfg.ssm_state, cfg.n_ssm_heads
    return MambaCache(
        PV((batch, cfg.ssm_conv - 1, di + 2 * N), cfg.dtype,
           ("batch", "", "model"), "zeros"),
        PV((batch, H, cfg.ssm_head_dim, N), jnp.float32,
           ("batch", "model", "", ""), "zeros"))


def mamba_layer_decode(p, x, cache: MambaCache, cfg: ModelConfig,
                       rules: ShardingRules):
    """Single-token recurrent step: state <- dA*state + dt*B (x) ; y = C.state."""
    B, S1, d = x.shape
    di, N, H = cfg.d_inner_ssm, cfg.ssm_state, cfg.n_ssm_heads
    kc = cfg.ssm_conv
    z, xbc, dtv = _mamba_project(p, x, cfg)
    window = jnp.concatenate([cache.conv, xbc], axis=1)       # (B, kc, ch)
    conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv = silu(conv)[:, None, :]
    xc, Bm, Cm = jnp.split(conv, [di, di + N], axis=-1)
    xh = xc.reshape(B, H, cfg.ssm_head_dim).astype(jnp.float32)
    dtb = jax.nn.softplus(dtv.astype(jnp.float32)[:, 0] + p["dt_bias"][None])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dtb * A[None])                               # (B,H)
    Bv = Bm[:, 0].astype(jnp.float32)                         # (B,N)
    Cv = Cm[:, 0].astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dtb, xh, Bv)
    state = cache.state * dA[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, Cv) + p["D"][None, :, None] * xh
    y = y.reshape(B, 1, di)
    y = rmsnorm(y.astype(x.dtype) * silu(z), p["gnorm"], cfg.norm_eps)
    out = kops.dense(y, p["out_proj"])
    new_conv = window[:, 1:] if kc > 1 else cache.conv
    return x + out.astype(x.dtype), MambaCache(new_conv, state)
