"""Whole-model assembly: embeddings -> scan over layer periods -> head.

One code path serves all ten assigned architectures: the repeating layer
``period`` (a tuple of layers, each a tuple of sublayer kinds) drives both
parameter stacking (compile-time O(one period) via lax.scan) and execution.
Families:

    dense / moe      decoder-only periods of (attn, mlp|moe)
    ssm              (mamba,) periods
    hybrid (jamba)   8-layer periods mixing mamba/attn and moe/mlp
    encdec           + a bidirectional encoder; decoder layers carry xattn
    vlm              + a frontend projection; xattn layers attend image tokens

Three entry points per model: ``forward_train`` (loss), ``prefill``
(populate caches, return last logits), ``decode_step`` (one token).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import substrate
from repro.configs.base import ATTN, MAMBA, MLP, MOE, XATTN, ModelConfig
from repro.parallel.sharding import PV, ShardingRules, constraint
from . import layers as L


# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------

def _stack(defs, n: int):
    return jax.tree.map(
        lambda pv: PV((n,) + pv.shape, pv.dtype, ("",) + pv.logical, pv.init,
                      pv.scale),
        defs, is_leaf=lambda x: isinstance(x, PV))


def _sublayer_defs(kind: str, cfg: ModelConfig):
    if kind == ATTN:
        return L.attn_defs(cfg)
    if kind == XATTN:
        return L.xattn_defs(cfg)
    if kind == MAMBA:
        return L.mamba_defs(cfg)
    if kind == MLP:
        return L.mlp_defs(cfg)
    if kind == MOE:
        return L.moe_defs_tp(cfg) if cfg.moe_tp else L.moe_defs(cfg)
    raise ValueError(kind)


def model_defs(cfg: ModelConfig) -> dict:
    d, V, dt = cfg.d_model, cfg.vocab_size, cfg.dtype
    # embed/head are vocab-sharded over `model` ONLY: FSDP-sharding their
    # d_model dim makes every loss chunk / embed lookup all-gather the whole
    # table over `data` (measured 8x wire blow-up in the dry-run).
    Vp = cfg.padded_vocab
    defs: dict[str, Any] = {
        "embed": PV((Vp, d), dt, ("model", ""), "normal", 0.02),
        "final_norm": PV((d,), jnp.float32, ("",), "ones"),
    }
    if not cfg.tie_embeddings:
        defs["head"] = PV((d, Vp), dt, ("", "model"))
    period = {}
    for li, layer in enumerate(cfg.layer_period):
        slots = {}
        for si, kind in enumerate(layer):
            slots[f"s{si}_{kind}"] = _stack(_sublayer_defs(kind, cfg),
                                            cfg.n_periods)
        period[f"l{li}"] = slots
    defs["period"] = period
    if cfg.family == "encdec":
        enc_layer = {"attn": L.attn_defs(cfg), "mlp": L.mlp_defs(cfg)}
        defs["encoder"] = {"layers": _stack(enc_layer, cfg.n_enc_layers),
                           "norm": PV((d,), jnp.float32, ("",), "ones")}
    if cfg.d_ctx:
        defs["ctx_proj"] = PV((cfg.d_ctx, d), dt, ("", "fsdp"))
    return defs


# ---------------------------------------------------------------------------
# Cache definitions (decode)
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    period = {}
    for li, layer in enumerate(cfg.layer_period):
        slots = {}
        for si, kind in enumerate(layer):
            if kind == ATTN:
                slots[f"s{si}_{kind}"] = _stack(
                    L.attn_cache_defs(cfg, batch, seq_len)._asdict(),
                    cfg.n_periods)
            elif kind == XATTN:
                slots[f"s{si}_{kind}"] = _stack(
                    L.xattn_cache_defs(cfg, batch)._asdict(), cfg.n_periods)
            elif kind == MAMBA:
                slots[f"s{si}_{kind}"] = _stack(
                    L.mamba_cache_defs(cfg, batch)._asdict(), cfg.n_periods)
        period[f"l{li}"] = slots
    return period


def pool_defs(cfg: ModelConfig, n_blocks: int, block_tokens: int) -> dict:
    """Paged-KV block pool defs: same tree shape as :func:`cache_defs` but
    each ATTN leaf is (n_periods, n_blocks, block_tokens, Hkv, Dh) — a
    shared pool of fixed-size token blocks indexed by per-request block
    tables (block 0 is the reserved zero block).  Paged serving supports
    pure-attention caches only (no SSM/xattn state) and full attention
    (no SWA ring), which the serving engine validates."""
    if cfg.window:
        raise ValueError("paged KV supports full attention only "
                         f"(cfg.window={cfg.window})")
    shp = (n_blocks, block_tokens, cfg.n_kv_heads, cfg.head_dim)
    period = {}
    for li, layer in enumerate(cfg.layer_period):
        slots = {}
        for si, kind in enumerate(layer):
            if kind == ATTN:
                slots[f"s{si}_{kind}"] = _stack(
                    {"k": PV(shp, cfg.dtype, ("", "", "kv", ""), "zeros"),
                     "v": PV(shp, cfg.dtype, ("", "", "kv", ""), "zeros")},
                    cfg.n_periods)
            elif kind in (XATTN, MAMBA):
                raise ValueError(
                    f"paged KV serving supports attention caches only, "
                    f"layer period has {kind}")
        period[f"l{li}"] = slots
    return period


# ---------------------------------------------------------------------------
# Context (encoder / image frontend)
# ---------------------------------------------------------------------------

def context_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.family == "encdec":
        return max(cfg.ssm_chunk, seq_len // 4)      # speech frames downsampled
    return cfg.n_ctx_tokens


def encode_context(params, ctx_embeds, cfg: ModelConfig, rules: ShardingRules):
    """Frontend stub output -> d_model context for xattn (encoder if encdec)."""
    ctx = ctx_embeds.astype(cfg.dtype)
    if "ctx_proj" in params:
        ctx = ctx @ params["ctx_proj"]
    ctx = constraint(ctx, rules, "batch", None, None)
    if cfg.family != "encdec":
        return ctx

    enc = params["encoder"]
    S = ctx.shape[1]
    positions = jnp.arange(S)

    def body(x, lp):
        x = L.attn_layer(lp["attn"], x, cfg, rules, positions, causal=False)
        x = L.mlp_layer(lp["mlp"], x, cfg, rules)
        return x, None

    if cfg.remat:
        body = jax.checkpoint(body)
    ctx, _ = jax.lax.scan(body, ctx, enc["layers"])
    return L.rmsnorm(ctx, enc["norm"], cfg.norm_eps)


# ---------------------------------------------------------------------------
# Decoder trunk
# ---------------------------------------------------------------------------

def _apply_slot(kind, sp, x, cfg, rules, positions, ctx):
    if kind == ATTN:
        return L.attn_layer(sp, x, cfg, rules, positions, causal=True)
    if kind == XATTN:
        return L.xattn_layer(sp, x, ctx, cfg, rules)
    if kind == MAMBA:
        return L.mamba_layer(sp, x, cfg, rules)
    if kind == MLP:
        return L.mlp_layer(sp, x, cfg, rules)
    if kind == MOE:
        return L.moe_layer(sp, x, cfg, rules)
    raise ValueError(kind)


def trunk(params, x, cfg: ModelConfig, rules: ShardingRules, positions,
          ctx=None):
    period = params["period"]
    kinds = cfg.layer_period

    def body(xc, pp):
        for li, layer in enumerate(kinds):
            for si, kind in enumerate(layer):
                sp = pp[f"l{li}"][f"s{si}_{kind}"]
                xc = _apply_slot(kind, sp, xc, cfg, rules, positions, ctx)
                xc = constraint(xc, rules, "batch", "act_seq", None)
        return xc, None

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.unroll_layers:
        for i in range(cfg.n_periods):
            x, _ = body(x, jax.tree.map(lambda t: t[i], period))
        return x
    x, _ = jax.lax.scan(body, x, period)
    return x


def embed_tokens(params, tokens, cfg: ModelConfig, rules: ShardingRules):
    mesh = rules.mesh
    if mesh is None or "model" not in mesh.shape or \
            cfg.padded_vocab % mesh.shape["model"]:
        x = jnp.take(params["embed"], tokens, axis=0)
        return constraint(x, rules, "batch", "act_seq", None)

    # Explicit vocab-sharded lookup: masked local gather + psum over `model`.
    # (The GSPMD gather fallback replicates the whole table per device —
    # >1 GiB for 150k vocabularies; this is the AraXL byte-map discipline:
    # touch only the locally-resident rows, reduce on the lane axis.)
    from jax.sharding import PartitionSpec as P
    V_loc = cfg.padded_vocab // mesh.shape["model"]
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bspec = rules.spec(("batch", ""))

    def body(tok, emb):
        lo = substrate.axis_index("model") * V_loc
        ids = tok - lo
        ok = (ids >= 0) & (ids < V_loc)
        safe = jnp.where(ok, ids, 0)
        x = emb[safe]                          # emb local block (V_loc, d)
        x = jnp.where(ok[..., None], x, 0)
        return jax.lax.psum(x, "model")

    x = substrate.shard_map(body, mesh=mesh,
                            in_specs=(bspec, P("model", None)),
                            out_specs=bspec)(tokens, params["embed"])
    return constraint(x, rules, "batch", "act_seq", None)


def _mask_pad_vocab(logits, cfg: ModelConfig):
    if cfg.padded_vocab == cfg.vocab_size:
        return logits
    ids = jnp.arange(cfg.padded_vocab)
    return jnp.where(ids >= cfg.vocab_size, jnp.asarray(-1e30, logits.dtype),
                     logits)


def logits_fn(params, x, cfg: ModelConfig, rules: ShardingRules):
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = _mask_pad_vocab(x @ head, cfg)
    return constraint(logits, rules, "batch", None, "model")


def _ce_terms(logits, targets):
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    picked = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    return lse - picked


def ce_loss(params, x, targets, mask, cfg: ModelConfig,
            rules: ShardingRules):
    """Mean masked next-token CE.  With cfg.loss_chunk the sequence is
    processed in checkpointed blocks so the f32 logits (B, S, V) are never
    materialised whole — the decisive memory lever for 100k+ vocabularies."""
    B, S, _ = x.shape
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["head"]
    chunk = cfg.loss_chunk
    if chunk <= 0 or S <= chunk or S % chunk:
        logits = constraint(_mask_pad_vocab(x @ head, cfg), rules,
                            "batch", None, "model")
        tok_loss = _ce_terms(logits, targets)
        return jnp.sum(tok_loss * mask) / jnp.sum(mask)

    nc = S // chunk
    xs = (x.reshape(B, nc, chunk, -1).transpose(1, 0, 2, 3),
          targets.reshape(B, nc, chunk).transpose(1, 0, 2),
          mask.reshape(B, nc, chunk).transpose(1, 0, 2))

    @jax.checkpoint
    def body(acc, blk):
        xc, tc, mc = blk
        logits = constraint(_mask_pad_vocab(xc @ head, cfg), rules,
                            "batch", None, "model")
        return acc + jnp.sum(_ce_terms(logits, tc) * mc), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), xs)
    return total / jnp.sum(mask)


def forward_train(params, tokens, cfg: ModelConfig, rules: ShardingRules,
                  ctx_embeds=None):
    """tokens (B, S) -> mean next-token cross-entropy loss."""
    B, S = tokens.shape
    positions = jnp.arange(S)
    ctx = None
    if cfg.family in ("encdec", "vlm"):
        ctx = encode_context(params, ctx_embeds, cfg, rules)
    x = embed_tokens(params, tokens, cfg, rules)
    x = trunk(params, x, cfg, rules, positions, ctx)
    targets = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones((B, S), jnp.float32).at[:, -1].set(0.0)
    return ce_loss(params, x, targets, mask, cfg, rules)


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def prefill(params, tokens, cfg: ModelConfig, rules: ShardingRules,
            cache_seq_len: int, ctx_embeds=None):
    """tokens (B, S) -> (cache, last-token logits)."""
    B, S = tokens.shape
    positions = jnp.arange(S)
    ctx = None
    if cfg.family in ("encdec", "vlm"):
        ctx = encode_context(params, ctx_embeds, cfg, rules)
    x = embed_tokens(params, tokens, cfg, rules)
    kinds = cfg.layer_period
    W = L.attn_cache_len(cfg, cache_seq_len)

    def body(xc, pp):
        caches = {}
        for li, layer in enumerate(kinds):
            lcaches = {}
            for si, kind in enumerate(layer):
                key = f"s{si}_{kind}"
                sp = pp[f"l{li}"][key]
                if kind == ATTN:
                    xc, c = L.attn_layer_prefill(sp, xc, cfg, rules,
                                                 positions, W)
                    lcaches[key] = c._asdict()
                elif kind == XATTN:
                    xc = L.xattn_layer(sp, xc, ctx, cfg, rules)
                    lcaches[key] = L.xattn_prefill_cache(sp, ctx, cfg)._asdict()
                elif kind == MAMBA:
                    xc, (conv, state) = L.mamba_layer(sp, xc, cfg, rules,
                                                      return_state=True)
                    lcaches[key] = {"conv": conv.astype(cfg.dtype),
                                    "state": state}
                else:
                    xc = _apply_slot(kind, sp, xc, cfg, rules, positions, ctx)
            caches[f"l{li}"] = lcaches
        xc = constraint(xc, rules, "batch", None, None)
        return xc, caches

    if cfg.remat:
        body = jax.checkpoint(body)
    if cfg.unroll_layers:                      # cost-analysis variants
        caches = []
        for i in range(cfg.n_periods):
            x, c = body(x, jax.tree.map(lambda t: t[i], params["period"]))
            caches.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        x, cache = jax.lax.scan(body, x, params["period"])
    logits = logits_fn(params, x[:, -1:], cfg, rules)
    return cache, logits


def decode_step(params, token, cache, pos, cfg: ModelConfig,
                rules: ShardingRules):
    """token (B, 1), pos scalar int32 or (B,) int32 per-slot positions
    -> (logits (B,1,V), new cache).  The vector form is the serving
    engine's continuous batch; it is bit-identical to the scalar form
    when every slot sits at the same position."""
    x = embed_tokens(params, token, cfg, rules)
    kinds = cfg.layer_period

    def body(xc, pc):
        pp, cc = pc
        new_caches = {}
        for li, layer in enumerate(kinds):
            lcaches = {}
            for si, kind in enumerate(layer):
                key = f"s{si}_{kind}"
                sp = pp[f"l{li}"][key]
                if kind == ATTN:
                    c = L.AttnCache(**cc[f"l{li}"][key])
                    xc, c = L.attn_layer_decode(sp, xc, c, pos, cfg, rules)
                    lcaches[key] = c._asdict()
                elif kind == XATTN:
                    c = L.XAttnCache(**cc[f"l{li}"][key])
                    xc, c = L.xattn_layer_decode(sp, xc, c, cfg, rules)
                    lcaches[key] = c._asdict()
                elif kind == MAMBA:
                    c = L.MambaCache(**cc[f"l{li}"][key])
                    xc, c = L.mamba_layer_decode(sp, xc, c, cfg, rules)
                    lcaches[key] = c._asdict()
                else:
                    xc = _apply_slot(kind, sp, xc, cfg, rules, None, None)
            new_caches[f"l{li}"] = lcaches
        return xc, new_caches

    if cfg.unroll_layers:                      # cost-analysis variants
        caches = []
        for i in range(cfg.n_periods):
            x, c = body(x, jax.tree.map(lambda t: t[i],
                                        (params["period"], cache)))
            caches.append(c)
        new_cache = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
    else:
        x, new_cache = jax.lax.scan(body, x, (params["period"], cache))
    logits = logits_fn(params, x, cfg, rules)
    return logits, new_cache


def decode_step_paged(params, token, pool, tables, pos, live,
                      cfg: ModelConfig, rules: ShardingRules):
    """One-token decode through block tables.

    token (B, 1); pool — the :func:`pool_defs` tree; tables
    (B, max_blocks) int32; pos (B,) int32 per-slot positions; live (B,)
    bool -> (logits (B,1,V), new pool).  Bit-identical to
    :func:`decode_step` given tables whose gathered view equals the dense
    cache (zero block 0 ≡ unwritten dense rows)."""
    x = embed_tokens(params, token, cfg, rules)

    def step(sp, xc, pk, pv):
        return L.attn_layer_decode_paged(sp, xc, pk, pv, tables, pos, live,
                                         cfg, rules)

    def body(xc, pc):
        pp, cc = pc
        new_pool = {}
        for li, layer in enumerate(cfg.layer_period):
            lpool = {}
            for si, kind in enumerate(layer):
                key = f"s{si}_{kind}"
                sp = pp[f"l{li}"][key]
                if kind == ATTN:
                    c = cc[f"l{li}"][key]
                    xc, pk, pv = step(sp, xc, c["k"], c["v"])
                    lpool[key] = {"k": pk, "v": pv}
                else:
                    xc = _apply_slot(kind, sp, xc, cfg, rules, None, None)
            new_pool[f"l{li}"] = lpool
        return xc, new_pool

    x, new_pool = jax.lax.scan(body, x, (params["period"], pool))
    logits = logits_fn(params, x, cfg, rules)
    return logits, new_pool


def prefill_chunk(params, tokens, pool, table_row, start, valid,
                  cfg: ModelConfig, rules: ShardingRules):
    """One fixed-size prefill chunk for a single request (B == 1).

    tokens (1, c) padded to the chunk length; ``start`` the chunk's base
    position (multiple of the block size), ``valid`` the count of real
    tokens.  Scatters the chunk's K/V into the pre-allocated blocks of
    ``table_row`` and returns (logits (1, c, V), new pool) — the engine
    reads logits[0, valid-1] on the final chunk for the first generated
    token.  Compiles once per chunk shape, not once per prompt length."""
    x = embed_tokens(params, tokens, cfg, rules)

    def body(xc, pc):
        pp, cc = pc
        new_pool = {}
        for li, layer in enumerate(cfg.layer_period):
            lpool = {}
            for si, kind in enumerate(layer):
                key = f"s{si}_{kind}"
                sp = pp[f"l{li}"][key]
                if kind == ATTN:
                    c = cc[f"l{li}"][key]
                    xc, pk, pv = L.attn_layer_prefill_paged(
                        sp, xc, c["k"], c["v"], table_row, start, valid,
                        cfg, rules)
                    lpool[key] = {"k": pk, "v": pv}
                else:
                    xc = _apply_slot(kind, sp, xc, cfg, rules, None, None)
            new_pool[f"l{li}"] = lpool
        return xc, new_pool

    x, new_pool = jax.lax.scan(body, x, (params["period"], pool))
    logits = logits_fn(params, x, cfg, rules)
    return logits, new_pool
