"""Topology — the one description of AraXL's machine geometry (§III-B).

AraXL's scalability argument (§III-B.4, §IV) rests on a *hierarchical*
interconnect: C clusters of L lanes each, where intra-cluster traffic rides
short wires (log2(L) cheap hops) and only the per-cluster stage ever touches
the long inter-cluster ring (log2(C) expensive hops).  Before this module the
repo carried two disconnected copies of that geometry — the emulation layer
(`repro.core.layout` / `ring` / `glsu`) took ``hierarchy="flat"|"two-level"``
kwargs while the analytical layer (`repro.sim`) hard-coded a flat ring.

:class:`Topology` is the single shared value: ``repro.sim.AraXLParams``
composes one (``params.topology``), ``repro.core.machine.make_machine``
accepts one and stores it on the ``VectorMachineSpec``, and ``launch/`` +
``benchmarks/run.py`` thread one through the fig6/fig7 scaling surface.  It
is pure Python (no jax import) so the sim layer stays data-free.

Hop pricing
-----------

Two wire classes, priced independently:

``intra_hop_lat``  one hop on the intra-cluster interconnect (short wires)
``inter_hop_lat``  one hop on the inter-cluster ring (RINGI; grows with C)

``hierarchy="flat"`` models the flattened C*L ring AraXL argues against:
every hop is an inter-class (long-wire) hop.  ``hierarchy="two-level"`` is
the paper's design: :meth:`hop_cost` prices a link by whether it crosses a
cluster boundary, and :meth:`slide_cost` prices a k-position slide by its
critical-path lane (the one that crosses the most boundaries).
"""
from __future__ import annotations

import dataclasses
import math

#: the two interconnect models (shared by core.ring, core.glsu, sim.params)
HIERARCHIES = ("flat", "two-level")

#: wire classes a transfer can ride
LEVELS = ("intra", "inter")


def check_hierarchy(hierarchy: str) -> None:
    if hierarchy not in HIERARCHIES:
        raise ValueError(f"hierarchy must be one of {HIERARCHIES}, "
                         f"got {hierarchy!r}")


@dataclasses.dataclass(frozen=True)
class Topology:
    """C clusters x L lanes/cluster plus the hierarchy and per-level wire
    prices.  Equality is by value, so two stacks provably share a topology
    when their ``Topology`` objects compare equal."""

    n_clusters: int
    lanes_per_cluster: int
    hierarchy: str = "two-level"
    cluster_axis: "str | tuple[str, ...]" = "cluster"
    lane_axis: "str | tuple[str, ...]" = "lane"
    intra_hop_lat: float = 2.0        # short-wire hop (cycles)
    inter_hop_lat: float = 4.0        # inter-cluster ring hop (cycles)

    def __post_init__(self):
        if self.n_clusters < 1 or self.lanes_per_cluster < 1:
            raise ValueError(f"need >=1 cluster and >=1 lane/cluster, got "
                             f"C={self.n_clusters} L={self.lanes_per_cluster}")
        check_hierarchy(self.hierarchy)

    # -- geometry -----------------------------------------------------------
    @property
    def n_lanes(self) -> int:
        """Total lanes (= flattened ring size = C * L)."""
        return self.n_clusters * self.lanes_per_cluster

    @property
    def grid(self) -> tuple[int, int]:
        return (self.n_clusters, self.lanes_per_cluster)

    @property
    def axis_names(self) -> tuple:
        return (self.cluster_axis, self.lane_axis)

    def coords(self, p: int) -> tuple[int, int]:
        """Flattened ring position p (cluster-major, lane-minor) -> (c, l)."""
        return divmod(p % self.n_lanes, self.lanes_per_cluster)

    def cluster_of(self, p: int) -> int:
        return self.coords(p)[0]

    def lane_of(self, p: int) -> int:
        return self.coords(p)[1]

    # -- wire pricing -------------------------------------------------------
    def link_level(self, p: int) -> str:
        """Wire class of the ring link p -> p+1: "inter" iff it crosses a
        cluster boundary (including the wrap link n-1 -> 0)."""
        return ("inter" if (p + 1) % self.lanes_per_cluster == 0 and
                self.n_clusters > 1 else "intra")

    def hop_lat(self, level: str) -> float:
        if level not in LEVELS:
            raise ValueError(f"level must be one of {LEVELS}, got {level!r}")
        return self.intra_hop_lat if level == "intra" else self.inter_hop_lat

    def hop_cost(self, src: int, dst: int) -> float:
        """Cycles for one transfer from ring position ``src`` forward to
        ``dst`` (sum of link prices along the directed ring path).  Under the
        flat hierarchy every link is priced as a long-wire ring hop."""
        n = self.n_lanes
        steps = (dst - src) % n
        if self.hierarchy == "flat":
            return steps * self.inter_hop_lat
        return sum(self.hop_lat(self.link_level((src + i) % n))
                   for i in range(steps))

    def slide_crossings(self, hops: int) -> int:
        """Cluster-boundary crossings on the *critical* lane path of a slide
        by ``hops`` positions (the completion bound: the slowest lane)."""
        if self.n_clusters == 1:
            return 0
        return min(hops, math.ceil(hops / self.lanes_per_cluster))

    def slide_level(self, hops: int = 1) -> str:
        """Wire class the critical path of a ``hops``-position slide crosses
        ("inter" whenever any lane must cross a cluster boundary)."""
        return "inter" if self.slide_crossings(max(1, hops)) else "intra"

    def slide_cost(self, hops: int) -> float:
        """Critical-path cycles before a slide by ``hops`` can stream.

        flat:       every hop is a full ring hop -> hops * inter_hop_lat.
        two-level:  the slowest lane crosses ceil(hops/L) cluster boundaries;
                    its remaining steps ride the short intra-cluster wires.
        """
        hops = max(0, hops)
        if self.hierarchy == "flat":
            return hops * self.inter_hop_lat
        inter = self.slide_crossings(hops)
        return inter * self.inter_hop_lat + (hops - inter) * self.intra_hop_lat

    @staticmethod
    def tree_stages(size: int):
        """Recursive-doubling stage distances 1, 2, 4, ... < size (the
        §III-B.4 log-tree: stage s rides s ring hops)."""
        s = 1
        while s < size:
            yield s
            s *= 2

    def tree_wire_cycles(self) -> float:
        """Pure wire cycles of a full cross-machine log-tree reduction.

        flat:       every stage spans the whole C*L ring at ring-hop price.
        two-level:  log2(L) stages on intra-cluster wires, then log2(C)
                    stages on the ring — the long wires never see lane
                    traffic, which is the paper's physical-scalability claim.

        Note this prices bare wires only; AraXL's *reduction* pipeline runs
        its intra-cluster stages through the calibrated A2A stage
        (``AraXLParams.interlane_lat``), so ``red_tree_lat`` consumes this
        method's ring terms but substitutes its own intra-cluster stage cost.
        """
        if self.hierarchy == "flat":
            return sum(s * self.inter_hop_lat
                       for s in self.tree_stages(self.n_lanes))
        intra = sum(s * self.intra_hop_lat
                    for s in self.tree_stages(self.lanes_per_cluster))
        inter = sum(s * self.inter_hop_lat
                    for s in self.tree_stages(self.n_clusters))
        return intra + inter

    # -- derivation helpers -------------------------------------------------
    def with_hierarchy(self, hierarchy: str) -> "Topology":
        return dataclasses.replace(self, hierarchy=hierarchy)

    def with_grid(self, n_clusters: int, lanes_per_cluster: int) -> "Topology":
        return dataclasses.replace(self, n_clusters=n_clusters,
                                   lanes_per_cluster=lanes_per_cluster)

    def describe(self) -> dict:
        """JSON-friendly record (benchmarks / dry-run artifacts)."""
        return {
            "n_clusters": self.n_clusters,
            "lanes_per_cluster": self.lanes_per_cluster,
            "n_lanes": self.n_lanes,
            "hierarchy": self.hierarchy,
            "cluster_axis": self.cluster_axis,
            "lane_axis": self.lane_axis,
            "intra_hop_lat": self.intra_hop_lat,
            "inter_hop_lat": self.inter_hop_lat,
        }


def factorizations(n_lanes: int, power_of_two: bool = True):
    """All (C, L) grids with C*L == n_lanes — the fig6 factorisation sweep
    (64 lanes as 16x4 / 8x8 / 4x16 / ...)."""
    out = []
    for L in range(1, n_lanes + 1):
        if n_lanes % L:
            continue
        C = n_lanes // L
        if power_of_two and ((C & (C - 1)) or (L & (L - 1))):
            continue
        out.append((C, L))
    return out


def parse_topology(s: str, **kw) -> Topology:
    """Parse "CxL" or "CxL:hierarchy" (e.g. "16x4:two-level") into a
    Topology; extra kwargs (axis names, hop prices) pass through."""
    spec, _, hierarchy = s.partition(":")
    try:
        c, _, l = spec.partition("x")
        C, L = int(c), int(l)
    except ValueError:
        raise ValueError(f"topology spec must look like '16x4[:hierarchy]', "
                         f"got {s!r}") from None
    if hierarchy:
        kw["hierarchy"] = hierarchy
    return Topology(C, L, **kw)
