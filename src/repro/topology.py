"""Topology — the one description of AraXL's machine geometry (§III-B).

AraXL's scalability argument (§III-B.4, §IV) rests on a *hierarchical*
interconnect: clusters of lanes, where intra-cluster traffic rides short
wires (log2(L) cheap hops) and only the per-cluster stage ever touches the
long inter-cluster ring (log2(C) expensive hops).  Ara2 and Spatz show the
cluster-of-clusters shape recurses — pods of clusters of lanes — so the
geometry here is an ordered tuple of :class:`Level` s (outermost first),
each with a name (its mesh axis), a fan-out, and a per-hop wire price:

    Topology.from_levels([("pod", 2, 8.0), ("cluster", 8, 4.0),
                          ("lane", 4, 2.0)])

:class:`Topology` is the single shared value: ``repro.sim.AraXLParams``
composes one (``params.topology``), ``repro.core.machine.make_machine``
accepts one and builds one mesh axis per level, and ``launch/`` +
``benchmarks/run.py`` thread one through the fig6/fig7 scaling surface.  It
is pure Python (no jax import) so the sim layer stays data-free.

Hop pricing
-----------

Every level prices its own wires: ``levels[i].hop_lat`` is the cycles for
one hop on level i's interconnect.  A link of the flattened (outer-major)
ring is priced by the *outermost* boundary it crosses — the most expensive
wire class on its path.  Wire-class labels (:meth:`wire_labels`) keep the
historical two names for the two innermost levels — ``"intra"`` (short
intra-cluster wires) and ``"inter"`` (the inter-cluster ring) — and use the
level's own name for anything further out (e.g. ``"pod"``).

``hierarchy="flat"`` models the flattened ring AraXL argues against: every
hop is priced as the outermost (longest-wire) class.  The hierarchical
model — ``"two-level"`` for two levels, ``"three-level"`` for three, … —
prices each link/stage by the level it actually rides, which is the paper's
physical-scalability claim.  The legacy two-entry constructor
``Topology(C, L, hierarchy=...)`` still parses and prices bit-identically
to the PR 2 calibration (flat/two-level ``red_tree_lat`` at 64 lanes:
286 / 106 cycles — asserted by tests against ``BENCH_sim.json``).
"""
from __future__ import annotations

import dataclasses
import math

#: the two historical interconnect models (kept for the two-level case;
#: deeper topologies name their hierarchical model "<n>-level")
HIERARCHIES = ("flat", "two-level")

#: the two historical wire classes; deeper levels label wires by level name
LEVELS = ("intra", "inter")

#: "<n>-level" spellings for the common depths (hier_name falls back to
#: the numeric form for anything deeper)
_HIER_WORDS = {1: "one-level", 2: "two-level", 3: "three-level",
               4: "four-level", 5: "five-level"}

#: default per-level axis names for parse_topology("PxCxL") style specs,
#: innermost last; levels beyond the pod are named by their depth from the
#: innermost (lane=1, cluster=2, pod=3): "l4", "l5", ...
DEFAULT_LEVEL_AXES = ("pod", "cluster", "lane")

#: default per-hop wire price for level j counted from the innermost
#: (lane) level outward: 2, 4, 8, ... cycles — each level's wires are
#: roughly twice as long as the level below.
def default_hop_lat(depth_from_inner: int) -> float:
    return 2.0 * (2 ** depth_from_inner)


#: default innermost-level wire bandwidth, bytes/s.  Matches the historical
#: flat launch-layer link price (``repro.roofline.analysis.HW["ici_bw"]``),
#: so a single-level topology prices collectives bit-identically to the old
#: flat ``wire_seconds()``.
DEFAULT_WIRE_BW = 50e9


#: default per-level wire bandwidth counted from the innermost level
#: outward: 50, 25, 12.5 ... GB/s — each level's longer wires carry half
#: the bandwidth of the level below (the launch-layer dual of
#: :func:`default_hop_lat`: latency doubles outward, bandwidth halves).
def default_wire_bw(depth_from_inner: int) -> float:
    return DEFAULT_WIRE_BW / (2 ** depth_from_inner)


def hier_name(n_levels: int) -> str:
    """The canonical hierarchical-model name for an n-deep topology."""
    return _HIER_WORDS.get(n_levels, f"{n_levels}-level")


def check_hierarchy(hierarchy: str, n_levels: int | None = None) -> None:
    """Validate a hierarchy string: "flat" always parses; the hierarchical
    spelling must match the level count when one is given (so a two-entry
    topology still rejects "three-level", as it always did)."""
    if hierarchy == "flat":
        return
    if n_levels is not None:
        if hierarchy != hier_name(n_levels):
            raise ValueError(
                f"hierarchy must be 'flat' or {hier_name(n_levels)!r} for a "
                f"{n_levels}-level topology, got {hierarchy!r}")
        return
    stem = hierarchy[: -len("-level")] if hierarchy.endswith("-level") else ""
    known = {w[: -len("-level")] for w in _HIER_WORDS.values()}
    if stem in known or stem.isdigit():
        return
    raise ValueError(f"hierarchy must be 'flat' or a hier_name() spelling "
                     f"('two-level', 'three-level', ..., '<n>-level'), "
                     f"got {hierarchy!r}")


@dataclasses.dataclass(frozen=True)
class Level:
    """One level of the interconnect hierarchy.

    ``axis``     mesh-axis name(s) this level shards over (str, or a tuple
                 of names treated as one flattened ring)
    ``size``     fan-out: how many level-(i+1) groups one group contains
    ``hop_lat``  cycles for one hop on this level's wires (the sim price)
    ``wire_bw``  bytes/s one link of this level's wires sustains (the
                 launch-layer price; ``None`` defaults by depth — 50 GB/s
                 innermost, halving outward, see :func:`default_wire_bw`)
    """
    axis: "str | tuple[str, ...]"
    size: int
    hop_lat: float
    wire_bw: "float | None" = None

    def __post_init__(self):
        if self.size < 1:
            raise ValueError(f"level {self.axis!r} needs size >= 1, "
                             f"got {self.size}")
        if self.hop_lat < 0:
            raise ValueError(f"level {self.axis!r} needs hop_lat >= 0, "
                             f"got {self.hop_lat}")
        if self.wire_bw is not None and self.wire_bw <= 0:
            raise ValueError(f"level {self.axis!r} needs wire_bw > 0, "
                             f"got {self.wire_bw}")

    @property
    def axes(self) -> tuple:
        """``axis`` normalised to a tuple of mesh-axis names."""
        return (self.axis,) if isinstance(self.axis, str) else tuple(self.axis)


def _as_level(entry) -> Level:
    if isinstance(entry, Level):
        return entry
    return Level(*entry)


@dataclasses.dataclass(frozen=True, init=False)
class Topology:
    """An N-deep machine geometry: ``levels`` outermost-first, plus which
    pricing model (``hierarchy``) applies.  Equality is by value, so two
    stacks provably share a topology when their ``Topology`` objects
    compare equal.

    The historical two-entry form ``Topology(C, L, hierarchy=...,
    cluster_axis=..., lane_axis=..., intra_hop_lat=..., inter_hop_lat=...)``
    builds the equivalent two-level geometry and is bit-identical to PR 2's
    calibration; pass ``levels=`` (or use :meth:`from_levels`) for deeper
    hierarchies.
    """

    levels: tuple
    hierarchy: str

    def __init__(self, n_clusters: int | None = None,
                 lanes_per_cluster: int | None = None,
                 hierarchy: str | None = None,
                 cluster_axis: "str | tuple[str, ...]" = "cluster",
                 lane_axis: "str | tuple[str, ...]" = "lane",
                 intra_hop_lat: float = 2.0,
                 inter_hop_lat: float = 4.0,
                 *, levels=None):
        if levels is not None:
            if n_clusters is not None or lanes_per_cluster is not None:
                raise ValueError("pass either levels= or "
                                 "(n_clusters, lanes_per_cluster), not both")
            levels = tuple(_as_level(l) for l in levels)
            if not levels:
                raise ValueError("need at least one level")
        else:
            if n_clusters is None or lanes_per_cluster is None:
                raise ValueError("pass (n_clusters, lanes_per_cluster) or "
                                 "levels=")
            if n_clusters < 1 or lanes_per_cluster < 1:
                raise ValueError(
                    f"need >=1 cluster and >=1 lane/cluster, got "
                    f"C={n_clusters} L={lanes_per_cluster}")
            levels = (Level(cluster_axis, n_clusters, inter_hop_lat),
                      Level(lane_axis, lanes_per_cluster, intra_hop_lat))
        if hierarchy is None:
            hierarchy = hier_name(len(levels))
        check_hierarchy(hierarchy, len(levels))
        names = [l.axis for l in levels]
        if len(set(names)) != len(names):
            raise ValueError(f"level axis names must be unique, got {names}")
        object.__setattr__(self, "levels", levels)
        object.__setattr__(self, "hierarchy", hierarchy)
        # Precomputed pricing tables (the sim prices every trace record
        # through this frozen value, link by link — don't rebuild per call).
        strides, s = [], 1
        for l in reversed(levels):
            strides.append(s)
            s *= l.size
        object.__setattr__(self, "_strides", tuple(reversed(strides)))
        groups, g = [], 1
        for l in levels:
            g *= l.size
            groups.append(g)
        object.__setattr__(self, "_groups_t", tuple(groups))
        labels = []
        for i, l in enumerate(levels):
            depth = len(levels) - 1 - i                # 0 = innermost
            if depth == 0:
                labels.append("intra")
            elif depth == 1:
                labels.append("inter")
            else:
                labels.append(l.axis if isinstance(l.axis, str)
                              else "+".join(l.axis))
        object.__setattr__(self, "_labels", tuple(labels))

    @classmethod
    def from_levels(cls, levels, hierarchy: str | None = None) -> "Topology":
        """Build from ``[(axis, size, hop_lat), ...]`` (outermost first)."""
        return cls(levels=levels, hierarchy=hierarchy)

    # -- geometry -----------------------------------------------------------
    @property
    def n_levels(self) -> int:
        return len(self.levels)

    @property
    def shape(self) -> tuple:
        """Per-level sizes, outermost first (the mesh shape)."""
        return tuple(l.size for l in self.levels)

    @property
    def n_lanes(self) -> int:
        """Total lanes (= flattened ring size = product of all fan-outs)."""
        return math.prod(self.shape)

    @property
    def n_clusters(self) -> int:
        """Groups seen by the innermost level: the product of every outer
        fan-out (multi-pod machines fold their pods in here)."""
        return self.n_lanes // self.lanes_per_cluster

    @property
    def lanes_per_cluster(self) -> int:
        return self.levels[-1].size

    @property
    def grid(self) -> tuple[int, int]:
        return (self.n_clusters, self.lanes_per_cluster)

    @property
    def cluster_axis(self) -> "str | tuple[str, ...]":
        """Axis name(s) of everything above the lane level (a single name
        for two-level topologies, a tuple for deeper ones)."""
        outer = self.levels[:-1]
        if len(outer) == 1:
            return outer[0].axis
        names: list = []
        for l in outer:
            names.extend((l.axis,) if isinstance(l.axis, str) else l.axis)
        return tuple(names)

    @property
    def lane_axis(self) -> "str | tuple[str, ...]":
        return self.levels[-1].axis

    @property
    def intra_hop_lat(self) -> float:
        """Hop price of the innermost (intra-cluster) wires."""
        return self.levels[-1].hop_lat

    @property
    def inter_hop_lat(self) -> float:
        """Hop price of the level just above the lanes (the RINGI ring)."""
        return self.levels[-2].hop_lat if self.n_levels > 1 \
            else self.levels[-1].hop_lat

    @property
    def axis_names(self) -> tuple:
        """Per-level axis entries, outermost first."""
        return tuple(l.axis for l in self.levels)

    def strides(self) -> tuple[int, ...]:
        """Flattened-ring positions spanned by one step of each level
        (outermost first; the innermost stride is always 1)."""
        return self._strides

    def coords(self, p: int) -> tuple:
        """Flattened ring position p (outer-major) -> per-level coordinates
        (outermost first; ``(c, l)`` for a two-level topology)."""
        p %= self.n_lanes
        out = []
        for stride, l in zip(self.strides(), self.levels):
            out.append((p // stride) % l.size)
        return tuple(out)

    def cluster_of(self, p: int) -> int:
        """Flattened index of the cluster holding ring position p."""
        return (p % self.n_lanes) // self.lanes_per_cluster

    def lane_of(self, p: int) -> int:
        return p % self.lanes_per_cluster

    # -- wire pricing -------------------------------------------------------
    def wire_labels(self) -> tuple[str, ...]:
        """Per-level wire-class labels, outermost first.  The innermost two
        keep their historical names ("intra" / "inter"); deeper levels are
        labelled by their axis name (e.g. "pod")."""
        return self._labels

    def _groups(self) -> tuple[int, ...]:
        """Cumulative group counts, outermost first: how many level-i blocks
        the whole machine contains (1 means level i has no boundaries)."""
        return self._groups_t

    def _link_index(self, p: int) -> int:
        """Level index (outermost first) whose wires the ring link p -> p+1
        rides: the outermost level whose coordinate changes across the link
        (including the wrap link n-1 -> 0)."""
        v = (p % self.n_lanes) + 1
        groups = self._groups()
        for i, stride in enumerate(self.strides()):
            if groups[i] > 1 and v % stride == 0:
                return i
        return self.n_levels - 1

    def link_level(self, p: int) -> str:
        """Wire class of the ring link p -> p+1: the *outermost* boundary it
        crosses (including the wrap link n-1 -> 0)."""
        return self.wire_labels()[self._link_index(p)]

    def hop_lat(self, level: str) -> float:
        """Hop price of one wire class (by label, see :meth:`wire_labels`)."""
        labels = self.wire_labels()
        if level not in labels:
            raise ValueError(f"level must be one of {labels}, got {level!r}")
        return self.levels[labels.index(level)].hop_lat

    def wire_bw(self, level: str) -> float:
        """Wire bandwidth (bytes/s) of one wire class, by label.  Always a
        float: levels built without an explicit ``wire_bw`` resolve to the
        depth default (:func:`default_wire_bw` — 50 GB/s innermost, halving
        outward), so equality-by-value between default-priced topologies is
        unaffected by the launch-layer prices."""
        labels = self.wire_labels()
        if level not in labels:
            raise ValueError(f"level must be one of {labels}, got {level!r}")
        i = labels.index(level)
        l = self.levels[i]
        if l.wire_bw is not None:
            return l.wire_bw
        return default_wire_bw(self.n_levels - 1 - i)

    def hop_cost(self, src: int, dst: int) -> float:
        """Cycles for one transfer from ring position ``src`` forward to
        ``dst`` (sum of link prices along the directed ring path).  Under
        the flat hierarchy every link is priced as the outermost (longest)
        wire class."""
        n = self.n_lanes
        steps = (dst - src) % n
        if self.hierarchy == "flat":
            return steps * self.levels[0].hop_lat
        return sum(self.levels[self._link_index((src + i) % n)].hop_lat
                   for i in range(steps))

    def slide_steps(self, hops: int) -> tuple[int, ...]:
        """Critical-path step counts per level (outermost first) of a slide
        by ``hops`` positions: the slowest lane crosses
        ``ceil(hops / span_i)`` boundaries of level i or outer (span_i =
        positions per level-i block), and each crossing is priced at the
        outermost level it touches."""
        hops = max(0, hops)
        groups = self._groups()
        steps, prev = [], 0
        for i, stride in enumerate(self.strides()):
            if groups[i] > 1:
                # level-i-or-outer boundaries recur every stride_i ring
                # positions, so a window of `hops` consecutive links holds
                # at most ceil(hops / stride_i) of them
                b = min(hops, math.ceil(hops / stride))
            else:
                b = prev
            steps.append(b - prev)
            prev = b
        # innermost level absorbs every remaining step (degenerate 1-lane
        # machines included)
        steps[-1] += hops - prev
        return tuple(steps)

    def slide_crossings(self, hops: int) -> int:
        """Boundary crossings above the innermost level on the critical
        lane path of a slide by ``hops`` (the completion bound)."""
        return sum(self.slide_steps(hops)[:-1])

    def slide_level(self, hops: int = 1) -> str:
        """Wire class the critical path of a ``hops``-position slide crosses
        (the outermost level any lane must touch)."""
        steps = self.slide_steps(max(1, hops))
        for label, s in zip(self.wire_labels(), steps):
            if s:
                return label
        return self.wire_labels()[-1]

    def slide_cost(self, hops: int) -> float:
        """Critical-path cycles before a slide by ``hops`` can stream.

        flat:          every hop is priced at the outermost wire class.
        hierarchical:  the slowest lane crosses ceil(hops/stride_i)
                       boundaries of each level; each crossing is priced at
                       the outermost level it touches, the remaining steps
                       ride the short innermost wires.
        """
        hops = max(0, hops)
        if self.hierarchy == "flat":
            return hops * self.levels[0].hop_lat
        return sum(s * l.hop_lat
                   for s, l in zip(self.slide_steps(hops), self.levels))

    @staticmethod
    def tree_stages(size: int):
        """Recursive-doubling stage distances 1, 2, 4, ... < size (the
        §III-B.4 log-tree: stage s rides s ring hops)."""
        s = 1
        while s < size:
            yield s
            s *= 2

    def tree_wire_cycles(self) -> float:
        """Pure wire cycles of a full cross-machine log-tree reduction.

        flat:          every stage spans the whole flattened ring at the
                       outermost wire price.
        hierarchical:  log2(size_i) stages per level, each on that level's
                       own wires — the long wires never see inner-level
                       traffic, which is the paper's physical-scalability
                       claim (and it recurses: pod wires never see cluster
                       traffic either).

        Note this prices bare wires only; AraXL's *reduction* pipeline runs
        its intra-cluster stages through the calibrated A2A stage
        (``AraXLParams.interlane_lat``), so ``red_tree_lat`` consumes this
        method's outer-level terms but substitutes its own intra-cluster
        stage cost.
        """
        if self.hierarchy == "flat":
            return sum(s * self.levels[0].hop_lat
                       for s in self.tree_stages(self.n_lanes))
        return sum(s * l.hop_lat
                   for l in self.levels for s in self.tree_stages(l.size))

    # -- derivation helpers -------------------------------------------------
    def with_hierarchy(self, hierarchy: str) -> "Topology":
        return Topology(levels=self.levels, hierarchy=hierarchy)

    def with_levels(self, levels, hierarchy: str | None = None) -> "Topology":
        """Same pricing model, new geometry (hierarchy respelled to the new
        depth unless explicitly given or flat)."""
        if hierarchy is None and self.hierarchy == "flat":
            hierarchy = "flat"
        return Topology(levels=levels, hierarchy=hierarchy)

    def with_grid(self, n_clusters: int, lanes_per_cluster: int) -> "Topology":
        """Re-factorise as a two-level C x L machine.  Both the axis name
        and the wire price of the new outer level come from the ring level
        just above the lanes (``inter_hop_lat``); on a deeper topology the
        levels outside that ring are folded away (their counts live on in
        ``n_clusters``)."""
        ring = self.levels[-2] if self.n_levels > 1 else self.levels[0]
        inner = self.levels[-1]
        lvls = (Level(ring.axis, n_clusters, self.inter_hop_lat,
                      ring.wire_bw),
                Level(inner.axis if self.n_levels > 1 else "lane",
                      lanes_per_cluster, self.intra_hop_lat, inner.wire_bw))
        hierarchy = "flat" if self.hierarchy == "flat" else None
        return Topology(levels=lvls, hierarchy=hierarchy)

    @classmethod
    def from_describe(cls, d: dict) -> "Topology":
        """Rebuild a Topology from a :meth:`describe` record (the JSON the
        dry-run / perf artifacts store), levels, prices, and hierarchy
        intact — so recorded artifacts can be re-priced offline."""
        levels = [Level(tuple(l["axis"]) if isinstance(l["axis"], list)
                        else l["axis"], l["size"], l["hop_lat"],
                        l.get("wire_bw"))
                  for l in d["levels"]]
        return cls(levels=levels, hierarchy=d["hierarchy"])

    def describe(self) -> dict:
        """JSON-friendly record (benchmarks / dry-run artifacts)."""
        return {
            "n_levels": self.n_levels,
            "levels": [{"axis": list(l.axis) if isinstance(l.axis, tuple)
                        else l.axis,
                        "size": l.size, "hop_lat": l.hop_lat,
                        "wire_bw": self.wire_bw(lab)}
                       for l, lab in zip(self.levels, self.wire_labels())],
            "n_clusters": self.n_clusters,
            "lanes_per_cluster": self.lanes_per_cluster,
            "n_lanes": self.n_lanes,
            "hierarchy": self.hierarchy,
            "cluster_axis": self.cluster_axis,
            "lane_axis": self.lane_axis,
            "intra_hop_lat": self.intra_hop_lat,
            "inter_hop_lat": self.inter_hop_lat,
        }


def mesh_levels(topology: Topology, mesh_shape) -> list:
    """Resolve a topology's levels against a mesh: (mesh-axes tuple, size)
    pairs, outermost first, validating that every level axis exists in
    ``mesh_shape`` (a mapping of axis name -> size) and that the sizes
    agree.  Shared by the hierarchical workloads (ring attention, MoE
    all-to-all) so level/mesh mismatch errors are raised once, identically.
    """
    levels = []
    for l in topology.levels:
        axes = l.axes
        size = 1
        for a in axes:
            if a not in mesh_shape:
                raise ValueError(f"topology level axis {a!r} not in mesh "
                                 f"axes {tuple(mesh_shape)}")
            size *= mesh_shape[a]
        if size != l.size:
            raise ValueError(f"topology level {l.axis!r} size {l.size} != "
                             f"mesh size {size}")
        levels.append((axes, size))
    return levels


def factorizations(n_lanes: int, power_of_two: bool = True):
    """All (C, L) grids with C*L == n_lanes — the fig6 factorisation sweep
    (64 lanes as 16x4 / 8x8 / 4x16 / ...)."""
    out = []
    for L in range(1, n_lanes + 1):
        if n_lanes % L:
            continue
        C = n_lanes // L
        if power_of_two and ((C & (C - 1)) or (L & (L - 1))):
            continue
        out.append((C, L))
    return out


def parse_topology(s: str, *, level_axes=None, hop_lats=None, **kw) -> Topology:
    """Parse an N-level topology spec into a :class:`Topology`.

    Grammar: ``S1xS2x...xSk[:hierarchy]`` — sizes outermost first, e.g.
    ``"16x4"`` (two-level), ``"16x4:flat"``, ``"2x8x4"`` (pods x clusters
    x lanes), ``"2x8x4:flat"``.

    Two sizes keep the legacy keywords (``cluster_axis`` / ``lane_axis`` /
    ``intra_hop_lat`` / ``inter_hop_lat`` pass through to the two-level
    constructor, bit-identical to PR 2).  Deeper specs name their levels
    from ``level_axes`` (default: ``("pod", "cluster", "lane")`` innermost-
    last; levels outside the pod are named by depth from the innermost —
    "l4", "l5", ...) and price them from ``hop_lats`` (default: 2, 4, 8,
    ... cycles doubling outward).  Keywords that don't apply to the spec's
    depth raise.
    """
    spec, _, hierarchy = s.partition(":")
    try:
        sizes = tuple(int(part) for part in spec.split("x"))
        if len(sizes) < 2:
            raise ValueError(spec)
    except ValueError:
        raise ValueError(f"topology spec must look like '16x4[:hierarchy]' "
                         f"or '2x8x4[:hierarchy]', got {s!r}") from None
    if len(sizes) == 2:
        if level_axes is not None or hop_lats is not None:
            raise ValueError(
                f"level_axes/hop_lats apply to specs deeper than two levels; "
                f"for {s!r} use cluster_axis/lane_axis and "
                f"intra_hop_lat/inter_hop_lat")
        if hierarchy:
            kw["hierarchy"] = hierarchy
        return Topology(*sizes, **kw)
    if kw:
        raise ValueError(
            f"{sorted(kw)} apply to two-level specs only; for {s!r} pass "
            f"level_axes=/hop_lats= (one entry per level)")
    k = len(sizes)
    if level_axes is None:
        pad = tuple(f"l{j}" for j in range(k, len(DEFAULT_LEVEL_AXES), -1))
        level_axes = (pad + DEFAULT_LEVEL_AXES)[-k:]
    if len(level_axes) != k:
        raise ValueError(f"need {k} level axes for {s!r}, got {level_axes}")
    if hop_lats is None:
        hop_lats = tuple(default_hop_lat(k - 1 - i) for i in range(k))
    if len(hop_lats) != k:
        raise ValueError(f"need {k} hop latencies for {s!r}, got {hop_lats}")
    levels = [Level(a, n, lat) for a, n, lat in zip(level_axes, sizes,
                                                    hop_lats)]
    return Topology(levels=levels, hierarchy=hierarchy or None)
