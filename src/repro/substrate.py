"""Version-portable collectives substrate.

JAX has moved/renamed its SPMD surface across minor releases: ``shard_map``
graduated from ``jax.experimental.shard_map`` to ``jax.shard_map``, and
``jax.lax.axis_size`` only exists on recent versions.  Every repro module
resolves the primitives from here instead of guessing, so the whole codebase
tracks one compatibility point.

The exported surface is the subset the AraXL reproduction actually uses:

* :func:`shard_map`   — the per-device SPMD mapper (wherever it lives)
* :func:`axis_size`   — static size of one or more mesh axes, usable inside
  a ``shard_map`` body (derived via the ``psum(1, axes)`` identity, which
  constant-folds to a Python int on every supported version)
* :func:`axis_index`  — flattened (row-major) device index over mesh axes
* :func:`ppermute`    — neighbour permutation (the RINGI hop)
* :func:`all_gather` / :func:`psum_scatter` — the XLA-native comparison
  points for the §Perf flat-vs-hierarchical ablations
* :func:`mesh_axis_size` — axis size read off a concrete ``Mesh`` (outside
  any traced context)
* :func:`halo_block_spec` — an element-offset (overlapping halo) Pallas
  ``BlockSpec``, portable across the ``pl.Element`` and
  ``indexing_mode=pl.Unblocked`` spellings
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
from jax.sharding import Mesh

Axis = str | Sequence[str]


def _axis_tuple(axis_names: Axis) -> tuple[str, ...]:
    if isinstance(axis_names, str):
        return (axis_names,)
    return tuple(axis_names)


def _resolve_shard_map() -> Callable:
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    try:
        from jax.experimental.shard_map import shard_map as fn  # type: ignore
        return fn
    except ImportError as e:  # pragma: no cover - one of the two must exist
        raise ImportError(
            "neither jax.shard_map nor jax.experimental.shard_map is "
            f"available in jax {jax.__version__}") from e


_SHARD_MAP = _resolve_shard_map()


def shard_map(f: Callable, *, mesh: Mesh, in_specs, out_specs, **kwargs):
    """``shard_map`` resolved from wherever this jax version keeps it.

    Same calling convention as the modern ``jax.shard_map`` for the argument
    subset this repo uses (``mesh``/``in_specs``/``out_specs`` keywords).
    """
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def axis_size(axis_names: Axis) -> int:
    """Size of (the product of) mesh axes, inside a ``shard_map`` body.

    ``jax.lax.axis_size`` where it exists; otherwise the portable
    ``psum(1, axes)`` identity, which resolves to a static Python int
    because the reduced value is a non-traced constant.
    """
    names = _axis_tuple(axis_names)
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(names)
    return jax.lax.psum(1, names)


def axis_index(axis_names: Axis) -> jax.Array:
    """Flattened row-major index over ``axis_names`` (first axis major).

    Built from single-axis ``jax.lax.axis_index`` calls so it works on
    versions where the tuple form is missing.
    """
    names = _axis_tuple(axis_names)
    idx = jax.lax.axis_index(names[0])
    for a in names[1:]:
        idx = idx * axis_size(a) + jax.lax.axis_index(a)
    return idx


def ppermute(x: jax.Array, axis_names: Axis,
             perm: Sequence[tuple[int, int]]) -> jax.Array:
    """Source->dest permutation over the flattened ``axis_names`` ring."""
    return jax.lax.ppermute(x, _axis_tuple(axis_names), perm=perm)


def psum(x, axis_names: Axis):
    return jax.lax.psum(x, _axis_tuple(axis_names))


def pmax(x, axis_names: Axis):
    return jax.lax.pmax(x, _axis_tuple(axis_names))


def all_gather(x: jax.Array, axis_names: Axis, *, axis: int = 0,
               tiled: bool = True) -> jax.Array:
    """XLA-native all-gather (the flat baseline the RINGI version races)."""
    return jax.lax.all_gather(x, _axis_tuple(axis_names), axis=axis,
                              tiled=tiled)


def psum_scatter(x: jax.Array, axis_names: Axis, *, scatter_dimension: int = 0,
                 tiled: bool = True) -> jax.Array:
    """XLA-native reduce-scatter comparison point."""
    return jax.lax.psum_scatter(x, _axis_tuple(axis_names),
                                scatter_dimension=scatter_dimension,
                                tiled=tiled)


def mesh_axis_size(mesh: Mesh, axis_names: Axis) -> int:
    """Static axis size read off a concrete mesh (outside traced code)."""
    return math.prod(mesh.shape[a] for a in _axis_tuple(axis_names))


def halo_block_spec(block_shape: Sequence[int], index_map: Callable):
    """Pallas ``BlockSpec`` whose ``index_map`` returns *element* offsets.

    Overlapping halo windows (stencil reads) need element-granular block
    placement.  Recent jax spells this ``pl.Element`` per dimension; older
    versions use ``indexing_mode=pl.Unblocked()``.  Resolve whichever exists.
    """
    from jax.experimental import pallas as pl
    element = getattr(pl, "Element", None)
    if element is not None:
        return pl.BlockSpec(tuple(element(b) for b in block_shape), index_map)
    return pl.BlockSpec(tuple(block_shape), index_map,
                        indexing_mode=pl.Unblocked())
