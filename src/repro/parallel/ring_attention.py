"""Ring attention — RINGI applied to sequence-parallel attention.

The sequence is sharded across the ring of clusters ("data" axis); KV blocks
rotate one neighbour hop per step (exactly AraXL's slide-by-1 bus) while
every device accumulates its queries' online-softmax state.  After n-1 hops
every query has seen every key with only neighbour communication — the
paper's scalability argument (no all-to-all, latency hidden behind the local
attention compute) applied to 500k-token contexts.

Exact (online softmax), causal + sliding-window aware, GQA via kv repeat.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import substrate
from repro.core.ring import ppermute_shift


def _block_attn(q, k, v, q_pos, k_pos, scale, causal, window):
    s = jnp.einsum("bqhd,bthd->bhqt", q, k) * scale
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)                     # (b,h,q,1)
    m = jnp.maximum(m, -1e30)                                  # empty rows
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqt,bthd->bhqd", p, v)
    return m, l, o


def ring_attention(q, k, v, mesh: Mesh, *, axis: str = "data",
                   causal: bool = True, window: int | None = None):
    """q (B,S,H,D), k/v (B,S,Hkv,D) globally; S sharded over ``axis``.

    Returns (B,S,H,D) with the same sharding. One ppermute per step — the
    KV blocks ride the ring while online-softmax state stays local."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    n = mesh.shape[axis]
    S_loc = S // n
    scale = 1.0 / math.sqrt(D)

    def body(q_loc, k_loc, v_loc):
        pos = jax.lax.axis_index(axis)
        q_pos = pos * S_loc + jnp.arange(S_loc)
        qf = q_loc.astype(jnp.float32)
        m = jnp.full((B, H, S_loc, 1), -jnp.inf, jnp.float32)
        l = jnp.zeros((B, H, S_loc, 1), jnp.float32)
        o = jnp.zeros((B, H, S_loc, D), jnp.float32)
        kc, vc = k_loc.astype(jnp.float32), v_loc.astype(jnp.float32)
        src = pos
        for step in range(n):
            k_pos = src * S_loc + jnp.arange(S_loc)
            mb, lb, ob = _block_attn(qf, kc, vc, q_pos, k_pos, scale,
                                     causal, window)
            m_new = jnp.maximum(m, mb)
            alpha = jnp.exp(jnp.where(jnp.isfinite(m), m - m_new, -jnp.inf))
            beta = jnp.exp(jnp.where(jnp.isfinite(mb), mb - m_new, -jnp.inf))
            l = l * alpha + lb * beta
            o = o * alpha + ob * beta
            m = m_new
            if step < n - 1:                      # rotate KV one hop (RINGI)
                kc = ppermute_shift(kc, (axis,), 1, n)
                vc = ppermute_shift(vc, (axis,), 1, n)
                src = (src + 1) % n
        safe = jnp.where(l == 0.0, 1.0, l)
        out = (o / safe).transpose(0, 2, 1, 3)    # (B,S_loc,H,D)
        return out.astype(q_loc.dtype)

    spec_q = P(None, axis, None, None)
    return substrate.shard_map(body, mesh=mesh,
                               in_specs=(spec_q, spec_q, spec_q),
                               out_specs=spec_q)(q, k, v)
