"""Ring attention — RINGI applied to sequence-parallel attention.

The sequence is sharded across a ring of devices; KV blocks rotate one
neighbour hop per step (exactly AraXL's slide-by-1 bus) while every device
accumulates its queries' online-softmax state.  After visiting every shard,
each query has seen every key with only neighbour communication — the
paper's scalability argument (no all-to-all, latency hidden behind the local
attention compute) applied to 500k-token contexts.

Two schedules, selected by ``topology=``:

* ``topology=None`` (flat): the historical single-axis ring — n-1 hops on
  one ``axis``, each a whole-KV-block transfer.

* ``topology=Topology(...)``: the AraXL hierarchy.  The sequence is sharded
  over *all* topology level axes (outer-major), and the KV rotation walks
  the levels odometer-style — the innermost (intra-cluster / `lane`) ring
  rotates every step, and a level-i ring only turns once per full cycle of
  the levels below it (intra-level ring first, then the inter-level
  exchange).  Most steps are a single short-wire hop; an odometer wrap
  additionally rotates each wrapped inner ring once to complete its cycle
  (up to n_levels hops on that step), but the physically long inter-
  cluster / inter-pod wires still carry only 1 / (product of inner sizes)
  of the steps — AraXL's short-wires-do-the-work claim at the sequence
  level.  The two schedules visit the same blocks in a different order, so
  results agree with the flat axis up to online-softmax re-association
  (exact for the max statistics, last-ulp for the sums); both are exact
  attention.

Both variants take ``schedule=``:

* ``"seq"`` (historical): compute on block *k*, then rotate to fetch block
  *k+1* — the ppermute sits on the critical path between blocks.

* ``"db"`` (double-buffered): the ppermute fetching block *k+1* is issued
  *before* the attention compute on block *k* (the per-level odometer is
  preserved — the same rings turn on the same steps).  The collective has
  no data dependency on the in-flight block's compute, so a backend with
  async collectives overlaps the KV transfer with the attention math —
  AraXL's slides-ride-the-wires-while-FPUs-stream claim at the sequence
  level.  Blocks are visited in the same order with the same arithmetic,
  so the result is bit-identical to ``"seq"``.

Exact (online softmax), causal + sliding-window aware, GQA via kv repeat.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro import substrate
from repro.core.ring import ppermute_shift
from repro.topology import Topology, mesh_levels


def _block_attn(q, k, v, q_pos, k_pos, scale, causal, window):
    s = jnp.einsum("bqhd,bthd->bhqt", q, k) * scale
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)                     # (b,h,q,1)
    m = jnp.maximum(m, -1e30)                                  # empty rows
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqt,bthd->bhqd", p, v)
    return m, l, o


def _ring_levels(mesh: Mesh, axis: str, topology: Topology | None):
    """The KV rotation rings as (axes-tuple, size) pairs, outermost first.

    Flat (``topology=None``): one ring over ``axis``.  With a Topology,
    one ring per level (each level's axes must exist in ``mesh``) — the
    sequence axis becomes the outer-major flattening of all of them.
    """
    if topology is None:
        return [((axis,), mesh.shape[axis])]
    return mesh_levels(topology, mesh.shape)


def ring_attention(q, k, v, mesh: Mesh, *, axis: str = "data",
                   topology: Topology | None = None,
                   causal: bool = True, window: int | None = None,
                   schedule: str = "seq"):
    """q (B,S,H,D), k/v (B,S,Hkv,D) globally; S sharded over the ring.

    Communicates across: the single ``axis`` ring (flat), or every level of
    ``topology`` — the innermost (lane) ring on almost every step, each
    outer (cluster / pod) ring once per inner cycle.  Returns (B,S,H,D)
    with the same sharding.  One ppermute per step — the KV blocks ride the
    ring while online-softmax state stays local.  ``schedule="db"`` issues
    each step's ppermute before the previous block's attention compute
    (bit-identical result; the transfer overlaps the math on backends with
    async collectives)."""
    if schedule not in ("seq", "db"):
        raise ValueError(f"schedule must be 'seq' or 'db', got {schedule!r}")
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=2)
        v = jnp.repeat(v, H // Hkv, axis=2)
    levels = _ring_levels(mesh, axis, topology)       # outermost first
    sizes = [s for _, s in levels]
    n = math.prod(sizes)
    S_loc = S // n
    scale = 1.0 / math.sqrt(D)
    # flattened-ring stride of one step of each level (outer-major layout)
    strides = []
    acc = 1
    for s in reversed(sizes):
        strides.append(acc)
        acc *= s
    strides = list(reversed(strides))

    def body(q_loc, k_loc, v_loc):
        coords = [substrate.axis_index(axes) for axes, _ in levels]
        pos = sum(c * st for c, st in zip(coords, strides))
        q_pos = pos * S_loc + jnp.arange(S_loc)
        qf = q_loc.astype(jnp.float32)
        m = jnp.full((B, H, S_loc, 1), -jnp.inf, jnp.float32)
        l = jnp.zeros((B, H, S_loc, 1), jnp.float32)
        o = jnp.zeros((B, H, S_loc, D), jnp.float32)
        kc, vc = k_loc.astype(jnp.float32), v_loc.astype(jnp.float32)
        offsets = [0] * len(levels)                   # KV rotation odometer

        def rotate(kc, vc, i):
            axes, size = levels[i]
            return (ppermute_shift(kc, axes, 1, size),
                    ppermute_shift(vc, axes, 1, size))

        def advance(kc, vc):                          # one odometer tick
            i = len(levels) - 1
            while offsets[i] == sizes[i] - 1:         # complete inner cycle
                kc, vc = rotate(kc, vc, i)
                offsets[i] = 0
                i -= 1
            kc, vc = rotate(kc, vc, i)                # one hop on ring i
            offsets[i] += 1
            return kc, vc

        for step in range(n):
            src = sum(((c + off) % s) * st for c, off, s, st in
                      zip(coords, offsets, sizes, strides))
            k_pos = src * S_loc + jnp.arange(S_loc)
            if schedule == "db" and step < n - 1:
                # double-buffer: issue the hop(s) fetching block step+1 now;
                # they depend only on kc/vc, not on this block's compute, so
                # the transfer can ride the wires under the attention math
                kn, vn = advance(kc, vc)
            mb, lb, ob = _block_attn(qf, kc, vc, q_pos, k_pos, scale,
                                     causal, window)
            m_new = jnp.maximum(m, mb)
            alpha = jnp.exp(jnp.where(jnp.isfinite(m), m - m_new, -jnp.inf))
            beta = jnp.exp(jnp.where(jnp.isfinite(mb), mb - m_new, -jnp.inf))
            l = l * alpha + lb * beta
            o = o * alpha + ob * beta
            m = m_new
            if step < n - 1:
                kc, vc = (kn, vn) if schedule == "db" else advance(kc, vc)
        safe = jnp.where(l == 0.0, 1.0, l)
        out = (o / safe).transpose(0, 2, 1, 3)        # (B,S_loc,H,D)
        return out.astype(q_loc.dtype)

    seq_axes = tuple(a for axes, _ in levels for a in axes)
    spec_q = P(None, seq_axes if len(seq_axes) > 1 else seq_axes[0],
               None, None)
    return substrate.shard_map(body, mesh=mesh,
                               in_specs=(spec_q, spec_q, spec_q),
                               out_specs=spec_q)(q, k, v)
