from .sharding import (ShardingRules, default_rules, logical_to_spec,
                       constraint, param_shardings, abstract_params,
                       init_params, PV)

__all__ = ["ShardingRules", "default_rules", "logical_to_spec", "constraint",
           "param_shardings", "abstract_params", "init_params", "PV"]
