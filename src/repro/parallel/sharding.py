"""Logical-axis sharding (the REQI/GLSU discipline applied to an LM).

Every parameter is declared once as a :class:`PV` (shape, dtype, logical axis
names, init law); everything else — random init, ShapeDtypeStructs for the
dry-run, NamedShardings, checkpoint manifests — derives from that single
definition.

Logical axes (mapped by :class:`ShardingRules`):

    batch   activation batch            -> (pod, data)   ["clusters"]
    seq     sequence (SP cells only)    -> (pod, data)
    fsdp    parameter FSDP shard dim    -> (pod, data)   [ZeRO-3]
    model   TP dim (heads/ff/experts/vocab) -> model     ["lanes"]
    layers / none                        -> unsharded

AraXL reading (one mesh axis per :class:`repro.topology.Topology` level):
the `model` axis is the intra-cluster lane group (fast, fine-grained TP
collectives), `data` the cluster ring, `pod` the outermost ring (gradient /
FSDP traffic rides ring-friendly reduce-scatter/all-gather).  A rule value
may be a *tuple* of mesh axes — that is how the hierarchical MoE maps its
logical `model` axis over every topology level at once
(`repro.models.layers._moe_ep_a2a`).

Nothing in this module communicates: every function here only derives
PartitionSpecs/NamedShardings from the rule table; the collectives they
imply are issued by the layers that consume them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class PV:
    """Parameter definition: one source of truth."""
    shape: tuple
    dtype: Any = jnp.float32
    logical: tuple = ()          # one name per dim ('' / None = replicated)
    init: str = "normal"         # normal | zeros | ones | scaled
    scale: float | None = None   # stddev override


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    mesh: Mesh | None = None
    rules: dict | None = None

    def axis(self, name: str | None):
        if not name or self.rules is None:
            return None
        return self.rules.get(name)

    def spec(self, logical: Sequence[str | None]) -> P:
        if self.mesh is None:
            return P()
        phys = []
        used = set()
        for name in logical:
            ax = self.axis(name)
            # never map one mesh axis twice in a single spec
            flat = tuple(a for a in (ax if isinstance(ax, tuple) else (ax,))
                         if a) if ax else ()
            flat = tuple(a for a in flat if a not in used and
                         a in self.mesh.shape)
            used.update(flat)
            if not flat:
                phys.append(None)
            elif len(flat) == 1:
                phys.append(flat[0])
            else:
                phys.append(flat)
        return P(*phys)

    def sharding(self, logical) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical))


def default_rules(mesh: Mesh | None, *, seq_sharded: bool = False,
                  fsdp: bool = True, kv_heads: int | None = None,
                  cache_seq: str | None = None, act_seq: bool = False,
                  batch: int | None = None) -> ShardingRules:
    """Build the logical->physical map for one (config, shape) cell.

    kv_heads: shard the kv-head dim over `model` only when divisible
              (glm4's kv=2 stays replicated).
    cache_seq: "model" for decode cells (KV seq TP + distributed-softmax
               merge — the inter-cluster log-tree reduce), None otherwise.
    batch: global batch; batch dim is sharded only when divisible by |dp|.
    """
    if mesh is None:
        return ShardingRules(None, None)
    names = set(mesh.axis_names)
    dp = tuple(a for a in ("pod", "data") if a in names) or None
    dp_size = 1
    if dp:
        dp_size = 1
        for a in dp:
            dp_size *= mesh.shape[a]
    msize = mesh.shape.get("model", 1)
    rules = {
        "batch": dp if (batch is None or batch % max(1, dp_size) == 0) else None,
        "seq": dp if seq_sharded else None,
        "fsdp": dp if fsdp else None,
        "model": "model" if "model" in names else None,
        "kv": ("model" if ("model" in names and kv_heads
                           and kv_heads % msize == 0) else None),
        "cache_seq": cache_seq,
        # Megatron-SP: the residual stream between layers is sequence-sharded
        # over `model` — 16x smaller layer-boundary activations (decisive for
        # the 94-layer / 72-layer giants), same wire cost as the TP ARs it
        # replaces (AR = RS + AG).
        "act_seq": "model" if (act_seq and "model" in names) else None,
        # intra-machine vector-register axes (AraXL core library)
        "cluster": "cluster" if "cluster" in names else None,
        "lane": "lane" if "lane" in names else None,
    }
    return ShardingRules(mesh, rules)


def logical_to_spec(rules: ShardingRules, logical) -> P:
    return rules.spec(logical)


def constraint(x: jax.Array, rules: ShardingRules, *logical) -> jax.Array:
    """with_sharding_constraint by logical axis names (no-op off-mesh)."""
    if rules.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, rules.spec(logical)))


# ---------------------------------------------------------------------------
# Param-tree derivations
# ---------------------------------------------------------------------------

def _is_pv(x):
    return isinstance(x, PV)


def abstract_params(defs) -> Any:
    return jax.tree.map(
        lambda pv: jax.ShapeDtypeStruct(pv.shape, pv.dtype), defs,
        is_leaf=_is_pv)


def param_shardings(defs, rules: ShardingRules):
    if rules.mesh is None:
        return jax.tree.map(lambda pv: None, defs, is_leaf=_is_pv)
    return jax.tree.map(
        lambda pv: NamedSharding(rules.mesh, rules.spec(pv.logical)),
        defs, is_leaf=_is_pv)


def _init_one(pv: PV, key) -> jax.Array:
    if pv.init == "zeros":
        return jnp.zeros(pv.shape, pv.dtype)
    if pv.init == "ones":
        return jnp.ones(pv.shape, pv.dtype)
    fan_in = pv.shape[-2] if len(pv.shape) >= 2 else pv.shape[-1]
    std = pv.scale if pv.scale is not None else 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, pv.shape, jnp.float32) * std).astype(pv.dtype)


def init_params(defs, key) -> Any:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_pv)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(pv, k) for pv, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)
