"""AdamW with dtype-configurable state + fp32 master weights, built in-house.

State dtypes matter at AraXL scale: a 398B-parameter hybrid on one pod is
HBM-bound on optimizer state, so m/v can be kept in bf16 (stochastic-rounding
-free, documented accuracy trade) while the master copy stays fp32.  All
states inherit the parameter's sharding (ZeRO-3-equivalent: the same 2-D
(fsdp, model) layout).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.sharding import PV


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    state_dtype: Any = jnp.float32      # m, v
    master_fp32: bool = True            # keep fp32 master when params are low-p
    math_dtype: Any = jnp.float32       # update arithmetic; bf16 for the
    #                                     HBM-bound giants (XLA hoists f32
    #                                     grad converts to whole-leaf buffers)


def lr_schedule(cfg: OptConfig, step):
    """Linear warmup -> cosine decay to 10%."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.1 + 0.45 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def opt_state_defs(param_defs, cfg: OptConfig):
    """PV tree for the optimizer state (same logical axes as the params)."""
    def per_param(pv: PV):
        out = {"m": PV(pv.shape, cfg.state_dtype, pv.logical, "zeros"),
               "v": PV(pv.shape, cfg.state_dtype, pv.logical, "zeros")}
        if cfg.master_fp32 and pv.dtype != jnp.float32:
            out["master"] = PV(pv.shape, jnp.float32, pv.logical, "zeros")
        return out

    tree = jax.tree.map(per_param, param_defs,
                        is_leaf=lambda x: isinstance(x, PV))
    return {"step": PV((), jnp.int32, (), "zeros"), "params": tree}


def adamw_init(params, cfg: OptConfig):
    def per_param(p):
        out = {"m": jnp.zeros(p.shape, cfg.state_dtype),
               "v": jnp.zeros(p.shape, cfg.state_dtype)}
        if cfg.master_fp32 and p.dtype != jnp.float32:
            out["master"] = p.astype(jnp.float32)
        return out

    return {"step": jnp.zeros((), jnp.int32),
            "params": jax.tree.map(per_param, params)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) \
        if cfg.clip_norm else jnp.float32(1.0)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = lr.astype(jnp.float32)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_s = treedef.flatten_up_to(state["params"])

    mdt = cfg.math_dtype

    def upd_leaf(p, g, s, decay):
        gf = g.astype(mdt) * scale.astype(mdt)
        m = s["m"].astype(mdt) * jnp.asarray(cfg.b1, mdt) \
            + gf * jnp.asarray(1 - cfg.b1, mdt)
        v = s["v"].astype(mdt) * jnp.asarray(cfg.b2, mdt) \
            + gf * gf * jnp.asarray(1 - cfg.b2, mdt)
        upd = (m / b1c.astype(mdt)) / (jnp.sqrt(v / b2c.astype(mdt))
                                       + jnp.asarray(cfg.eps, mdt))
        master = s.get("master", p).astype(mdt)
        master = master - lr.astype(mdt) * (upd + jnp.asarray(decay, mdt)
                                            * master)
        ns = {"m": m.astype(cfg.state_dtype), "v": v.astype(cfg.state_dtype)}
        if "master" in s:
            ns["master"] = master.astype(jnp.float32)
        return master.astype(p.dtype), ns

    def upd_stacked(p, g, s, decay):
        """Layer-stacked leaf (e.g. 94 x 128-expert FFNs): update one layer
        slice at a time inside a fori_loop whose carry aliases the donated
        buffers — f32 temporaries are 1/L of the leaf, not GiBs live."""
        has_master = "master" in s
        L = p.shape[0]

        def body(i, carry):
            pc, mc, vc, mac = carry
            sl = {"m": jax.lax.dynamic_index_in_dim(mc, i, keepdims=False),
                  "v": jax.lax.dynamic_index_in_dim(vc, i, keepdims=False)}
            if has_master:
                sl["master"] = jax.lax.dynamic_index_in_dim(
                    mac, i, keepdims=False)
            np_, ns = upd_leaf(
                jax.lax.dynamic_index_in_dim(pc, i, keepdims=False),
                jax.lax.dynamic_index_in_dim(g, i, keepdims=False),
                sl, decay)
            pc = jax.lax.dynamic_update_index_in_dim(pc, np_, i, 0)
            mc = jax.lax.dynamic_update_index_in_dim(mc, ns["m"], i, 0)
            vc = jax.lax.dynamic_update_index_in_dim(vc, ns["v"], i, 0)
            if has_master:
                mac = jax.lax.dynamic_update_index_in_dim(
                    mac, ns["master"], i, 0)
            return pc, mc, vc, mac

        init = (p, s["m"], s["v"], s["master"] if has_master else p)
        pc, mc, vc, mac = jax.lax.fori_loop(0, L, body, init)
        ns = {"m": mc, "v": vc}
        if has_master:
            ns["master"] = mac
        return pc, ns

    new_p, new_s = [], []
    for p, g, s in zip(flat_p, flat_g, flat_s):
        decay = cfg.weight_decay if p.ndim >= 2 else 0.0
        if p.ndim >= 3 and p.shape[0] >= 8:
            np_, ns = upd_stacked(p, g, s, decay)
        else:
            np_, ns = upd_leaf(p, g, s, decay)
        new_p.append(np_)
        new_s.append(ns)

    return (jax.tree.unflatten(treedef, new_p),
            {"step": step, "params": jax.tree.unflatten(treedef, new_s)},
            {"lr": lr, "grad_norm": gnorm})
