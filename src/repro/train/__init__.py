from .optimizer import OptConfig, adamw_init, adamw_update, lr_schedule
from .trainer import (TrainState, init_train_state, make_grad_sync,
                      make_train_step, train_state_defs)

__all__ = ["OptConfig", "adamw_init", "adamw_update", "lr_schedule",
           "TrainState", "init_train_state", "make_grad_sync",
           "make_train_step", "train_state_defs"]
