from .optimizer import OptConfig, adamw_init, adamw_update, lr_schedule
from .trainer import (TrainState, make_grad_sync, make_train_step,
                      train_state_defs)

__all__ = ["OptConfig", "adamw_init", "adamw_update", "lr_schedule",
           "TrainState", "make_grad_sync", "make_train_step",
           "train_state_defs"]
