from .optimizer import OptConfig, adamw_init, adamw_update, lr_schedule
from .trainer import (TrainState, abstract_train_state, init_train_state,
                      make_grad_sync, make_train_step, train_state_defs,
                      train_state_shardings)

__all__ = ["OptConfig", "adamw_init", "adamw_update", "lr_schedule",
           "TrainState", "abstract_train_state", "init_train_state",
           "make_grad_sync", "make_train_step", "train_state_defs",
           "train_state_shardings"]
