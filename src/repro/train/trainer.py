"""Train step assembly: microbatched grad accumulation + AdamW + metrics."""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.parallel.sharding import (ShardingRules, abstract_params,
                                     param_shardings)
from .optimizer import OptConfig, adamw_init, adamw_update, opt_state_defs


class TrainState(NamedTuple):
    params: Any
    opt: Any


def train_state_defs(cfg: ModelConfig, opt_cfg: OptConfig):
    pdefs = lm.model_defs(cfg)
    return pdefs, opt_state_defs(pdefs, opt_cfg)


def abstract_train_state(cfg: ModelConfig, opt_cfg: OptConfig) -> TrainState:
    """ShapeDtypeStruct skeleton of the full train state — the
    ``tree_like`` a checkpoint restore targets without materialising a
    single parameter (the restart path re-creates multi-GiB states straight
    onto the new mesh)."""
    pdefs, odefs = train_state_defs(cfg, opt_cfg)
    return TrainState(abstract_params(pdefs), abstract_params(odefs))


def train_state_shardings(cfg: ModelConfig, opt_cfg: OptConfig,
                          rules: ShardingRules) -> TrainState:
    """NamedSharding tree for the full train state under ``rules``.

    Because optimizer-state PVs inherit each parameter's logical axes
    (``opt_state_defs``), this is a pure function of (config, rules) — the
    elastic-restore path calls it with rules re-derived on the *survivor*
    mesh (``ft.rescale_rules``) and hands the result to
    ``restore_checkpoint(shardings=...)``: cross-mesh restore without any
    checkpoint-format migration."""
    pdefs, odefs = train_state_defs(cfg, opt_cfg)
    return TrainState(param_shardings(pdefs, rules),
                      param_shardings(odefs, rules))


def make_grad_sync(cfg: ModelConfig, rules: ShardingRules,
                   bucket_mb: float | None = None):
    """Hierarchical gradient-sync hook for ``make_train_step(grad_sync=)``.

    Pins each accumulated gradient to its parameter's sharding under
    ``rules`` *before* the optimizer step.  With pod-local FSDP rules
    (``fsdp`` mapped over the inner topology levels only, params replicated
    across pods), this materialises the reduce-scatter on the inner rings
    first; the cross-pod all-reduce XLA then inserts for the replicated
    params only ever carries the 1/|inner|-sized shard — the launch-layer
    analogue of ``core.ring.ring_reduce_scatter_local_hier`` (lane ring
    first, pod ring last), expressed as sharding rules + a hook instead of
    monkey-patching.

    ``bucket_mb`` selects the *bucketed, backward-overlapped* variant
    (``fsdp_hier_ov`` in ``launch.perf``): gradients are grouped — in
    reverse parameter order, the order backprop produces them — into
    buckets of at most ``bucket_mb`` MiB, and each bucket is pinned and
    fenced with ``jax.lax.optimization_barrier``.  The fences stop XLA
    from coalescing every gradient into one monolithic end-of-step sync,
    so each bucket's inner-ring reduce-scatter is free to start as soon as
    its gradients exist and ride the wires under the remaining backward
    compute; the pod-ring exchange still happens last, at the optimizer's
    replicated reads.  Barriers and sharding constraints are identity
    functions, so the result is grad-equivalent to the unbucketed hook.
    """
    shardings = param_shardings(lm.model_defs(cfg), rules)

    if bucket_mb is None:
        def sync(grads):
            return jax.tree.map(
                lambda g, s: g if s is None
                else jax.lax.with_sharding_constraint(g, s),
                grads, shardings)

        return sync

    bucket_bytes = int(bucket_mb * 2**20)

    def sync(grads):
        leaves, treedef = jax.tree.flatten(grads)
        # keep None leaves (mesh-less rules: nothing to pin, buckets still
        # fence) — a bare flatten would drop them and misalign the zip
        shs = jax.tree.flatten(shardings,
                               is_leaf=lambda x: x is None)[0]
        assert len(leaves) == len(shs), (len(leaves), len(shs))
        out = list(leaves)
        bucket: list[int] = []
        size = 0

        def flush():
            if not bucket:
                return
            pinned = tuple(
                out[i] if shs[i] is None
                else jax.lax.with_sharding_constraint(out[i], shs[i])
                for i in bucket)
            fenced = jax.lax.optimization_barrier(pinned)
            for i, g in zip(bucket, fenced):
                out[i] = g
            bucket.clear()

        # reverse parameter order: the tail of the model backprops first,
        # so its bucket's reduce-scatter can launch while earlier layers'
        # gradients are still being computed
        for i in reversed(range(len(leaves))):
            bucket.append(i)
            size += leaves[i].size * leaves[i].dtype.itemsize
            if size >= bucket_bytes:
                flush()
                size = 0
        flush()
        return jax.tree.unflatten(treedef, out)

    return sync


def make_train_step(cfg: ModelConfig, rules: ShardingRules,
                    opt_cfg: OptConfig, n_microbatches: int = 1,
                    acc_dtype=jnp.float32, grad_sync=None):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: {"tokens": (B, S) int32, optional "ctx": (B, T, d_ctx)}.
    Microbatches split the batch dim and accumulate grads (``acc_dtype``;
    bf16 for the HBM-bound giants) in a sequential lax.scan — the standard
    memory/compute trade at pod scale.

    ``grad_sync`` (grads -> grads), when given, runs on the accumulated
    gradients before the optimizer update — the hierarchical-sync hook
    (:func:`make_grad_sync`) stages the gradient reduce-scatter level by
    level there instead of leaving the whole sync to XLA's default placement.
    """

    def loss_fn(params, tokens, ctx):
        return lm.forward_train(params, tokens, cfg, rules, ctx)

    def train_step(state: TrainState, batch):
        tokens = batch["tokens"]
        ctx = batch.get("ctx")
        B = tokens.shape[0]
        if n_microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, tokens,
                                                      ctx)
        else:
            assert B % n_microbatches == 0
            mb = B // n_microbatches
            tok_mb = tokens.reshape(n_microbatches, mb, -1)
            ctx_mb = (ctx.reshape(n_microbatches, mb, *ctx.shape[1:])
                      if ctx is not None else None)

            def acc_fn(carry, xs):
                acc, loss_sum = carry
                t = xs[0]
                c = xs[1] if ctx is not None else None
                l, g = jax.value_and_grad(loss_fn)(state.params, t, c)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(acc_dtype), acc, g)
                return (acc, loss_sum + l), None

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), state.params)
            xs = (tok_mb, ctx_mb) if ctx is not None else (tok_mb,)
            (gacc, lsum), _ = jax.lax.scan(acc_fn, (zero, 0.0), xs)
            grads = jax.tree.map(lambda g: g / n_microbatches, gacc)
            loss = lsum / n_microbatches

        if grad_sync is not None:
            grads = grad_sync(grads)
        params, opt, metrics = adamw_update(state.params, grads, state.opt,
                                            opt_cfg)
        metrics["loss"] = loss
        return TrainState(params, opt), metrics

    return train_step


def init_train_state(cfg: ModelConfig, opt_cfg: OptConfig, key) -> TrainState:
    from repro.parallel.sharding import init_params
    params = init_params(lm.model_defs(cfg), key)
    return TrainState(params, adamw_init(params, opt_cfg))
