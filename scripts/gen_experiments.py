"""Generate EXPERIMENTS.md §Dry-run / §Roofline / §Perf tables from results/."""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]


def load(d):
    out = {}
    p = ROOT / d
    if not p.exists():
        return out
    for f in sorted(p.glob("*.json")):
        out[f.stem] = json.loads(f.read_text())
    return out


def fmt_cell(rec):
    r = rec["roofline"]
    m = rec["mem_per_device"]
    return (f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | "
            f"{rec.get('n_microbatches', 1)} | "
            f"{m['resident_model_gib']:.1f} ({m['total_gib']:.1f}) | "
            f"{'Y' if rec['fits_16gib_hbm'] else 'N'} | "
            f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | {r['bottleneck'].replace('_s','')} | "
            f"{rec['model_vs_hlo_flops']:.2f} | "
            f"{r['mfu_upper_bound']*100:.1f}% |")


HEADER = ("| arch | shape | mesh | nm | resident GiB (cpu-arena) | fits "
          "| compute s | memory s | collective s | bound | 6ND/HLO "
          "| roofline frac |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|---|")


def main():
    dry = load("results/dryrun")
    perf = load("results/perf")

    lines = []
    lines.append("## §Dry-run + §Roofline — baseline table (single pod "
                 "16x16 = 256 chips)\n")
    lines.append(HEADER)
    skips = []
    multi_ok = []
    for k, rec in dry.items():
        if "skipped" in rec:
            skips.append(f"* `{rec['arch']} x {rec['shape']}` — "
                         f"{rec['skipped']}")
            continue
        if rec["mesh"] == "pod16x16":
            lines.append(fmt_cell(rec))
        else:
            multi_ok.append(rec)
    lines.append("\n### Multi-pod (2x16x16 = 512 chips) compile results\n")
    lines.append(HEADER)
    for rec in multi_ok:
        lines.append(fmt_cell(rec))
    lines.append("\n### Noted skips (DESIGN.md §Arch-applicability)\n")
    lines.extend(sorted(set(skips)))

    lines.append("\n\n## §Perf — hillclimb records\n")
    lines.append("| cell | strategy | compute s | memory s | collective s "
                 "| bound | roofline frac | resident GiB |")
    lines.append("|---|---|---|---|---|---|---|---|")
    for k, rec in perf.items():
        r = rec["roofline"]
        lines.append(
            f"| {rec['arch']} x {rec['shape']} | {rec.get('strategy','?')} | "
            f"{r['compute_s']:.3f} | {r['memory_s']:.3f} | "
            f"{r['collective_s']:.3f} | {r['bottleneck'].replace('_s','')} | "
            f"{r['mfu_upper_bound']*100:.1f}% | "
            f"{rec['mem_per_device']['resident_model_gib']:.1f} |")

    print("\n".join(lines))


if __name__ == "__main__":
    main()
