#!/usr/bin/env bash
# Tier-1 CI entry point (offline; no pip installs — missing extras like
# `hypothesis` are shimmed by tests/conftest.py).
#
# The main pytest process runs with 8 fake CPU devices; the multi-device
# correctness checks additionally spawn their own 8-device subprocesses.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

# Static analysis (fails the build on any finding): the AST lint runs
# everywhere; the semantic front (collective pricing coverage, ring
# schedules, VRF budgets) traces the public entry points on the 8 fake CPU
# devices exported above.  The bench validator pins every BENCH_sim.json
# section schema in one place.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis.bench
echo "analysis OK (L1-L4 lint, S1-S3 semantic, bench schemas)"

# Tier-1 pytest (includes tests/test_docs.py, which executes every fenced
# python block in docs/*.md in an 8-fake-device subprocess — the docs are
# part of the contract, not prose).
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# Fast sim-only benchmark smoke: the analytical model (fig7 latency
# tolerance + tab2 area) must run end-to-end, so cost-model regressions
# fail tier-1 instead of waiting for eyeballs on the full benchmark run.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run fig7 tab2 --no-json > /dev/null
echo "sim benchmark smoke OK (fig7 tab2)"

# Launch-strategy smoke: the hierarchical gradient-sync paths (sharding
# rules + grad-sync hook, plain and bucketed/backward-overlapped) must
# lower and compile, with per-level collective pricing — and the
# overlap-aware exposed seconds — in the record: 8 fake devices, smallest
# (smoke) arch, 2x2x2 three-level topology.  Exits non-zero on any
# strategy failure.
PERF_OUT="$(mktemp -d)"
AT_CACHE="$(mktemp -d)"
trap 'rm -rf "$PERF_OUT" "$AT_CACHE"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.perf \
    --smoke --arch llama3-8b --shape train_4k --topology 2x2x2 \
    --strategy baseline --strategy fsdp_hier_ov --out "$PERF_OUT" > /dev/null
echo "launch perf smoke OK (baseline fsdp_hier_ov @ 2x2x2)"

# Overlap smoke: one double-buffered ring-attention step (flat + the
# 2x2x2 odometer) must run and match the sequential schedule bit for bit.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.testing.check_overlap attn > /dev/null
echo "overlap smoke OK (double-buffered ring attention @ 2x2x2)"

# Autotune smoke: the enumerate → model-rank → measure-shortlist → cache
# loop must run end-to-end for every kernel (tiny shapes, interpret-mode
# Pallas, top-2 shortlist) against a throwaway cache so the committed
# results/autotune table is never touched by CI.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -c \
    'import sys; from repro.kernels.autotune import main; sys.exit(main(sys.argv[1:]))' \
    --smoke --top-k 2 --reps 3 --cache "$AT_CACHE/cache.json" > /dev/null
echo "autotune smoke OK (all kernels, top-2 shortlist, throwaway cache)"

# Serve smoke: the open-loop traffic generator must drive both the dense
# and the paged (block-table KV) engines end-to-end at equal KV memory —
# Poisson arrivals, Zipf prompt pool, 8 fake devices.  Tiny request count
# keeps it ~30s; the recorded three-arm ablation (BENCH_serve.json) is
# `python -m benchmarks.run serve` and is never touched by CI.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.serve.traffic \
    --configs dense,paged --requests 6 --max-new 6 --pool 3 \
    --max-seq 64 --rate 50 > /dev/null
echo "serve smoke OK (open-loop dense+paged @ equal KV memory)"

# Chaos smoke: the elastic-training acceptance check.  Two runs of
# launch.train's chaos loop on the 8 fake devices (2 hosts x 4): a clean
# reference, and one with an injected host kill, a torn checkpoint, and a
# transient straggler.  Asserts heartbeat-timeout detection, an 8 -> 4
# device rescale (model axis intact), restore from the pre-torn durable
# checkpoint, bit-identical (seed, step) batch replay, and loss
# continuity within fp tolerance — see docs/RESILIENCE.md.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    python -m repro.testing.check_chaos --steps 12 > /dev/null
echo "chaos smoke OK (kill + torn ckpt + straggle; 8->4 rescale, bit-exact replay)"

# Multi-process chaos smoke: the same elastic story with every fault made
# real — N worker processes, socket heartbeats, SIGKILL at a fence, a
# writer killed mid-checkpoint-write (the crash-atomic save must leave a
# detectably torn step), detection on real heartbeat deadlines, and a
# deterministic seeded replay.  Hard wall-clock bound: the full check
# takes ~2.5 min (7 worker epochs); timeout at 7 min so a hung worker or
# a lost heartbeat fails CI instead of wedging it.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} \
    timeout 420 python -m repro.testing.check_chaos_procs > /dev/null
echo "procs chaos smoke OK (real SIGKILL x3, socket-deadline detection, mid-write kill)"
