#!/usr/bin/env bash
# Tier-1 CI entry point (offline; no pip installs — missing extras like
# `hypothesis` are shimmed by tests/conftest.py).
#
# The main pytest process runs with 8 fake CPU devices; the multi-device
# correctness checks additionally spawn their own 8-device subprocesses.
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}"

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
