"""Recompute cost terms (unrolled p1/p2) for existing dry-run JSONs."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS","")
import json, pathlib, sys, time
sys.path.insert(0, "src")
from repro.configs import SHAPES, get_config
from repro.launch import dryrun as dr
from repro.launch.mesh import make_production_mesh, production_topology
from repro.roofline.analysis import (HW, collective_bytes, extrapolate,
                                     memory_model_bytes, parse_collectives,
                                     roofline_terms)

kinds = set(sys.argv[1:]) or {"prefill"}
mesh = make_production_mesh()
topo = production_topology()
outdir = pathlib.Path("results/dryrun")
for f in sorted(outdir.glob("*pod16x16.json")):
    rec = json.loads(f.read_text())
    if "skipped" in rec or rec["kind"] not in kinds:
        continue
    cfg = get_config(rec["arch"]); shape = SHAPES[rec["shape"]]
    nm = rec["n_microbatches"]; n_dev = rec["devices"]
    t0 = time.time()
    costs = {}
    cshape = dr._cost_shape(shape, nm)
    for n in (1, 2):
        lo, co = dr.lower_cell(dr._variant(cfg, n), cshape, mesh, n_micro=1)
        ca = co.cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jax: one dict per device
            ca = ca[0]
        colls = parse_collectives(co.as_text())
        costs[n] = {"flops": float(ca.get("flops", 0.0)),
                    "bytes": float(ca.get("bytes accessed", 0.0)),
                    "wire": collective_bytes(colls)}
        del co, lo
    L = cfg.n_periods
    flops = nm * extrapolate(costs[1]["flops"], costs[2]["flops"], L)
    bytes_ = nm * extrapolate(costs[1]["bytes"], costs[2]["bytes"], L)
    wire = nm * extrapolate(costs[1]["wire"]["total"], costs[2]["wire"]["total"], L)
    rec["per_device"] = {"flops": flops, "bytes": bytes_, "wire_bytes": wire}
    rec["roofline"] = roofline_terms(flops, bytes_, wire)
    mm = memory_model_bytes(cfg, shape, n_dev, nm, topology=topo)
    rec["roofline"]["memory_s_hlo_upper"] = rec["roofline"]["memory_s"]
    rec["roofline"]["memory_s"] = mm / HW["hbm_bw"]
    terms = {k: rec["roofline"][k] for k in ("compute_s","memory_s","collective_s")}
    rec["roofline"]["bottleneck"] = max(terms, key=terms.get)
    rec["roofline"]["step_s_lower_bound"] = max(terms.values())
    mf = rec["model_flops_global"]
    rec["model_vs_hlo_flops"] = mf / (flops*n_dev) if flops else 0.0
    rec["roofline"]["mfu_upper_bound"] = (mf/n_dev/HW["peak_flops"]
        / rec["roofline"]["step_s_lower_bound"]) if rec["roofline"]["step_s_lower_bound"] else 0.0
    rec["recost_unrolled"] = True
    f.write_text(json.dumps(rec, indent=2))
    r = rec["roofline"]
    print(f"[recost] {f.stem}: c={r['compute_s']:.3f} m={r['memory_s']:.3f} "
          f"w={r['collective_s']:.3f} bound={r['bottleneck']} ({time.time()-t0:.0f}s)", flush=True)
