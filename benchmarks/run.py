"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and (for the sim sections)
merges the derived numbers into machine-readable ``BENCH_sim.json`` at the
repo root, so the perf trajectory is trackable across PRs.

All sim sections price the interconnect through the shared
:class:`repro.topology.Topology` (``AraXLParams.topology`` — the same value
``repro.core.machine.make_machine`` emulates).  ``--hierarchy`` selects which
interconnect the fig6 weak-scaling curves use; fig6 always also reports the
flat-vs-two-level ablation and the C x L factorisation sweep at 64 lanes
(16x4 / 8x8 / 4x16 ...), reproducing the paper's §III-B.4 claim that the
hierarchy — not the flattened ring — is what scales.

Sections:

  fig6   performance scalability (weak scaling, normalized to 8-lane Ara2)
         + flat-vs-two-level ablation + 64-lane C x L factorisation sweep
         + 64-lane three-level pod x cluster x lane sweep (2x8x4, 4x4x4, ...)
         + 64-lane sequential-vs-overlap (double-buffered machine) ablation
           with the exposed-vs-hidden wire-cycle split
  fig7   interface latency tolerance (utilization drop per register cut)
  tab1   kernel peak-rate check (Table I max-perf model vs simulated)
  tab2   area model vs published kGE breakdown
  tab3   PPA (peak GFLOPs / energy / area efficiency)
  kern   Pallas kernels (interpret) vs jnp oracle wall time
  ring   AraXL core collectives correctness+wall time (8 fake devices)
  coll   flat vs two-level vs XLA-native collectives head-to-head
         (reduce / allgather / reduce-scatter / staged GLSU + the db
         double-buffered rings, 8 fake devices, both C·L factorizations —
         the §III-B.4 hierarchy ablation; median-of-k wall-clock recorded
         into BENCH_sim.json `coll`)
  ring_attn  measured sequential vs double-buffered ring attention
         (8 fake devices, flat + 2x2x2 odometer; BENCH_sim.json
         `ring_attention_8dev`)
  kernels  model-guided autotune calibration table: per problem signature,
         every legal block-shape candidate measured (interpret kernels) with
         the sim-model rank recorded next to the measured median+IQR —
         merged into BENCH_kernels.json (the sim-vs-kernels agreement
         artifact) and into the persistent results/autotune/ winner cache
  serve  open-loop serving ablation (dense vs paged vs paged+chunked KV at
         equal device memory, Poisson arrivals over a Zipf prompt pool on
         8 fake devices): p50/p99 TTFT, decode tok/s, slot occupancy, peak
         concurrency, resident KV bytes — merged into BENCH_serve.json
         (schema pinned by repro.analysis.bench.validate_serve_bench)
  roof   roofline summary per dry-run cell (requires results/dryrun/*.json)
  perf   launch-strategy comparison (baseline / fsdp_pure / fsdp_hier /
         fsdp_hier_ov): merges the per-level collective pricing and the
         overlap-aware exposed seconds of results/perf/*.json into
         BENCH_sim.json — the pod-ring gradient-sync ablation

Usage: PYTHONPATH=src python -m benchmarks.run [section ...]
           [--hierarchy flat|two-level|both] [--json PATH | --no-json]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

import numpy as np

from repro.testing.timing import now

KERNELS = ["fmatmul", "fconv2d", "jacobi2d", "fdotproduct", "exp", "softmax"]

#: machine-readable results of the sim sections, merged into BENCH_sim.json
BENCH: dict = {}

#: the autotuner's model-vs-measured rank table, merged into
#: BENCH_kernels.json (schema pinned by repro.analysis.bench)
BENCH_KERNELS: dict = {}

#: the open-loop serving ablation, merged into BENCH_serve.json
BENCH_SERVE: dict = {}


def _t(fn, *args, reps=3, **kw):
    fn(*args, **kw)
    t0 = now()
    for _ in range(reps):
        out = fn(*args, **kw)
    return (now() - t0) / reps * 1e6, out


def bench_fig6(hierarchies=("flat", "two-level")):
    from repro.sim import ara2_params, araxl_params, build_trace, simulate
    from repro.topology import factorizations
    base = {}
    for k in KERNELS:
        p8 = ara2_params(8)
        r8 = simulate(build_trace(k, p8, 512), p8)
        base[k] = r8.flop_per_cycle

    seen = {}                          # (params, kernel) -> scale

    def scale(k, p):
        # memo keyed by the full (frozen, hashable) params — a coarser key
        # once made every C x L grid row reuse the default 16x4 scale
        key = (p, k)
        if key not in seen:
            seen[key] = simulate(build_trace(k, p, 512),
                                 p).flop_per_cycle / base[k]
        return seen[key]

    fig6 = BENCH.setdefault("fig6", {})
    for h in hierarchies:
        curves = fig6.setdefault(h, {})
        for lanes in (8, 16, 32, 64):
            p = araxl_params(lanes, hierarchy=h)
            for k in KERNELS:
                us, res = _t(lambda: simulate(build_trace(k, p, 512), p))
                s = res.flop_per_cycle / base[k]
                seen[(p, k)] = s
                curves.setdefault(k, {})[str(lanes)] = round(s, 3)
                print(f"fig6/{k}/L{lanes}/{h},{us:.0f},"
                      f"scale={s:.2f}x util={res.utilization:.3f}")

    # Flat-vs-two-level ablation at the flagship 64 lanes (always reported):
    # the two-level interconnect must never scale worse than the flat ring.
    p2, pf = araxl_params(64), araxl_params(64, hierarchy="flat")
    BENCH["red_tree_lat_64"] = {"flat": pf.red_tree_lat(),
                                "two-level": p2.red_tree_lat()}
    print(f"fig6/red_tree/L64,0,flat={pf.red_tree_lat():.0f}cyc "
          f"two-level={p2.red_tree_lat():.0f}cyc")
    ablate = BENCH.setdefault("fig6_ablation_64", {})
    for k in KERNELS:
        sf, s2 = scale(k, pf), scale(k, p2)
        ablate[k] = {"flat": round(sf, 3), "two-level": round(s2, 3)}
        print(f"fig6/ablate/{k},0,flat={sf:.2f}x two-level={s2:.2f}x")

    # C x L factorisation sweep: 64 lanes as 16x4 / 8x8 / 4x16 / ... — how
    # the same silicon scales under different cluster groupings.
    grid = BENCH.setdefault("fig6_grid_64", {})
    for C, L in factorizations(64):
        p = araxl_params(64, lanes_per_cluster=L)
        tag = f"C{C}xL{L}"
        grid[tag] = {"red_tree_lat": p.red_tree_lat()}
        for k in ("softmax", "fdotproduct"):
            s = scale(k, p)
            grid[tag][k] = round(s, 3)
            print(f"fig6/grid/{k}/{tag},0,scale={s:.2f}x "
                  f"tree={p.red_tree_lat():.0f}cyc")

    # Three-level (pod x cluster x lane) sweep at the flagship 64 lanes:
    # the N-level Topology groups the clusters into pods (pod ring priced
    # at pod_hop > ring_hop); the paper's two-level 16x4 machine rides
    # along as the P1 reference row.  The hierarchy claim must recurse:
    # pod grouping shortens the cluster log-tree even though pod wires
    # are priced dearer.
    pods = BENCH.setdefault("fig6_pod_64", {})
    for P_, C_, L_ in ((1, 16, 4), (2, 8, 4), (4, 4, 4),
                       (2, 4, 8), (4, 2, 8)):
        p = araxl_params(64, lanes_per_cluster=L_, n_pods=P_)
        tag = f"P{P_}xC{C_}xL{L_}"
        assert p.topology.shape == ((P_, C_, L_) if P_ > 1 else (C_, L_))
        pods[tag] = {"red_tree_lat": p.red_tree_lat(),
                     "n_levels": p.topology.n_levels}
        for k in ("softmax", "fdotproduct"):
            s = scale(k, p)
            pods[tag][k] = round(s, 3)
            print(f"fig6/pod/{k}/{tag},0,scale={s:.2f}x "
                  f"tree={p.red_tree_lat():.0f}cyc")

    # Overlap ablation at the flagship 64 lanes: the double-buffered
    # machine (simulate(overlap=True) — wire-wait bubbles backfilled by
    # independent instructions) against the paper-calibrated sequential
    # engine, with the exposed-vs-hidden wire-cycle split of both.  The
    # reduction-bound kernels are the ones the overlap should move toward
    # the near-linear band; compute-bound kernels must not regress.
    p64 = araxl_params(64)
    ov = BENCH.setdefault("fig6_overlap_64", {})
    for k in KERNELS:
        r0 = simulate(build_trace(k, p64, 512), p64)
        r1 = simulate(build_trace(k, p64, 512), p64, overlap=True)
        s0 = r0.flop_per_cycle / base[k]
        s1 = r1.flop_per_cycle / base[k]
        ov[k] = {"baseline": round(s0, 3), "overlap": round(s1, 3),
                 "exposed_cycles": round(r0.wire_exposed_total, 1),
                 "exposed_cycles_overlap": round(r1.wire_exposed_total, 1),
                 "hidden_cycles_overlap": round(r1.wire_hidden_total, 1)}
        print(f"fig6/overlap/{k},0,base={s0:.2f}x overlap={s1:.2f}x "
              f"exposed={r0.wire_exposed_total:.0f}->"
              f"{r1.wire_exposed_total:.0f}cyc")


def bench_fig7():
    from repro.sim import araxl_params, build_trace, simulate
    cuts = [("glsu+4", dict(glsu=4)), ("reqi+1", dict(reqi=1)),
            ("ringi+1", dict(ringi=1))]
    p0 = araxl_params(64)
    fig7 = BENCH.setdefault("fig7", {})
    for name, kw in cuts:
        for k in KERNELS:
            p1 = p0.with_cuts(**kw)
            u0 = simulate(build_trace(k, p0, 512), p0).utilization
            u1 = simulate(build_trace(k, p1, 512), p1).utilization
            fig7.setdefault(name, {})[k] = round(100 * (u0 - u1), 3)
            print(f"fig7/{name}/{k},0,drop={100*(u0-u1):.2f}%")


def bench_tab1():
    from repro.sim import araxl_params, build_trace, simulate
    from repro.sim.kernels import max_perf_flop_per_cycle
    p = araxl_params(64)
    tab1 = BENCH.setdefault("tab1", {})
    for k in KERNELS:
        res = simulate(build_trace(k, p, 512), p)
        peak = max_perf_flop_per_cycle(k, 64)
        tab1[k] = {"flop_per_cycle": round(res.flop_per_cycle, 2),
                   "peak": peak}
        print(f"tab1/{k},0,fpc={res.flop_per_cycle:.1f}/"
              f"{peak:.1f} ({100*res.flop_per_cycle/peak:.0f}% of Table-I peak)")


def bench_tab2():
    from repro.sim import araxl_params
    from repro.sim import paper, ppa
    tab2 = BENCH.setdefault("tab2", {})
    for lanes in (16, 32, 64):
        got = ppa.area_breakdown_kge(araxl_params(lanes))
        want = paper.TABLE_II_KGE[lanes]
        err = 100 * (got["total"] - want["total"]) / want["total"]
        tab2[str(lanes)] = {"model_kge": round(got["total"], 1),
                            "paper_kge": want["total"],
                            "err_pct": round(err, 2)}
        print(f"tab2/area/L{lanes},0,model={got['total']:.0f}kGE "
              f"paper={want['total']}kGE err={err:+.1f}% "
              f"ifc={100*ppa.interface_area_fraction(araxl_params(lanes)):.1f}%")


def bench_tab3():
    from repro.sim import araxl_params, build_trace, simulate
    from repro.sim import paper, ppa
    tab3 = BENCH.setdefault("tab3", {})
    for lanes in (16, 32, 64):
        p = araxl_params(lanes)
        u = simulate(build_trace("fmatmul", p, 512), p).utilization
        perf = ppa.peak_gflops(p, u)
        eeff = ppa.energy_eff_gflops_per_w(p, u)
        aeff = ppa.area_eff_gflops_per_mm2(p, u)
        w = paper.TABLE_III[lanes]
        tab3[str(lanes)] = {"perf_gflops": round(perf, 2),
                            "energy_eff": round(eeff, 2),
                            "area_eff": round(aeff, 2),
                            "paper": list(w)}
        print(f"tab3/ppa/L{lanes},0,"
              f"perf={perf:.1f}GF(paper {w[1]}) "
              f"eeff={eeff:.1f}GF/W(paper {w[2]}) "
              f"aeff={aeff:.1f}GF/mm2(paper {w[3]})")


def bench_kernels():
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)

    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    us_p, _ = _t(lambda: ops.matmul(a, b, use_pallas=True).block_until_ready())
    us_r, _ = _t(lambda: ref.matmul(a, b).block_until_ready())
    print(f"kern/matmul_256(interpret),{us_p:.0f},ref={us_r:.0f}us")

    x = jnp.asarray(rng.normal(size=(32, 512)), jnp.float32)
    us_p, _ = _t(lambda: ops.softmax_rows(x, use_pallas=True)
                 .block_until_ready())
    us_r, _ = _t(lambda: ref.softmax_rows(x).block_until_ready())
    print(f"kern/softmax_rows(interpret),{us_p:.0f},ref={us_r:.0f}us")

    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    us_p, _ = _t(lambda: ops.attention(q, k, v, use_pallas=True, bq=64,
                                       bk=64).block_until_ready())
    us_r, _ = _t(lambda: ref.attention(q, k, v).block_until_ready())
    print(f"kern/flash_attn(interpret),{us_p:.0f},ref={us_r:.0f}us")


def bench_autotune():
    """The kernel autotuner's calibration table: for every case in
    ``repro.kernels.autotune.CASES``, measure *all* legal candidates
    (interpret-mode kernels off-TPU) so the model's predicted rank can be
    scored against the measured order, and persist the winners into the
    default results/autotune/ cache that `kernels.ops` resolves against."""
    from repro.kernels import autotune
    BENCH_KERNELS["schema"] = 1
    recs = BENCH_KERNELS.setdefault("records", {})
    with autotune.tuned(top_k=3, reps=5, warmup=1, min_block=64) as ctx:
        for kernel, shapes in autotune.CASES.items():
            for shape in shapes:
                rec = autotune.autotune(kernel, shape, ctx=ctx,
                                        measure_all=True)
                sig = autotune.signature(kernel, rec["shape"], rec["dtype"],
                                         ctx.topology_tag)
                recs[sig] = rec
                win = next(c for c in rec["candidates"]
                           if c.get("measured_rank") == 0)
                print(f"kernels/{sig},{win['measured_us']:.1f},"
                      f"winner={rec['winner']} "
                      f"model_rank={rec['model_rank_of_winner']} "
                      f"agree@{rec['top_k']}={rec['agreement_at_k']}")


def bench_ring():
    from repro.testing.subproc import run_check
    t0 = now()
    run_check("repro.testing.check_core", "2", "4", devices=8)
    us = (now() - t0) * 1e6
    print(f"ring/core_suite_8dev,{us:.0f},all-modes-allclose")


def bench_collectives():
    """XLA-native vs shard_map-ring head-to-head, both factorizations,
    recorded into BENCH_sim.json under ``coll`` (median-of-k timing from
    ``check_collectives``): coll[CxL][collective][variant] = median us.
    Variants cover flat / two-level / xla plus the ``*-db`` double-buffered
    ring schedules."""
    from repro.testing.subproc import run_check
    coll = BENCH.setdefault("coll", {})
    for C, L in ((4, 2), (2, 4)):
        out = run_check("repro.testing.check_collectives", str(C), str(L),
                        devices=8)
        for line in out.splitlines():
            if not line.startswith("coll/"):
                continue
            print(line)
            name, us, _ = line.split(",")
            _, op, tag, variant = name.split("/")
            coll.setdefault(tag, {}).setdefault(op, {})[variant] = float(us)


def bench_ring_attn():
    """Measured sequential-vs-double-buffered ring attention on 8 fake
    devices (flat ring + hierarchical 2x2x2 odometer), median wall-clock
    per schedule from ``check_overlap`` — recorded into BENCH_sim.json as
    ``ring_attention_8dev[case][schedule] = us`` (the db schedule also
    re-proves bit-identity in the same run)."""
    from repro.testing.subproc import run_check
    out = run_check("repro.testing.check_overlap", "attn", devices=8)
    ra = BENCH.setdefault("ring_attention_8dev", {})
    for line in out.splitlines():
        if not line.startswith("ringattn/"):
            continue
        print(line)
        name, us, _ = line.split(",")
        _, case, sched = name.split("/")
        ra.setdefault(case, {})[sched] = float(us)


def bench_serve():
    """The paged-KV serving ablation under open-loop load: the
    ``repro.serve.traffic`` CLI runs all three arms (dense / paged /
    paged+chunked) at equal KV device memory in an 8-fake-device
    subprocess; its ``serve_json`` lines are merged into BENCH_serve.json
    keyed by arm tag."""
    from repro.testing.subproc import run_check
    out = run_check("repro.serve.traffic", devices=8)
    BENCH_SERVE["schema"] = 1
    arms = BENCH_SERVE.setdefault("open_loop", {})
    for line in out.splitlines():
        if line.startswith("serve/"):
            print(line)
        elif line.startswith("serve_json "):
            rec = json.loads(line[len("serve_json "):])
            arms[rec["tag"]] = rec


def bench_roofline():
    outdir = ROOT / "results/dryrun"
    cells = sorted(outdir.glob("*.json")) if outdir.exists() else []
    if not cells:
        print("roof/none,0,run `python -m repro.launch.dryrun --all` first")
        return
    for f in cells:
        rec = json.loads(f.read_text())
        if "skipped" in rec:
            print(f"roof/{f.stem},0,SKIP({rec['skipped']})")
            continue
        r = rec["roofline"]
        print(f"roof/{f.stem},0,"
              f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s bound={r['bottleneck']} "
              f"mfu_ub={r.get('mfu_upper_bound', 0):.3f} "
              f"mem={rec['mem_per_device']['resident_model_gib']:.1f}GiB")


def bench_perf():
    """Merge the launch-strategy roofline records (produced by
    ``python -m repro.launch.perf ... --mesh multi``) into BENCH_sim.json:
    per strategy, the per-level collective seconds and wire bytes — the
    end-to-end fig-7-style ablation of what the pod ring actually carries
    under flat vs hierarchical gradient sync."""
    outdir = ROOT / "results/perf"
    cells = sorted(outdir.glob("*.json")) if outdir.exists() else []
    if not cells:
        print("perf/none,0,run `python -m repro.launch.perf --arch llama3-8b"
              " --shape train_4k --mesh multi --strategy baseline"
              " --strategy fsdp_pure --strategy fsdp_hier` first")
        return
    perf = BENCH.setdefault("perf", {})
    for f in cells:
        if "__smoke" in f.stem:
            # CI-scale smoke artifacts never belong in the calibration file
            print(f"perf/skip-smoke/{f.stem},0,not merged")
            continue
        rec = json.loads(f.read_text())
        strat = rec.get("strategy", f.stem)
        mesh = rec.get("mesh", "?")
        r = rec["roofline"]
        entry = {
            "collective_s": r["collective_s"],
            "bottleneck": r["bottleneck"],
            "mfu_upper_bound": round(r.get("mfu_upper_bound", 0.0), 4),
        }
        if "collective_s_by_level" in r:
            entry["collective_s_by_level"] = r["collective_s_by_level"]
            entry["collective_s_flat_hw"] = r["collective_s_flat_hw"]
            entry["wire_bytes_by_level"] = \
                rec["per_device"]["wire_bytes_by_level"]
            # overlap-aware exposure (exposed_i <= collective_i per level);
            # artifacts recorded before the field existed are re-priced
            # from their stored topology + per-level seconds
            exp = r.get("exposed_collective_s_by_level")
            exp_total = r.get("exposed_collective_s")
            if exp is None and "topology" in rec:
                from repro.roofline.analysis import exposed_level_seconds
                from repro.topology import Topology
                derived = exposed_level_seconds(
                    r["collective_s_by_level"], r["compute_s"],
                    Topology.from_describe(rec["topology"]))
                exp_total = derived.pop("total")
                exp = derived
            if exp is not None:
                entry["exposed_collective_s_by_level"] = exp
                entry["exposed_collective_s"] = exp_total
        key = f"{rec['arch']}__{rec['shape']}__{mesh}"
        perf.setdefault(key, {})[strat] = entry
        lv = r.get("collective_s_by_level", {})
        lv_txt = " ".join(f"{k}={v:.5f}s" for k, v in lv.items())
        print(f"perf/{key}/{strat},0,coll={r['collective_s']:.5f}s {lv_txt} "
              f"bound={r['bottleneck']}")


SECTIONS = {
    "fig6": bench_fig6, "fig7": bench_fig7, "tab1": bench_tab1,
    "tab2": bench_tab2, "tab3": bench_tab3, "kern": bench_kernels,
    "kernels": bench_autotune, "ring": bench_ring,
    "coll": bench_collectives, "ring_attn": bench_ring_attn,
    "serve": bench_serve, "roof": bench_roofline, "perf": bench_perf,
}

#: sections whose derived numbers land in BENCH_sim.json
SIM_SECTIONS = ("fig6", "fig7", "tab1", "tab2", "tab3", "coll",
                "ring_attn", "perf")


def _deep_merge(base: dict, new: dict) -> dict:
    """Merge ``new`` into ``base`` recursively so a partial run (e.g. fig6
    --hierarchy flat) updates only its own sub-keys instead of wiping the
    sibling curves saved by earlier runs."""
    for k, v in new.items():
        if isinstance(v, dict) and isinstance(base.get(k), dict):
            _deep_merge(base[k], v)
        else:
            base[k] = v
    return base


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("sections", nargs="*", default=[], metavar="section",
                    help=f"one of {', '.join(SECTIONS)} (default: all)")
    ap.add_argument("--hierarchy", choices=["flat", "two-level", "both"],
                    default="both",
                    help="interconnect for the fig6 weak-scaling curves")
    ap.add_argument("--json", default=str(ROOT / "BENCH_sim.json"),
                    help="where to merge the machine-readable sim results")
    ap.add_argument("--no-json", action="store_true",
                    help="skip writing BENCH_sim.json")
    args = ap.parse_args(argv)
    unknown = [s for s in args.sections if s not in SECTIONS]
    if unknown:
        ap.error(f"unknown section(s) {unknown}; pick from "
                 f"{', '.join(SECTIONS)}")
    which = args.sections or list(SECTIONS)
    hierarchies = (("flat", "two-level") if args.hierarchy == "both"
                   else (args.hierarchy,))

    print("name,us_per_call,derived")
    for name in which:
        if name == "fig6":
            bench_fig6(hierarchies)
        else:
            SECTIONS[name]()

    if not args.no_json and any(s in SIM_SECTIONS for s in which):
        path = pathlib.Path(args.json)
        merged = {}
        if path.exists():
            try:
                merged = json.loads(path.read_text())
            except (json.JSONDecodeError, OSError):
                merged = {}
        _deep_merge(merged, BENCH)
        path.write_text(json.dumps(merged, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {path}", file=sys.stderr)

    if not args.no_json and "serve" in which and BENCH_SERVE:
        spath = ROOT / "BENCH_serve.json"
        merged = {}
        if spath.exists():
            try:
                merged = json.loads(spath.read_text())
            except (json.JSONDecodeError, OSError):
                merged = {}
        _deep_merge(merged, BENCH_SERVE)
        spath.write_text(json.dumps(merged, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {spath}", file=sys.stderr)

    if not args.no_json and "kernels" in which and BENCH_KERNELS:
        kpath = ROOT / "BENCH_kernels.json"
        merged = {}
        if kpath.exists():
            try:
                merged = json.loads(kpath.read_text())
            except (json.JSONDecodeError, OSError):
                merged = {}
        _deep_merge(merged, BENCH_KERNELS)
        kpath.write_text(json.dumps(merged, indent=2, sort_keys=True) + "\n")
        print(f"# wrote {kpath}", file=sys.stderr)


if __name__ == '__main__':
    main()
