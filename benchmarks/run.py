"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Sections:

  fig6   performance scalability (weak scaling, normalized to 8-lane Ara2)
  fig7   interface latency tolerance (utilization drop per register cut)
  tab1   kernel peak-rate check (Table I max-perf model vs simulated)
  tab2   area model vs published kGE breakdown
  tab3   PPA (peak GFLOPs / energy / area efficiency)
  kern   Pallas kernels (interpret) vs jnp oracle wall time
  ring   AraXL core collectives correctness+wall time (8 fake devices)
  coll   flat vs two-level vs XLA-native collectives head-to-head
         (reduce / allgather / reduce-scatter / staged GLSU, 8 fake devices,
         both C·L factorizations — the §III-B.4 hierarchy ablation)
  roof   roofline summary per dry-run cell (requires results/dryrun/*.json)

Usage: PYTHONPATH=src python -m benchmarks.run [section ...]
"""
from __future__ import annotations

import json
import pathlib
import sys
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np


def _t(fn, *args, reps=3, **kw):
    fn(*args, **kw)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) / reps * 1e6, out


def bench_fig6():
    from repro.sim import ara2_params, araxl_params, build_trace, simulate
    kernels = ["fmatmul", "fconv2d", "jacobi2d", "fdotproduct", "exp",
               "softmax"]
    base = {}
    for k in kernels:
        p8 = ara2_params(8)
        r8 = simulate(build_trace(k, p8, 512), p8)
        base[k] = r8.flop_per_cycle
    for lanes in (8, 16, 32, 64):
        p = araxl_params(lanes)
        for k in kernels:
            us, res = _t(lambda: simulate(build_trace(k, p, 512), p))
            scale = res.flop_per_cycle / base[k]
            print(f"fig6/{k}/L{lanes},{us:.0f},"
                  f"scale={scale:.2f}x util={res.utilization:.3f}")


def bench_fig7():
    from repro.sim import araxl_params, build_trace, simulate
    cuts = [("glsu+4", dict(glsu=4)), ("reqi+1", dict(reqi=1)),
            ("ringi+1", dict(ringi=1))]
    p0 = araxl_params(64)
    for name, kw in cuts:
        for k in ("fmatmul", "fconv2d", "jacobi2d", "fdotproduct", "exp",
                  "softmax"):
            p1 = p0.with_cuts(**kw)
            u0 = simulate(build_trace(k, p0, 512), p0).utilization
            u1 = simulate(build_trace(k, p1, 512), p1).utilization
            print(f"fig7/{name}/{k},0,drop={100*(u0-u1):.2f}%")


def bench_tab1():
    from repro.sim import araxl_params, build_trace, simulate
    from repro.sim.kernels import max_perf_flop_per_cycle
    p = araxl_params(64)
    for k in ("fmatmul", "fconv2d", "jacobi2d", "fdotproduct", "exp",
              "softmax"):
        res = simulate(build_trace(k, p, 512), p)
        peak = max_perf_flop_per_cycle(k, 64)
        print(f"tab1/{k},0,fpc={res.flop_per_cycle:.1f}/"
              f"{peak:.1f} ({100*res.flop_per_cycle/peak:.0f}% of Table-I peak)")


def bench_tab2():
    from repro.sim import araxl_params
    from repro.sim import paper, ppa
    for lanes in (16, 32, 64):
        got = ppa.area_breakdown_kge(araxl_params(lanes))
        want = paper.TABLE_II_KGE[lanes]
        err = 100 * (got["total"] - want["total"]) / want["total"]
        print(f"tab2/area/L{lanes},0,model={got['total']:.0f}kGE "
              f"paper={want['total']}kGE err={err:+.1f}% "
              f"ifc={100*ppa.interface_area_fraction(araxl_params(lanes)):.1f}%")


def bench_tab3():
    from repro.sim import araxl_params, build_trace, simulate
    from repro.sim import paper, ppa
    for lanes in (16, 32, 64):
        p = araxl_params(lanes)
        u = simulate(build_trace("fmatmul", p, 512), p).utilization
        perf = ppa.peak_gflops(p, u)
        eeff = ppa.energy_eff_gflops_per_w(p, u)
        aeff = ppa.area_eff_gflops_per_mm2(p, u)
        w = paper.TABLE_III[lanes]
        print(f"tab3/ppa/L{lanes},0,"
              f"perf={perf:.1f}GF(paper {w[1]}) "
              f"eeff={eeff:.1f}GF/W(paper {w[2]}) "
              f"aeff={aeff:.1f}GF/mm2(paper {w[3]})")


def bench_kernels():
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    rng = np.random.default_rng(0)

    a = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(256, 256)), jnp.float32)
    us_p, _ = _t(lambda: ops.matmul(a, b, use_pallas=True).block_until_ready())
    us_r, _ = _t(lambda: ref.matmul(a, b).block_until_ready())
    print(f"kern/matmul_256(interpret),{us_p:.0f},ref={us_r:.0f}us")

    x = jnp.asarray(rng.normal(size=(32, 512)), jnp.float32)
    us_p, _ = _t(lambda: ops.softmax_rows(x, use_pallas=True)
                 .block_until_ready())
    us_r, _ = _t(lambda: ref.softmax_rows(x).block_until_ready())
    print(f"kern/softmax_rows(interpret),{us_p:.0f},ref={us_r:.0f}us")

    q = jnp.asarray(rng.normal(size=(1, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 2, 256, 64)), jnp.float32)
    us_p, _ = _t(lambda: ops.attention(q, k, v, use_pallas=True, bq=64,
                                       bk=64).block_until_ready())
    us_r, _ = _t(lambda: ref.attention(q, k, v).block_until_ready())
    print(f"kern/flash_attn(interpret),{us_p:.0f},ref={us_r:.0f}us")


def bench_ring():
    from repro.testing.subproc import run_check
    t0 = time.perf_counter()
    run_check("repro.testing.check_core", "2", "4", devices=8)
    us = (time.perf_counter() - t0) * 1e6
    print(f"ring/core_suite_8dev,{us:.0f},all-modes-allclose")


def bench_collectives():
    from repro.testing.subproc import run_check
    for C, L in ((4, 2), (2, 4)):
        out = run_check("repro.testing.check_collectives", str(C), str(L),
                        devices=8)
        for line in out.splitlines():
            if line.startswith("coll/"):
                print(line)


def bench_roofline():
    outdir = pathlib.Path(__file__).resolve().parents[1] / "results/dryrun"
    cells = sorted(outdir.glob("*.json")) if outdir.exists() else []
    if not cells:
        print("roof/none,0,run `python -m repro.launch.dryrun --all` first")
        return
    for f in cells:
        rec = json.loads(f.read_text())
        if "skipped" in rec:
            print(f"roof/{f.stem},0,SKIP({rec['skipped']})")
            continue
        r = rec["roofline"]
        print(f"roof/{f.stem},0,"
              f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
              f"collective={r['collective_s']:.4f}s bound={r['bottleneck']} "
              f"mfu_ub={r.get('mfu_upper_bound', 0):.3f} "
              f"mem={rec['mem_per_device']['resident_model_gib']:.1f}GiB")


SECTIONS = {
    "fig6": bench_fig6, "fig7": bench_fig7, "tab1": bench_tab1,
    "tab2": bench_tab2, "tab3": bench_tab3, "kern": bench_kernels,
    "ring": bench_ring, "coll": bench_collectives, "roof": bench_roofline,
}


def main() -> None:
    which = sys.argv[1:] or list(SECTIONS)
    print("name,us_per_call,derived")
    for name in which:
        SECTIONS[name]()


if __name__ == '__main__':
    main()
