"""Quickstart: the AraXL machine as a JAX library.

Builds an 8-"lane" distributed vector machine (2 clusters x 4 lanes — the
paper's building block), loads long vectors through the staged GLSU, runs
slide/reduction kernels over the RINGI, and executes the paper's benchmark
kernels through the vector ISA.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import numpy as np

from repro.core import make_machine
from repro.core import isa_kernels


def main():
    print(f"devices: {len(jax.devices())}")
    # C=2 clusters x L=4 lanes, RVV-maximum VLEN (64 Kibit -> 1024 f32/vreg)
    v = make_machine(2, 4, vlen_bits=65536, sew_bits=64)
    n = v.spec.n_total_lanes
    print(f"machine: {v.spec.n_clusters} clusters x {v.spec.n_lanes} lanes, "
          f"VLMAX={v.vlmax} elements/vreg")

    # --- GLSU: memory -> striped register file (paper byte map) ------------
    x = np.arange(n * n, dtype=np.float64)
    r = v.vle(x)
    from repro.core import element_to_coords
    b, c, l = element_to_coords(5, v.spec.n_clusters, v.spec.n_lanes)
    print(f"vle: element 5 sits at (row, cluster, lane) = ({b}, {c}, {l})")

    # --- RINGI: slide-by-1 and the 4-stage reduction ------------------------
    slid = v.vslide1down(r, fill=-1.0)
    print("slide1down head:", np.asarray(v.vse(slid))[:6])
    print("vredsum:", float(v.vredsum(r)), "expected:", x.sum())

    # --- the paper's kernels through the ISA --------------------------------
    rng = np.random.default_rng(0)
    A = rng.normal(size=(4, 8))
    B = rng.normal(size=(8, 4 * n))
    C = isa_kernels.fmatmul(v, A, B)
    print("fmatmul max err:", float(np.abs(C - A @ B).max()))

    S = rng.normal(size=(3, 4 * n))
    sm = isa_kernels.softmax(v, S)
    print("softmax row sums:", np.asarray(sm).sum(axis=1))

    d = isa_kernels.fdotproduct(v, rng.normal(size=4 * n),
                                rng.normal(size=4 * n))
    print("fdotproduct:", float(d))

    # --- trace the same program through the cycle model ---------------------
    from repro.sim import TraceMachine, araxl_params, simulate
    tv = TraceMachine()
    isa_kernels.softmax(tv, np.zeros((4, 64 * 64)))
    res = simulate(tv.trace, araxl_params(64))
    print(f"softmax on simulated 64-lane AraXL: {res.cycles:.0f} cycles, "
          f"FPU util {res.utilization:.1%}, "
          f"{res.flop_per_cycle:.1f} DP-FLOP/cycle")


if __name__ == "__main__":
    main()
