"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

The model is a reduced llama3-family config (~100M params with tied
embeddings); the run exercises the full production path: deterministic
sharded data pipeline, microbatched AdamW step, async checkpointing with
resume, heartbeat/straggler monitoring.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--small]
(--small: ~8M params, finishes in ~1 min on CPU; default ~100M takes
a while on CPU — it is sized for a real accelerator.)
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.configs.archs import smoke_variant
from repro.launch.train import run


def lm100m(small: bool):
    base = get_config("llama3-8b")
    if small:
        return smoke_variant(base)
    import jax.numpy as jnp
    return dataclasses.replace(
        base, name="llama3-100m", n_layers=10, d_model=640, n_heads=10,
        n_kv_heads=5, d_head=64, d_ff=1792, vocab_size=32000,
        tie_embeddings=True, dtype=jnp.float32, remat=False)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/araxl_lm_ckpt")
    args = ap.parse_args()

    cfg = lm100m(args.small)
    print(f"model: {cfg.name}, {cfg.n_params()/1e6:.1f}M params")

    import repro.configs.archs as archs
    archs.CONFIGS[cfg.name] = cfg          # register for the launcher
    out = run(cfg.name, smoke=False, steps=args.steps, global_batch=8,
              seq_len=128, lr=1e-3, ckpt_dir=args.ckpt, ckpt_every=100,
              n_microbatches=2, log_every=10)
    print(f"loss: {out['losses'][0]:.3f} -> {out['final_loss']:.3f}")


if __name__ == "__main__":
    main()
