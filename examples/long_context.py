"""Long-context via the RINGI idiom: hierarchical ring attention.

Demonstrates the paper's thesis at the sequence level: a long context
sharded over the AraXL hierarchy — the one :class:`repro.topology.Topology`
value that also drives the sim and the emulator.  KV blocks rotate
odometer-style (the intra-cluster `lane` ring turns every step; the
`cluster` ring only once per lane cycle, so the long wires carry 1/L of
the traffic), exactness verified against the single-device oracle and the
flat single-axis schedule.

Run:  PYTHONPATH=src python examples/long_context.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.parallel.ring_attention import ring_attention
from repro.testing.timing import now
from repro.topology import Topology


def main():
    # 2 clusters x 4 lanes — the same geometry type the sim prices
    topo = Topology(2, 4, cluster_axis="cluster", lane_axis="lane")
    mesh = jax.make_mesh(topo.shape, ("cluster", "lane"))
    n = topo.n_lanes
    rng = np.random.default_rng(0)
    B, S, H, Hkv, D = 1, n * 256, 8, 2, 64       # 2k tokens over the 8-ring
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.bfloat16)

    fn = jax.jit(lambda q, k, v: ring_attention(q, k, v, mesh, topology=topo,
                                                causal=True, window=512))
    out = fn(q, k, v)                             # compile + run
    t0 = now()
    out = jax.block_until_ready(fn(q, k, v))
    dt = now() - t0

    want = ref.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), causal=True,
                         window=512).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    C, L = topo.grid
    print(f"hierarchical ring attention over {C}x{L} devices: "
          f"S={S}, SWA window 512")
    print(f"  wall {dt*1e3:.1f} ms, max err vs oracle {err:.2e}")
    kv_mb = 2 * (S // n) * H * D * 2 / 1e6
    print(f"  KV bytes rotated/device/step: {kv_mb:.2f} MB; "
          f"inter-cluster wires carry only 1/{L} of the steps")


if __name__ == "__main__":
    main()
