"""Long-context via the RINGI idiom: ring attention + SSM state streaming.

Demonstrates the paper's thesis at the sequence level: a long context
sharded over a ring of devices, attention/KV blocks rotating one neighbour
hop per step (slide-by-1), exactness verified against the single-device
oracle.

Run:  PYTHONPATH=src python examples/long_context.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.parallel.ring_attention import ring_attention


def main():
    n = 8
    mesh = jax.make_mesh((n,), ("data",))
    rng = np.random.default_rng(0)
    B, S, H, Hkv, D = 1, 8 * 256, 8, 2, 64       # 2k tokens over an 8-ring
    q = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, D)), jnp.bfloat16)

    fn = jax.jit(lambda q, k, v: ring_attention(q, k, v, mesh, causal=True,
                                                window=512))
    out = fn(q, k, v)                             # compile + run
    t0 = time.time()
    out = jax.block_until_ready(fn(q, k, v))
    dt = time.time() - t0

    want = ref.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3), causal=True,
                         window=512).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                - want.astype(jnp.float32))))
    print(f"ring attention over {n} devices: S={S}, SWA window 512")
    print(f"  wall {dt*1e3:.1f} ms, max err vs oracle {err:.2e}")
    print(f"  KV bytes rotated/device/step: "
          f"{2 * (S // n) * H * D * 2 / 1e6:.2f} MB x {n-1} hops")


if __name__ == "__main__":
    main()
