"""Serve a small model with continuously-batched requests.

Run:  PYTHONPATH=src python examples/serve_batch.py [--arch mixtral-8x7b]
Uses the reduced same-family config on CPU; on a pod the same engine drives
the full config against the production mesh (see launch/dryrun.py decode
cells for the sharding).
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()
    finished = run(args.arch, smoke=True, n_requests=args.requests,
                   max_new=args.max_new, max_batch=4, max_seq=128)
    for r in finished[:4]:
        print(f"req {r.rid}: prompt[:6]={r.prompt[:6].tolist()} "
              f"-> {len(r.out)} tokens: {r.out[:10]}")


if __name__ == "__main__":
    main()
