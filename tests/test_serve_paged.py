"""Paged-KV serving: dense-vs-paged bit-identity + COW + chunked prefill on
8 fake devices (subprocess check), allocator units, submit() boundary, the
per-slot decode-position equivalence, and the BENCH_serve.json >= 2x
paged-concurrency acceptance pin."""
import numpy as np
import pytest

from repro.analysis.bench import load_serve_bench, validate_serve_bench
from repro.serve import (BlockAllocator, PromptTooLongError, Request,
                         kv_token_bytes, max_block_tokens, validate_prompt)
from repro.testing.subproc import run_check


def test_serve_paged_multidevice():
    out = run_check("repro.testing.check_serve_paged", devices=8)
    assert "check_serve_paged OK" in out


# ---------------------------------------------------------------------------
# BlockAllocator units
# ---------------------------------------------------------------------------

def test_alloc_free_bookkeeping():
    a = BlockAllocator(4, 8)
    assert a.n_free == 4 and a.n_allocated == 0
    b1, b2 = a.alloc(), a.alloc()
    assert (b1, b2) == (1, 2)               # lowest ids first, 0 reserved
    assert a.n_allocated == 2 and a.peak_allocated == 2
    a.release(b1)
    assert a.n_free == 3
    assert a.alloc() == 1                   # freed id comes back
    a.release(1)
    a.release(b2)
    assert a.n_allocated == 0 and a.peak_allocated == 2


def test_alloc_exhaustion_raises():
    a = BlockAllocator(2, 8)
    a.alloc(), a.alloc()
    with pytest.raises(RuntimeError, match="exhausted"):
        a.alloc()


def test_refcount_sharing_and_release():
    a = BlockAllocator(4, 8)
    key = ("full", (1, 2, 3))
    bid = a.alloc(key)
    assert a.lookup(key) == bid
    a.retain(bid)
    assert a.refcount[bid] == 2 and a.shared_hits == 1
    a.release(bid)                          # one sharer gone: still keyed
    assert a.refcount[bid] == 1 and a.lookup(key) == bid
    a.release(bid)                          # last ref: key dropped, freed
    assert a.lookup(key) is None
    assert a.n_allocated == 0


def test_register_first_writer_wins_and_forget():
    a = BlockAllocator(4, 8)
    key = ("part", (9, 9))
    b1 = a.alloc(key)
    b2 = a.alloc(key)                       # duplicate content: stays private
    assert a.lookup(key) == b1
    a.forget_key(b2)                        # no-op: b2 never owned the key
    assert a.lookup(key) == b1
    a.forget_key(b1)                        # pre-divergence unpublish
    assert a.lookup(key) is None
    assert a.refcount[b1] == 1              # forget does not free


# ---------------------------------------------------------------------------
# submit() boundary (the silent-overflow bugfix)
# ---------------------------------------------------------------------------

def test_validate_prompt_boundary():
    assert validate_prompt(np.arange(63, dtype=np.int32), 64) == 63
    with pytest.raises(PromptTooLongError, match="64-position cache"):
        validate_prompt(np.arange(64, dtype=np.int32), 64)
    with pytest.raises(PromptTooLongError):
        validate_prompt(np.arange(100, dtype=np.int32), 64)
    with pytest.raises(ValueError, match="empty"):
        validate_prompt(np.zeros(0, np.int32), 64)


def test_engine_submit_rejects_oversized_prompt():
    import jax
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.parallel.sharding import default_rules, init_params
    from repro.serve import (PagedServeConfig, PagedServingEngine,
                             ServeConfig, ServingEngine)
    cfg = get_smoke_config("llama3-8b")
    rules = default_rules(None)
    params = init_params(lm.model_defs(cfg), jax.random.key(0))
    dense = ServingEngine(cfg, params, rules,
                          ServeConfig(max_batch=2, max_seq=32))
    paged = PagedServingEngine(cfg, params, rules,
                               PagedServeConfig(max_batch=2, max_seq=32,
                                                block_tokens=8, n_blocks=8))
    bad = Request(rid=0, prompt=np.ones(32, np.int32), max_new_tokens=4)
    for eng in (dense, paged):
        with pytest.raises(PromptTooLongError):
            eng.submit(bad)
        assert eng.n_waiting == 0           # rejected before enqueue
    ok = Request(rid=1, prompt=np.ones(31, np.int32), max_new_tokens=4)
    dense.submit(ok)                        # boundary length is admissible
    assert dense.n_waiting == 1


# ---------------------------------------------------------------------------
# per-slot decode positions (the shared-max-pos bugfix)
# ---------------------------------------------------------------------------

def test_decode_step_vector_pos_matches_scalar():
    """For equal-length slots the vectorised per-slot position path must be
    bit-identical to the historical scalar-pos path (same logits, same
    cache) — the regression guard for the pos = max(slot_pos) retirement."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.parallel.sharding import default_rules, init_params
    cfg = get_smoke_config("llama3-8b")
    rules = default_rules(None)
    params = init_params(lm.model_defs(cfg), jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 6)), jnp.int32)
    cache, _ = lm.prefill(params, toks, cfg, rules, 32)
    step_tok = jnp.asarray(rng.integers(1, cfg.vocab_size, (2, 1)), jnp.int32)
    lg_s, c_s = lm.decode_step(params, step_tok, cache, 6, cfg, rules)
    lg_v, c_v = lm.decode_step(params, step_tok, cache,
                               jnp.array([6, 6], jnp.int32), cfg, rules)
    assert jnp.array_equal(lg_s, lg_v)
    for a, b in zip(jax.tree.leaves(c_s), jax.tree.leaves(c_v)):
        assert jnp.array_equal(a, b)


# ---------------------------------------------------------------------------
# block sizing against the VRF budget
# ---------------------------------------------------------------------------

def test_block_sizing_respects_vreg_budget():
    from repro.configs import get_smoke_config
    from repro.kernels.vrf import VREG_GROUP_BYTES
    cfg = get_smoke_config("llama3-8b")
    bt = max_block_tokens(cfg)
    per_tok = cfg.n_kv_heads * cfg.head_dim * 4       # f32 smoke config
    assert bt & (bt - 1) == 0                          # power of two
    assert 2 * bt * per_tok <= VREG_GROUP_BYTES
    assert 4 * bt * per_tok > VREG_GROUP_BYTES         # largest such
    assert kv_token_bytes(cfg) > 0


# ---------------------------------------------------------------------------
# the recorded ablation: paged serves >= 2x dense concurrency at equal KV
# ---------------------------------------------------------------------------

def test_bench_serve_concurrency_acceptance():
    doc = load_serve_bench()
    if doc is None:
        pytest.skip("BENCH_serve.json not recorded yet "
                    "(python -m benchmarks.run serve)")
    assert validate_serve_bench(doc) == []
    arms = doc["open_loop"]
    dense, paged = arms["dense"], arms["paged"]
    # equal device memory is the premise of the comparison
    assert paged["kv_bytes_capacity"] == dense["kv_bytes_capacity"]
    assert paged["max_concurrent"] >= 2 * dense["max_concurrent"], \
        (paged["max_concurrent"], dense["max_concurrent"])
    for arm in arms.values():
        assert arm["completed"] == arm["n_requests"]


# ---------------------------------------------------------------------------
# shutdown hygiene: assert_quiescent / BlockLeakError (the fd-leak analogue)
# ---------------------------------------------------------------------------

def test_assert_quiescent_passes_when_clean():
    from repro.serve import BlockLeakError  # noqa: F401 (export check)
    a = BlockAllocator(4, 8)
    b = a.alloc(("prefix", (1, 2)))
    a.retain(b)
    a.release(b)
    a.release(b)                            # last ref: key dropped, freed
    a.assert_quiescent()                    # no raise


def test_assert_quiescent_names_live_refcounts():
    from repro.serve import BlockLeakError
    a = BlockAllocator(4, 8)
    b1, b2 = a.alloc(), a.alloc()
    a.release(b1)
    with pytest.raises(BlockLeakError, match="live refcounts"):
        a.assert_quiescent()
    a.release(b2)
    a.assert_quiescent()


def test_assert_quiescent_catches_stale_registry_entry():
    """A registry key whose block was freed behind its back (the COW
    forget_key contract violated) is a leak even with all refcounts
    zero — the stale key would alias future prefills to a recycled
    block's contents."""
    from repro.serve import BlockLeakError
    a = BlockAllocator(4, 8)
    b = a.alloc(("k", (7,)))
    a.release(b)
    a.assert_quiescent()
    a._prefix[("stale", (0,))] = 3          # inject the violation
    with pytest.raises(BlockLeakError, match="registry"):
        a.assert_quiescent()


def test_engine_shutdown_refuses_inflight_then_catches_leak():
    """PagedServingEngine.shutdown(): refuses while work is in flight,
    passes after a clean drain, and surfaces an injected block leak as
    BlockLeakError instead of silently shrinking the pool."""
    import jax
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.parallel.sharding import default_rules, init_params
    from repro.serve import (BlockLeakError, PagedServeConfig,
                             PagedServingEngine)
    cfg = get_smoke_config("llama3-8b")
    rules = default_rules(None)
    params = init_params(lm.model_defs(cfg), jax.random.key(0))
    eng = PagedServingEngine(cfg, params, rules,
                             PagedServeConfig(max_batch=2, max_seq=32,
                                              block_tokens=8, n_blocks=8))
    eng.submit(Request(rid=0, prompt=np.ones(8, np.int32),
                       max_new_tokens=2))
    with pytest.raises(BlockLeakError, match="in flight"):
        eng.shutdown()                      # still queued
    eng.run()                               # drain to completion
    eng.shutdown()                          # clean: no raise

    leaked = eng.alloc.alloc()              # inject a leaked reservation
    with pytest.raises(BlockLeakError, match="live refcounts"):
        eng.shutdown()
    eng.alloc.release(leaked)
    eng.shutdown()
