"""Per-architecture smoke tests (reduced configs, 1 CPU device).

For every assigned arch: instantiate the reduced same-family config, run one
forward/train step, assert output shapes and finiteness.  For representative
families additionally check that prefill + step-by-step decode reproduces the
full-sequence forward logits (the strongest cache-correctness signal).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_smoke_config, list_archs
from repro.models import lm
from repro.parallel import abstract_params, default_rules, init_params

RULES = default_rules(None)


def make_inputs(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    ctx = None
    if cfg.family in ("encdec", "vlm"):
        T = lm.context_len(cfg, S)
        ctx = jnp.asarray(rng.normal(size=(B, T, cfg.d_ctx)) * 0.1,
                          jnp.float32)
    return tokens, ctx


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_smoke_config(name)
            params = init_params(lm.model_defs(cfg), jax.random.key(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", list_archs())
def test_forward_train_smoke(arch_state, name):
    cfg, params = arch_state(name)
    tokens, ctx = make_inputs(cfg)
    loss = jax.jit(lambda p, t, c: lm.forward_train(p, t, cfg, RULES, c)
                   )(params, tokens, ctx)
    assert loss.shape == ()
    assert jnp.isfinite(loss), (name, loss)
    assert float(loss) > 0.0


@pytest.mark.parametrize("name", list_archs())
def test_train_step_smoke(arch_state, name):
    """One full gradient step: loss decreases-or-moves, grads finite."""
    cfg, params = arch_state(name)
    tokens, ctx = make_inputs(cfg)

    def loss_fn(p):
        return lm.forward_train(p, tokens, cfg, RULES, ctx)

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert jnp.isfinite(loss)
    assert jnp.isfinite(gnorm) and float(gnorm) > 0.0, name


@pytest.mark.parametrize("name", list_archs())
def test_prefill_decode_smoke(arch_state, name):
    cfg, params = arch_state(name)
    B, S = 2, 16
    tokens, ctx = make_inputs(cfg, B, S)
    cache, logits = jax.jit(
        lambda p, t, c: lm.prefill(p, t, cfg, RULES, 2 * S, c)
    )(params, tokens, ctx)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), name
    nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    step = jax.jit(lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg,
                                                       RULES))
    lg, cache = step(params, nxt, cache, jnp.int32(S))
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all()), name


@pytest.mark.parametrize("name", ["llama3-8b", "mixtral-8x7b", "mamba2-370m",
                                  "jamba-1.5-large-398b",
                                  "seamless-m4t-large-v2",
                                  "llama-3.2-vision-11b"])
def test_decode_matches_forward(arch_state, name):
    """prefill(t[:k]) + decode steps == full forward logits (teacher forcing).

    Covers: KV caches (full + SWA ring), mamba states, cross-attn caches."""
    cfg, params = arch_state(name)
    B, S, k = 2, 16, 8
    tokens, ctx = make_inputs(cfg, B, S, seed=3)

    # full-sequence logits via prefill over the whole sequence
    _, full_last = jax.jit(
        lambda p, t, c: lm.prefill(p, t, cfg, RULES, S, c))(params, tokens, ctx)

    # prefill the first k, then decode the rest token-by-token
    cache, lg = jax.jit(
        lambda p, t, c: lm.prefill(p, t, cfg, RULES, S, c)
    )(params, tokens[:, :k], ctx)
    step = jax.jit(lambda p, t, c, pos: lm.decode_step(p, t, c, pos, cfg,
                                                       RULES))
    for i in range(k, S):
        lg, cache = step(params, tokens[:, i:i + 1], cache, jnp.int32(i))
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(full_last[:, 0], np.float32), rtol=2e-3, atol=2e-3)


def test_param_counts_match_published():
    """Sanity: full-config parameter counts are in the published ballparks."""
    from repro.configs import get_config
    expect = {
        "qwen3-moe-235b-a22b": (235e9, 0.10),
        "mixtral-8x7b": (46.7e9, 0.10),
        "jamba-1.5-large-398b": (398e9, 0.15),
        "phi3-mini-3.8b": (3.8e9, 0.10),
        "deepseek-7b": (7e9, 0.10),
        "glm4-9b": (9e9, 0.15),
        "llama3-8b": (8e9, 0.10),
        "mamba2-370m": (370e6, 0.15),
        "llama-3.2-vision-11b": (10.6e9, 0.20),
        "seamless-m4t-large-v2": (2.3e9, 0.50),
    }
    for name, (want, tol) in expect.items():
        got = get_config(name).n_params()
        assert abs(got - want) / want <= tol, (name, got, want)


def test_active_params_moe():
    from repro.configs import get_config
    q = get_config("qwen3-moe-235b-a22b")
    assert abs(q.n_active_params() - 22e9) / 22e9 < 0.25
    m = get_config("mixtral-8x7b")
    assert abs(m.n_active_params() - 12.9e9) / 12.9e9 < 0.15
