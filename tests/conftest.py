"""Shared test bootstrap.

The offline CI image has no ``hypothesis``; install the deterministic compat
shim before the property-test modules are collected.  With the real package
available the shim is a no-op.
"""
import pathlib
import sys

_SRC = pathlib.Path(__file__).resolve().parents[1] / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

from repro.testing import hypothesis_compat

hypothesis_compat.install()
