"""Shared test bootstrap — the ONE place the test env is mutated.

Fake-device setup: multi-device behaviour must be identical under bare
``pytest`` and under ``scripts/ci.sh`` (which exports the same env), so the
8-CPU-device flags are set *here*, idempotently — an inherited device-count
flag or platform choice is respected, never clobbered.  Lint rule L2's env
sub-rule (``repro.analysis``) rejects any *test module* touching
``XLA_FLAGS`` / ``JAX_PLATFORMS`` at import time: by the time a module
imports, jax may already be initialised and the flip silently no-ops on
part of the suite — this file runs before collection, so here it is safe.

The offline CI image has no ``hypothesis``; install the deterministic compat
shim before the property-test modules are collected.  With the real package
available the shim is a no-op.
"""
import os
import pathlib
import sys

os.environ.setdefault("JAX_PLATFORMS", "cpu")
if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=8 "
        + os.environ.get("XLA_FLAGS", "")).strip()

_SRC = pathlib.Path(__file__).resolve().parents[1] / "src"
if str(_SRC) not in sys.path:
    sys.path.insert(0, str(_SRC))

from repro.testing import hypothesis_compat

hypothesis_compat.install()
