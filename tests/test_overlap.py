"""Overlap as a first-class concept, pinned at every layer.

* sim — ``simulate(overlap=True)`` (backfilled wire-wait bubbles) never
  regresses a kernel, moves the reduction-bound ones toward linear
  scaling at 64 lanes, and splits every wire wait into exposed vs hidden
  cycles that add up exactly;
* roofline — ``exposed_level_seconds`` keeps ``exposed <= collective``
  per level, conserves nothing it shouldn't, and degenerates to the
  additive model with zero compute;
* BENCH_sim.json — the fig6 overlap ablation, the measured sequential-vs-
  double-buffered ring-attention wall-clock, the ``coll`` median-of-k
  schema, and the ``perf`` strategy records (``fsdp_hier_ov`` included)
  are all pinned against the file;
* multi-device — ``check_overlap`` re-proves, on 8 fake devices, that the
  double-buffered schedules are bit-identical (ring attention) and
  grad-equivalent (bucketed sync) to their sequential twins.
"""
import json
import pathlib

import pytest

from repro.analysis.bench import validate_section
from repro.sim import araxl_params, ara2_params, build_trace, simulate
from repro.testing.subproc import run_check

ROOT = pathlib.Path(__file__).resolve().parents[1]
KERNELS = ("fmatmul", "fconv2d", "jacobi2d", "fdotproduct", "exp", "softmax")


def _bench():
    return json.loads((ROOT / "BENCH_sim.json").read_text())


# ---------------------------------------------------------------------------
# sim: overlap semantics
# ---------------------------------------------------------------------------

def _scales(kernel, overlap):
    p, a8 = araxl_params(64), ara2_params(8)
    base = simulate(build_trace(kernel, a8, 512), a8).flop_per_cycle
    r = simulate(build_trace(kernel, p, 512), p, overlap=overlap)
    return r.flop_per_cycle / base, r


def test_overlap_never_regresses_and_moves_softmax():
    """Backfilling wire-wait bubbles can only help; at 64 lanes it must
    visibly lift the reduction-bound softmax toward the linear band."""
    for k in KERNELS:
        s0, r0 = _scales(k, overlap=False)
        s1, r1 = _scales(k, overlap=True)
        assert s1 >= s0 - 1e-9, (k, s0, s1)
        assert r1.cycles <= r0.cycles + 1e-9, k
    s0, _ = _scales("softmax", overlap=False)
    s1, _ = _scales("softmax", overlap=True)
    assert s1 > s0 + 0.3, (s0, s1)            # the fig6 knob actually moves


def test_exposed_plus_hidden_conserve_wire_cycles():
    """The exposed/hidden split is an attribution, not a rescale: per wire
    class the two parts sum to the same total in both modes."""
    p = araxl_params(64)
    for k in ("softmax", "fdotproduct", "jacobi2d", "fconv2d"):
        r0 = simulate(build_trace(k, p, 512), p)
        r1 = simulate(build_trace(k, p, 512), p, overlap=True)
        labels = set(r0.wire_exposed) | set(r0.wire_hidden)
        assert labels == set(r1.wire_exposed) | set(r1.wire_hidden), k
        for lab in labels:
            t0 = r0.wire_exposed.get(lab, 0) + r0.wire_hidden.get(lab, 0)
            t1 = r1.wire_exposed.get(lab, 0) + r1.wire_hidden.get(lab, 0)
            assert t0 == pytest.approx(t1), (k, lab)
            assert r1.wire_exposed.get(lab, 0) <= \
                r0.wire_exposed.get(lab, 0) + 1e-9, (k, lab)


def test_overlap_exposes_only_the_unamortized_tree_tail():
    """fdotproduct: at 512 B/lane the single strip's tree is fully exposed
    in both modes (nothing to backfill); at 16384 B/lane only the final
    strip's tree sticks out — the paper's long-vector amortization."""
    p = araxl_params(64)
    tree = p.red_tree_lat()
    for overlap in (False, True):
        r = simulate(build_trace("fdotproduct", p, 512), p, overlap=overlap)
        assert r.wire_exposed == {"tree": tree}
        r = simulate(build_trace("fdotproduct", p, 16384), p, overlap=overlap)
        assert r.wire_exposed["tree"] == tree
        assert r.wire_hidden["tree"] == pytest.approx(15 * tree)


def test_default_engine_untouched_by_overlap_plumbing():
    """The paper calibration rides on overlap=False staying bit-identical:
    spot-pin the 64-lane softmax/fdotproduct cycle counts."""
    p = araxl_params(64)
    assert simulate(build_trace("softmax", p, 512), p).cycles == 115991.5
    assert simulate(build_trace("fdotproduct", p, 512), p).cycles == 321.5


# ---------------------------------------------------------------------------
# roofline: exposed_level_seconds
# ---------------------------------------------------------------------------

def test_exposed_level_seconds_properties():
    from repro.roofline.analysis import exposed_level_seconds
    from repro.topology import Topology, Level
    topo = Topology(levels=(Level("pod", 2, 8.0), Level("data", 16, 4.0),
                            Level("model", 16, 2.0)))
    secs = {"pod": 2.0, "inter": 3.0, "intra": 1.0}
    # zero compute: degenerates to the additive pricing
    e0 = exposed_level_seconds(secs, 0.0, topo)
    assert {k: e0[k] for k in secs} == secs
    # per-level cap and innermost-first budget draw
    e = exposed_level_seconds(secs, 3.5, topo)
    for lab in secs:
        assert 0.0 <= e[lab] <= secs[lab]
    assert e["intra"] == 0.0                  # 1.0 fully hidden behind 3.5
    assert e["inter"] == pytest.approx(0.5)   # 3.0 against the remaining 2.5
    assert e["pod"] == pytest.approx(2.0)     # budget exhausted
    assert e["total"] == pytest.approx(2.5)
    # compute >= all collectives: everything hides
    assert exposed_level_seconds(secs, 100.0, topo)["total"] == 0.0


# ---------------------------------------------------------------------------
# BENCH_sim.json pins
# ---------------------------------------------------------------------------

def test_bench_fig6_overlap_recorded_and_improves():
    """Schema (key sets, overlap >= baseline, exposure monotone) lives in
    the shared validator; this test keeps only the numeric pins."""
    ov = _bench()["fig6_overlap_64"]
    assert validate_section("fig6_overlap_64", ov) == []
    assert ov["softmax"]["overlap"] > ov["softmax"]["baseline"]
    # the recorded ablation is reproducible from the engine
    s1, _ = _scales("softmax", overlap=True)
    assert ov["softmax"]["overlap"] == pytest.approx(s1, abs=5e-3)


def test_bench_ring_attention_wallclock_recorded():
    assert validate_section("ring_attention_8dev",
                            _bench()["ring_attention_8dev"]) == []


def test_bench_coll_schema():
    """The re-baselined XLA-native vs shard_map-ring comparison: pinned
    schema (shared validator) so the ROADMAP re-baseline item has a stable
    record to diff."""
    assert validate_section("coll", _bench()["coll"]) == []


def test_bench_perf_exposed_le_collective_per_level():
    """Acceptance pin: every perf strategy record carries the overlap-aware
    exposure with exposed <= collective per level (shared validator), and
    the bucketed fsdp_hier_ov strategy is recorded on the multi-pod cell."""
    perf = _bench()["perf"]
    assert validate_section("perf", perf) == []
    cell = perf["llama3-8b__train_4k__pod2x16x16"]
    assert "fsdp_hier_ov" in cell
    # the bucketed sync must not change what the wires carry vs fsdp_hier
    hier, ov = cell["fsdp_hier"], cell["fsdp_hier_ov"]
    assert ov["collective_s_by_level"]["pod"] == \
        pytest.approx(hier["collective_s_by_level"]["pod"], rel=0.05)


# ---------------------------------------------------------------------------
# multi-device equivalence (8 fake devices, subprocess)
# ---------------------------------------------------------------------------

def test_overlap_schedules_equivalent_multidevice():
    out = run_check("repro.testing.check_overlap", "all", devices=8)
    assert "check_overlap attn OK" in out
    assert "check_overlap grad OK" in out
