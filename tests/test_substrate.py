"""Substrate tests: optimizer, data pipeline, checkpointing, FT, serving."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import DataConfig, SyntheticCorpus, make_pipeline
from repro.checkpoint import CheckpointManager, restore_checkpoint, \
    save_checkpoint
from repro.checkpoint.ckpt import latest_step
from repro.ft import (HeartbeatMonitor, RestartPolicy, StragglerMitigator,
                      plan_rescale)
from repro.train import OptConfig, adamw_init, adamw_update, lr_schedule


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _quad_params():
    return {"w": jnp.array([1.5, -2.0, 3.0]), "b": jnp.zeros(())}


def test_adamw_converges_quadratic():
    cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                    total_steps=300, clip_norm=0.0)
    params = _quad_params()
    state = adamw_init(params, cfg)
    for _ in range(300):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2)(params)
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_stacked_leaf_matches_flat():
    """The fori_loop chunked path must produce identical updates to the
    plain path (stacked leaf with first dim >= 8)."""
    cfg = OptConfig(lr=0.01, warmup_steps=1, total_steps=10)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(12, 4, 5)), jnp.float32)
    g = jnp.asarray(rng.normal(size=(12, 4, 5)), jnp.float32)
    ps, ss = {"w": w}, adamw_init({"w": w}, cfg)
    pf, sf = {"w": w[0]}, adamw_init({"w": w[0]}, cfg)
    ps2, ss2, _ = adamw_update(ps, {"w": g}, ss, cfg)
    # same slice updated standalone (clip differs through gnorm; disable)
    cfg2 = OptConfig(lr=0.01, warmup_steps=1, total_steps=10, clip_norm=0.0)
    ps3, _, _ = adamw_update(ps, {"w": g}, adamw_init(ps, cfg2), cfg2)
    pf3, _, _ = adamw_update(pf, {"w": g[0]}, adamw_init(pf, cfg2), cfg2)
    np.testing.assert_allclose(np.asarray(ps3["w"][0]),
                               np.asarray(pf3["w"]), rtol=1e-6)


def test_bf16_state_roundtrip():
    # lr must exceed the bf16 ulp at 1.0 (0.0078): without an fp32 master,
    # smaller updates round away — the documented trade of the giant configs.
    cfg = OptConfig(lr=0.1, state_dtype=jnp.bfloat16, master_fp32=False,
                    math_dtype=jnp.bfloat16, warmup_steps=1, total_steps=10)
    params = {"w": jnp.ones((16, 8), jnp.bfloat16)}
    state = adamw_init(params, cfg)
    g = {"w": jnp.full((16, 8), 0.5, jnp.bfloat16)}
    p2, s2, _ = adamw_update(params, g, state, cfg)
    assert p2["w"].dtype == jnp.bfloat16
    assert s2["params"]["w"]["m"].dtype == jnp.bfloat16
    assert float(p2["w"][0, 0]) < 1.0            # moved in -grad direction


def test_lr_schedule_shape():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(cfg, jnp.int32(s))) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert lrs[99] < lrs[50] < lrs[12]
    assert lrs[99] >= 0.099                      # floor ~10%


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_deterministic_and_restartable():
    cfg = DataConfig(vocab_size=100, seq_len=32, global_batch=4, seed=7)
    c1 = SyntheticCorpus(cfg)
    c2 = SyntheticCorpus(cfg)
    np.testing.assert_array_equal(c1.batch(5), c2.batch(5))
    assert not np.array_equal(c1.batch(5), c1.batch(6))
    # restart mid-stream == fresh stream at that step
    it = make_pipeline(cfg, start_step=3)
    np.testing.assert_array_equal(next(it), c1.batch(3))
    np.testing.assert_array_equal(next(it), c1.batch(4))


def test_data_host_sharding_partitions_global_batch():
    whole = SyntheticCorpus(
        DataConfig(vocab_size=50, seq_len=16, global_batch=8, seed=1))
    parts = [SyntheticCorpus(
        DataConfig(vocab_size=50, seq_len=16, global_batch=8, seed=1,
                   n_hosts=4, host_id=h)) for h in range(4)]
    got = np.concatenate([p.batch(2) for p in parts])
    np.testing.assert_array_equal(got, whole.batch(2))


@given(st.integers(0, 30), st.integers(2, 6))
@settings(max_examples=10, deadline=None)
def test_data_tokens_in_range(step, batch):
    cfg = DataConfig(vocab_size=64, seq_len=24, global_batch=batch, seed=3)
    b = SyntheticCorpus(cfg).batch(step)
    assert b.shape == (batch, 24)
    assert b.min() >= 0 and b.max() < 64


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "step": jnp.int32(7)}}


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(tmp_path, t, step=42, extra={"note": "x"})
    assert latest_step(tmp_path) == 42
    got, step, extra = restore_checkpoint(tmp_path, t)
    assert step == 42 and extra["note"] == "x"
    np.testing.assert_array_equal(np.asarray(got["a"]), np.asarray(t["a"]))
    assert got["b"]["c"].dtype == jnp.bfloat16


def test_checkpoint_atomicity_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for s in (1, 2, 3):
        mgr.save_async(t, s)
        mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in
                   pathlib.Path(tmp_path).glob("step_*"))
    assert steps == [2, 3]
    # a leftover .tmp dir must never be picked up
    (pathlib.Path(tmp_path) / "step_00000099.tmp").mkdir()
    assert latest_step(tmp_path) == 3


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore onto a different 'mesh' (here: different sharding = None ->
    plain arrays; the reshard path is device_put with target shardings)."""
    t = {"w": jnp.arange(64.0).reshape(8, 8)}
    save_checkpoint(tmp_path, t, step=1)
    got, _, _ = restore_checkpoint(tmp_path, t, shardings=None)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(t["w"]))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_detects_dead_host():
    clock = [0.0]
    mon = HeartbeatMonitor(n_hosts=3, timeout_s=10.0,
                           clock=lambda: clock[0])
    for h in range(3):
        mon.beat(h, step=1, step_s=1.0)
    clock[0] = 5.0
    mon.beat(0, 2)
    mon.beat(1, 2)
    clock[0] = 12.0
    assert mon.dead_hosts() == [2]
    assert not mon.healthy()


def test_straggler_needs_persistence():
    s = StragglerMitigator(threshold=1.5, patience=2)
    base = {0: 1.0, 1: 1.0, 2: 1.0, 3: 2.0}
    assert s.update(base) == []                   # first strike
    assert s.update(base) == [3]                  # persistent -> flagged
    assert s.update({0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}) == []


def test_plan_rescale_keeps_model_axis():
    plan = plan_rescale(old_devices=256, lost_hosts=2, devices_per_host=8,
                        mesh_axes=(16, 16), global_batch=256,
                        restore_step=100)
    assert plan.new_mesh_shape[1] == 16            # model axis intact
    assert plan.new_devices == plan.new_mesh_shape[0] * 16
    assert plan.new_global_batch % plan.new_mesh_shape[0] == 0


def test_restart_policy_backoff():
    p = RestartPolicy(max_restarts=3, backoff_s=1.0)
    d = [p.next_delay() for _ in range(3)]
    assert d == [1.0, 2.0, 4.0]
    assert not p.should_restart()


# ---------------------------------------------------------------------------
# end-to-end: tiny training run learns; checkpoint/restart resumes exactly
# ---------------------------------------------------------------------------

def test_train_loss_decreases_and_resumes(tmp_path):
    from repro.launch.train import run
    out1 = run("mamba2-370m", smoke=True, steps=16, global_batch=4,
               seq_len=32, lr=5e-3, ckpt_dir=str(tmp_path), ckpt_every=8,
               log_every=100)
    first, last = out1["losses"][0], out1["final_loss"]
    assert last < first, (first, last)
    # resume from step 16's checkpoint... (ckpt at 8 and 16)
    out2 = run("mamba2-370m", smoke=True, steps=20, global_batch=4,
               seq_len=32, lr=5e-3, ckpt_dir=str(tmp_path), ckpt_every=8,
               log_every=100)
    assert out2["start_step"] == 16
    assert len(out2["losses"]) == 4


def test_serving_engine_batches_requests():
    from repro.launch.serve import run
    finished = run("deepseek-7b", smoke=True, n_requests=5, max_new=8,
                   max_batch=3, max_seq=64)
    assert len(finished) == 5
    assert all(1 <= len(r.out) <= 8 for r in finished)
