"""Single-device units for the hierarchy plumbing: staged-network round
counts, the log-tree partial combiner, and the hierarchical Pallas dotprod."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.glsu import n_staged_rounds
from repro.core.ring import HIERARCHIES
from repro.kernels import ops
from repro.kernels.reduction import combine_partials, dotprod_hier


def test_n_staged_rounds_matches_route_schedule():
    # n=1 runs zero ppermute rounds (the _route_buckets loop never enters);
    # the cost model must agree — this was the seed off-by-one.
    assert n_staged_rounds(1) == 0
    for n in (2, 4, 8, 16, 64):
        assert n_staged_rounds(n) == int(np.log2(n))


@pytest.mark.parametrize("C,L", [(4, 2), (2, 4), (1, 8), (8, 1), (2, 3)])
def test_combine_partials_matches_sum(C, L):
    rng = np.random.default_rng(0)
    parts = rng.integers(-100, 100, size=C * L)
    for h in HIERARCHIES:
        got = combine_partials(jnp.asarray(parts), C, L, hierarchy=h)
        assert int(got) == int(parts.sum())     # integer adds: bit-for-sum


def test_combine_partials_max():
    parts = jnp.asarray([3.0, -1.0, 7.0, 2.0, 0.0, 5.0, -9.0, 4.0])
    for h in HIERARCHIES:
        got = combine_partials(parts, 4, 2, hierarchy=h, op=jnp.maximum)
        assert float(got) == 7.0


def test_combine_partials_rejects_unknown_hierarchy():
    with pytest.raises(ValueError):
        combine_partials(jnp.zeros(8), 4, 2, hierarchy="three-level")


@pytest.mark.parametrize("C,L", [(4, 2), (2, 4)])
@pytest.mark.parametrize("hierarchy", HIERARCHIES)
def test_dotprod_hier_interpret(C, L, hierarchy):
    n = C * L
    N = n * 8 * 64
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.normal(size=N), jnp.float32)
    b = jnp.asarray(rng.normal(size=N), jnp.float32)
    got = dotprod_hier(a, b, C=C, L=L, block=64, hierarchy=hierarchy,
                       interpret=True)
    np.testing.assert_allclose(float(got), float(np.asarray(a) @ np.asarray(b)),
                               rtol=1e-4)


def test_dotprod_hier_ops_wrapper_pads():
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.normal(size=5000), jnp.float32)   # not a quantum multiple
    b = jnp.asarray(rng.normal(size=5000), jnp.float32)
    got = ops.dotprod_hier(a, b, C=2, L=2, block=64, use_pallas=True)
    np.testing.assert_allclose(float(got), float(np.asarray(a) @ np.asarray(b)),
                               rtol=1e-4)
