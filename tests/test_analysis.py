"""repro.analysis acceptance: every rule (L1-L4, S1-S3) fires on its bad
fixture and stays silent on the good twin, the suppression syntax works,
the bench schema validator accepts the recorded artifact and rejects a
mutated one, and the repo itself analyzes clean end to end.

Lint fixtures are source *strings* fed to ``lint_source`` with a crafted
relpath (the relpath decides the allow-lists), so the banned spellings
below never execute and never trip the lint on this file.  Semantic
fixtures are traced in-process — the conftest gives the main pytest
process 8 fake devices, which is all ``jax.make_jaxpr`` needs.
"""
import copy
import json
import pathlib

import jax
import jax.numpy as jnp
import pytest
from jax.experimental import pallas as pl
from jax.sharding import PartitionSpec as P

from repro import substrate
from repro.analysis import RULES, Finding
from repro.analysis.bench import validate_section
from repro.analysis.jaxpr_check import (check_collective_pricing,
                                        check_pallas_budget)
from repro.analysis.lint import lint_source
from repro.analysis.schedule_check import (check_aliasing,
                                           check_ppermute_schedules,
                                           check_ring_permutation)
from repro.core.ring import _shift_perm
from repro.sim import araxl_params
from repro.testing.subproc import run_check
from repro.topology import Topology

ROOT = pathlib.Path(__file__).resolve().parents[1]


def _rules(findings):
    return sorted({f.rule for f in findings})


# ---------------------------------------------------------------------------
# L1 — substrate-only
# ---------------------------------------------------------------------------

L1_BAD = """\
import jax

def step(x):
    return jax.lax.ppermute(x, "lane", perm=[(0, 1)])
"""

L1_GOOD = """\
from repro import substrate

def step(x):
    return substrate.ppermute(x, "lane", perm=[(0, 1)])
"""


def test_l1_fires_on_direct_jax_and_not_on_substrate():
    bad = lint_source(L1_BAD, "src/repro/parallel/foo.py")
    assert _rules(bad) == ["L1"] and bad[0].line == 4
    assert "substrate" in bad[0].hint
    assert lint_source(L1_GOOD, "src/repro/parallel/foo.py") == []
    # the allow-list: the same spelling is legal inside substrate.py itself
    assert lint_source(L1_BAD, "src/repro/substrate.py") == []


def test_l1_catches_aliased_imports_and_halo_specs():
    src = ("from jax.experimental.shard_map import shard_map as smap\n"
           "out = smap(lambda x: x, mesh=None, in_specs=(), out_specs=())\n")
    assert _rules(lint_source(src, "src/repro/core/foo.py")) == ["L1"]
    halo = ("from jax.experimental import pallas as pl\n"
            "spec = pl.BlockSpec((8,), lambda i: (i,),\n"
            "                    indexing_mode=pl.Unblocked())\n")
    assert _rules(lint_source(halo, "src/repro/kernels/foo.py")) == ["L1"]


# ---------------------------------------------------------------------------
# L2 — x64 flips + import-time env mutation in tests
# ---------------------------------------------------------------------------

L2_BAD = """\
import jax
jax.config.update("jax_enable_x64", True)
"""

L2_ENV_BAD = """\
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=64"
"""


def test_l2_fires_outside_x64_module_only():
    assert _rules(lint_source(L2_BAD, "src/repro/sim/foo.py")) == ["L2"]
    assert lint_source(L2_BAD, "src/repro/testing/x64.py") == []


def test_l2_env_mutation_in_test_modules():
    assert _rules(lint_source(L2_ENV_BAD, "tests/test_foo.py")) == ["L2"]
    # conftest is the sanctioned bootstrap
    assert lint_source(L2_ENV_BAD, "tests/conftest.py") == []
    # inside a function (not import time) is a runtime concern, not L2's
    fn = "import os\ndef setup():\n    os.environ[\"XLA_FLAGS\"] = \"x\"\n"
    assert lint_source(fn, "tests/test_foo.py") == []
    # and library code setting env at import time is L2-exempt (the rule
    # targets the test suite, where jax may already be initialised)
    assert lint_source(L2_ENV_BAD, "examples/foo.py") == []


# ---------------------------------------------------------------------------
# L3 — BENCH_*.json writes
# ---------------------------------------------------------------------------

L3_BAD = """\
import json

def save(results):
    with open("BENCH_sim.json", "w") as f:
        json.dump(results, f)
"""


def test_l3_fires_outside_benchmarks_run():
    assert _rules(lint_source(L3_BAD, "src/repro/launch/foo.py")) == ["L3"]
    assert lint_source(L3_BAD, "benchmarks/run.py") == []
    # reading the artifact is always fine
    ok = 'import json\nd = json.load(open("BENCH_sim.json"))\n'
    assert lint_source(ok, "tests/test_foo.py") == []


# ---------------------------------------------------------------------------
# L4 — wall-clock timing
# ---------------------------------------------------------------------------

L4_BAD = """\
import time

def bench(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0
"""


def test_l4_fires_outside_timing_module():
    bad = lint_source(L4_BAD, "benchmarks/foo.py")
    assert _rules(bad) == ["L4"] and [f.line for f in bad] == [4, 6]
    assert lint_source(L4_BAD, "src/repro/testing/timing.py") == []
    ok = ("from repro.testing.timing import now\n"
          "def bench(fn):\n    t0 = now()\n    fn()\n    return now() - t0\n")
    assert lint_source(ok, "benchmarks/foo.py") == []


def test_noqa_suppression_is_per_rule_and_per_line():
    src = ("import time\n"
           "t = time.time()  # boot stamp, not a measurement"
           "  # repro: noqa(L4)\n")
    assert lint_source(src, "src/repro/ft/foo.py") == []
    # a noqa for a different rule does not silence L4
    other = "import time\nt = time.time()  # repro: noqa(L1)\n"
    assert _rules(lint_source(other, "src/repro/ft/foo.py")) == ["L4"]


def test_l4_sanctioned_monotonic_facade():
    """Raw ``time.monotonic`` is still a finding, but the supervisor's
    sanctioned spelling — ``repro.testing.timing.monotonic()`` for
    liveness deadlines — passes under every aliasing."""
    raw = ("import time\n"
           "def watchdog(deadline):\n"
           "    return time.monotonic() > deadline\n")
    bad = lint_source(raw, "src/repro/ft/foo.py")
    assert _rules(bad) == ["L4"] and [f.line for f in bad] == [3]
    assert "timing.monotonic" in bad[0].hint     # hint names the facade

    direct = ("from repro.testing.timing import monotonic\n"
              "def watchdog(deadline):\n"
              "    return monotonic() > deadline\n")
    assert lint_source(direct, "src/repro/ft/foo.py") == []

    # the adversarial alias: the facade imported *as* ``time`` must not
    # fire, and a real ``time`` aliased to something else still must
    aliased = ("from repro.testing import timing as time\n"
               "def watchdog(deadline):\n"
               "    return time.monotonic() > deadline\n")
    assert lint_source(aliased, "src/repro/ft/foo.py") == []
    sneaky = ("import time as clock\n"
              "def watchdog(deadline):\n"
              "    return clock.monotonic() > deadline\n")
    assert _rules(lint_source(sneaky, "src/repro/ft/foo.py")) == ["L4"]


# ---------------------------------------------------------------------------
# S1 — collective pricing coverage
# ---------------------------------------------------------------------------

def _psum_jaxpr(mesh):
    def f(x):
        return substrate.shard_map(
            lambda v: substrate.psum(v, "cluster"), mesh=mesh,
            in_specs=P("cluster", "lane"), out_specs=P(None, "lane"))(x)
    return jax.make_jaxpr(f)(jnp.zeros((2, 4), jnp.float32))


def test_s1_fires_on_unpriced_axis_and_passes_on_declared():
    mesh = jax.make_mesh((2, 4), ("cluster", "lane"))
    closed = _psum_jaxpr(mesh)
    # the topology only declares the lane level: a psum over "cluster"
    # would be priced by the flat fallback -> finding
    topo_bad = Topology.from_levels([("lane", 4, 2.0)])
    bad = check_collective_pricing(closed, topo_bad, "fixture:s1")
    assert _rules(bad) == ["S1"] and "cluster" in bad[0].message
    # declaring both levels resolves every replica group
    topo_good = Topology.from_levels([("cluster", 2, 4.0),
                                      ("lane", 4, 2.0)])
    assert check_collective_pricing(closed, topo_good, "fixture:s1") == []


def test_s1_fires_on_mesh_topology_size_mismatch():
    mesh = jax.make_mesh((2, 4), ("cluster", "lane"))
    closed = _psum_jaxpr(mesh)
    topo = Topology.from_levels([("cluster", 4, 4.0), ("lane", 2, 2.0)])
    bad = check_collective_pricing(closed, topo, "fixture:s1")
    assert _rules(bad) == ["S1"] and "mismatch" in bad[0].message


# ---------------------------------------------------------------------------
# S2 — ring schedules + aliasing
# ---------------------------------------------------------------------------

def test_s2_permutation_checker():
    n = 8
    for shift in (1, 2, 4, 7):      # recursive doubling's gcd>1 shifts pass
        assert check_ring_permutation(_shift_perm(n, shift), n) == []
    assert any("partial ring" in p
               for p in check_ring_permutation([(0, 1)], n))
    # pairwise swap: bijective and full-ring, but shifts {1, 7} mix
    assert any("non-uniform" in p for p in check_ring_permutation(
        [(p, p ^ 1) for p in range(n)], n))
    assert any("zero shift" in p
               for p in check_ring_permutation(_shift_perm(n, 0), n))
    assert any("duplicate" in p for p in check_ring_permutation(
        [(0, 1), (0, 2)], n))


def test_s2_fires_on_partial_ring_ppermute_and_not_on_full_shift():
    mesh = jax.make_mesh((8,), ("lane",))

    def traced(perm):
        def f(x):
            return substrate.shard_map(
                lambda v: substrate.ppermute(v, "lane", perm), mesh=mesh,
                in_specs=P("lane"), out_specs=P("lane"))(x)
        return jax.make_jaxpr(f)(jnp.zeros((8,), jnp.float32))

    bad = check_ppermute_schedules(traced([(0, 1)]), "fixture:s2")
    assert _rules(bad) == ["S2"] and "deadlock" in bad[0].message
    assert check_ppermute_schedules(traced(_shift_perm(8, 1)),
                                    "fixture:s2") == []


def _copy_call(x, donate):
    def k(x_ref, o_ref):
        o_ref[...] = x_ref[...]
    return pl.pallas_call(
        k, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        input_output_aliases={0: 0} if donate else {},
        interpret=True)(x)


def test_s2_aliasing_race_detector():
    x = jnp.zeros((8, 8), jnp.float32)
    # donated input read again after the call -> in-flight race
    bad = jax.make_jaxpr(lambda x: _copy_call(x, True) + x)(x)
    fnd = check_aliasing(bad, "fixture:s2")
    assert _rules(fnd) == ["S2"] and "race" in fnd[0].message
    # same double read without donation is fine...
    assert check_aliasing(
        jax.make_jaxpr(lambda x: _copy_call(x, False) + x)(x),
        "fixture:s2") == []
    # ...and so is donation with a single consumer
    assert check_aliasing(
        jax.make_jaxpr(lambda x: _copy_call(x, True))(x),
        "fixture:s2") == []


# ---------------------------------------------------------------------------
# S3 — Pallas divisibility + VRF budget
# ---------------------------------------------------------------------------

def _block_call(x, block, grid=(1,)):
    def k(x_ref, o_ref):
        o_ref[...] = x_ref[...]
    return pl.pallas_call(
        k, grid=grid,
        in_specs=[pl.BlockSpec(block, lambda i: (0, 0))],
        out_specs=pl.BlockSpec(block, lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(block, x.dtype),
        interpret=True)(x)


def test_s3_fires_on_ragged_blocks():
    p = araxl_params(8)
    x = jnp.zeros((64, 64), jnp.float32)
    closed = jax.make_jaxpr(lambda x: _block_call(x, (48, 64)))(x)
    bad = check_pallas_budget(closed, p, "fixture:s3")
    assert _rules(bad) == ["S3"]
    assert any("not divisible" in f.message for f in bad)
    assert check_pallas_budget(
        jax.make_jaxpr(lambda x: _block_call(x, (32, 64)))(x), p,
        "fixture:s3") == []


def test_s3_fires_on_vrf_budget_busts():
    p = araxl_params(8)                       # 64 Kibit/vreg -> 64 KiB group
    x = jnp.zeros((8, 8192), jnp.float32)     # 256 KiB block: 4x the group
    bad = check_pallas_budget(
        jax.make_jaxpr(lambda x: _block_call(x, (8, 8192)))(x), p,
        "fixture:s3")
    assert _rules(bad) == ["S3"]
    assert any("register group" in f.message for f in bad)
    assert any("VRF" in f.message for f in bad)
    # the repo's own wide-row kernel clamps its block under the group
    from repro.kernels.rmsnorm import rmsnorm
    wide = jnp.zeros((64, 4096), jnp.float32)
    closed = jax.make_jaxpr(
        lambda x, g: rmsnorm(x, g, interpret=True))(wide, jnp.ones((4096,)))
    assert check_pallas_budget(closed, p, "entry:rmsnorm") == []


# ---------------------------------------------------------------------------
# bench schema validator
# ---------------------------------------------------------------------------

def test_bench_validator_accepts_recorded_artifact():
    bench = json.loads((ROOT / "BENCH_sim.json").read_text())
    for name, value in bench.items():
        assert validate_section(name, value) == [], name


def test_bench_validator_rejects_mutations():
    bench = json.loads((ROOT / "BENCH_sim.json").read_text())
    broken = copy.deepcopy(bench["coll"])
    del broken["C2L4"]["reduce"]["xla"]
    assert any("missing" in p for p in validate_section("coll", broken))
    ov = copy.deepcopy(bench["fig6_overlap_64"])
    ov["softmax"]["overlap"] = ov["softmax"]["baseline"] - 0.5
    assert any("overlap" in p
               for p in validate_section("fig6_overlap_64", ov))
    assert validate_section("mystery_section", {}) != []


# ---------------------------------------------------------------------------
# BENCH_kernels.json autotune-record schema
# ---------------------------------------------------------------------------

def test_kernels_bench_validator_accepts_recorded_artifact():
    from repro.analysis.bench import load_kernels_bench, validate_kernels_bench
    doc = load_kernels_bench(ROOT)
    assert doc is not None, "BENCH_kernels.json missing — run " \
                            "`python -m benchmarks.run kernels`"
    assert validate_kernels_bench(doc) == []


def test_kernels_bench_validator_fires():
    from repro.analysis.bench import validate_kernels_bench
    doc = json.loads((ROOT / "BENCH_kernels.json").read_text())

    # wrong schema pin
    bad = {"schema": 99, "records": doc["records"]}
    assert any("schema" in p for p in validate_kernels_bench(bad))

    # winner must be the measured_rank-0 candidate's config
    broken = copy.deepcopy(doc)
    sig, rec = sorted(broken["records"].items())[0]
    rec["winner"] = {"bogus": 1}
    assert any("winner" in p for p in validate_kernels_bench(broken))

    # model ranks must form a permutation of 0..n-1
    broken = copy.deepcopy(doc)
    sig, rec = sorted(broken["records"].items())[0]
    rec["candidates"][0]["model_rank"] = 999
    assert any("permutation" in p for p in validate_kernels_bench(broken))

    # coverage floor: >=3 kernels x >=2 shapes each
    lone = {"schema": 1, "records": {sig: copy.deepcopy(doc["records"][sig])}}
    assert any("coverage" in p for p in validate_kernels_bench(lone))


# ---------------------------------------------------------------------------
# BENCH_serve.json open-loop serving schema
# ---------------------------------------------------------------------------

def test_serve_bench_validator_accepts_recorded_artifact():
    from repro.analysis.bench import load_serve_bench, validate_serve_bench
    doc = load_serve_bench(ROOT)
    assert doc is not None, "BENCH_serve.json missing — run " \
                            "`python -m benchmarks.run serve`"
    assert validate_serve_bench(doc) == []


def test_serve_bench_validator_fires():
    from repro.analysis.bench import validate_serve_bench
    doc = json.loads((ROOT / "BENCH_serve.json").read_text())

    # wrong schema pin
    bad = {"schema": 99, "open_loop": doc["open_loop"]}
    assert any("schema" in p for p in validate_serve_bench(bad))

    # all three ablation arms are mandatory
    broken = copy.deepcopy(doc)
    del broken["open_loop"]["paged"]
    assert any("missing" in p for p in validate_serve_bench(broken))

    # percentiles must be ordered
    broken = copy.deepcopy(doc)
    rec = broken["open_loop"]["dense"]
    rec["ttft_p99_ms"] = rec["ttft_p50_ms"] - 1.0
    assert any("p99" in p for p in validate_serve_bench(broken))

    # occupancy is a fraction of slots
    broken = copy.deepcopy(doc)
    broken["open_loop"]["paged"]["occupancy"] = 1.5
    assert any("occupancy" in p for p in validate_serve_bench(broken))

    # resident KV can never exceed the declared capacity
    broken = copy.deepcopy(doc)
    rec = broken["open_loop"]["paged"]
    rec["kv_bytes_resident_peak"] = rec["kv_bytes_capacity"] + 1
    assert any("capacity" in p for p in validate_serve_bench(broken))

    # a paged arm must declare its block size
    broken = copy.deepcopy(doc)
    broken["open_loop"]["paged_chunked"]["config"]["block_tokens"] = 0
    assert any("block_tokens" in p for p in validate_serve_bench(broken))


# ---------------------------------------------------------------------------
# catalogue + repo-wide clean run
# ---------------------------------------------------------------------------

def test_rule_catalogue_and_finding_formatting():
    assert set(RULES) == {"L1", "L2", "L3", "L4", "S1", "S2", "S3"}
    f = Finding("L4", "src/x.py", 7, "boom", "use now()")
    assert str(f) == "src/x.py:7: L4: boom  [fix: use now()]"
    assert str(Finding("S1", "entry:e", 0, "m")) == "entry:e: S1: m"


def test_repo_analyzes_clean():
    """The acceptance gate: both fronts over this checkout, zero findings
    (same invocation scripts/ci.sh runs)."""
    out = run_check("repro.analysis", devices=8)
    assert "repro.analysis: clean" in out
