"""Units for the shared Topology abstraction (N-level geometry, per-level
hop pricing, the flat-vs-hierarchical tree claim, with_lanes clamping), the
regression gate that two-level parse/pricing stays byte-identical to the
PR 2 calibration in BENCH_sim.json, plus the multi-device check that the
emulator and the sim provably share one Topology value across every
8-device factorisation (two- and three-level)."""
import json
import math
import pathlib

import pytest

from repro.sim import AraXLParams, ara2_params, araxl_params, build_trace
from repro.testing.subproc import run_check
from repro.topology import (HIERARCHIES, Level, Topology, factorizations,
                            hier_name, parse_topology)

ROOT = pathlib.Path(__file__).resolve().parents[1]


# ---------------------------------------------------------------------------
# Geometry + validation
# ---------------------------------------------------------------------------

def test_topology_geometry():
    t = Topology(16, 4)
    assert t.n_lanes == 64 and t.grid == (16, 4)
    assert t.coords(0) == (0, 0)
    assert t.coords(5) == (1, 1)          # cluster-major, lane-minor
    assert t.coords(63) == (15, 3)
    assert t.cluster_of(63) == 15 and t.lane_of(63) == 3


def test_topology_validates():
    with pytest.raises(ValueError):
        Topology(0, 4)
    with pytest.raises(ValueError):
        Topology(4, 4, hierarchy="three-level")
    with pytest.raises(ValueError):
        parse_topology("sixteen-by-four")


def test_parse_topology():
    t = parse_topology("16x4:flat", cluster_axis="data", lane_axis="model")
    assert t.grid == (16, 4) and t.hierarchy == "flat"
    assert t.cluster_axis == "data" and t.lane_axis == "model"
    assert parse_topology("8x8").hierarchy == "two-level"


def test_factorizations_of_64():
    grids = factorizations(64)
    assert (16, 4) in grids and (8, 8) in grids and (4, 16) in grids
    assert all(C * L == 64 for C, L in grids)


# ---------------------------------------------------------------------------
# Per-level hop pricing
# ---------------------------------------------------------------------------

def test_hop_cost_prices_levels_differently():
    t = Topology(4, 4, intra_hop_lat=2.0, inter_hop_lat=5.0)
    # links inside a cluster are short wires; the boundary link rides RINGI
    assert t.hop_cost(0, 1) == 2.0
    assert t.hop_cost(3, 4) == 5.0        # crosses the cluster boundary
    assert t.hop_cost(15, 0) == 5.0       # the wrap link
    assert t.hop_cost(0, 4) == 3 * 2.0 + 5.0
    # flat hierarchy: every link is a long-wire ring hop
    f = t.with_hierarchy("flat")
    assert f.hop_cost(0, 1) == 5.0
    assert f.hop_cost(0, 4) == 4 * 5.0


def test_slide_cost_critical_path():
    t = Topology(4, 4, intra_hop_lat=2.0, inter_hop_lat=5.0)
    # slide-by-1 always crosses a boundary somewhere: bound by the ring hop
    assert t.slide_cost(1) == 5.0
    assert t.slide_level(1) == "inter"
    # larger slides: ceil(k/L) crossings, the rest on short wires
    assert t.slide_cost(6) == 2 * 5.0 + 4 * 2.0
    assert t.with_hierarchy("flat").slide_cost(6) == 6 * 5.0
    # single cluster: everything is intra-cluster
    one = Topology(1, 8, intra_hop_lat=2.0, inter_hop_lat=5.0)
    assert one.slide_cost(3) == 3 * 2.0
    assert one.slide_level(1) == "intra"


def test_tree_wire_cycles_hierarchy_wins():
    t = Topology(16, 4, intra_hop_lat=2.0, inter_hop_lat=4.0)
    assert t.tree_wire_cycles() < t.with_hierarchy("flat").tree_wire_cycles()


def test_traces_tag_slide_levels():
    p = araxl_params(64)
    slides = [r for r in build_trace("jacobi2d", p, 64) if r.unit == "sldu"]
    assert slides and all(r.meta["level"] == "inter" for r in slides)
    slides = [r for r in build_trace("fconv2d", ara2_params(8), 64)
              if r.unit == "sldu"]
    assert slides and all(r.meta["level"] == "intra" for r in slides)


# ---------------------------------------------------------------------------
# AraXLParams composes the Topology (and with_lanes is clamped)
# ---------------------------------------------------------------------------

def test_params_compose_topology():
    p = araxl_params(64)
    t = p.topology
    assert t == Topology(16, 4, hierarchy="two-level",
                         intra_hop_lat=p.intra_hop, inter_hop_lat=p.hop_lat)
    # interface register cuts reprice the ring hops through the same type
    assert p.with_cuts(ringi=1).topology.inter_hop_lat == p.hop_lat + 1


def test_with_lanes_clamps_tiny_configs():
    # seed bug: n_lanes < 4 kept lanes_per_cluster=4, mispricing n_clusters
    for n in (1, 2):
        p = araxl_params(n)
        assert p.lanes_per_cluster == n and p.n_clusters == 1
    assert araxl_params(2).red_tree_lat() < araxl_params(8).red_tree_lat()


def test_constructor_validates_grid():
    with pytest.raises(ValueError):
        AraXLParams(n_lanes=6, lanes_per_cluster=4)
    with pytest.raises(ValueError):
        araxl_params(64, lanes_per_cluster=5)
    # with_lanes keeps the grid consistent even for awkward totals
    p = araxl_params(64).with_lanes(6)
    assert p.n_lanes % p.lanes_per_cluster == 0


@pytest.mark.parametrize("C,L", factorizations(64))
def test_all_64_lane_factorisations_price_coherently(C, L):
    p = araxl_params(64, lanes_per_cluster=L)
    assert p.topology.grid == (C, L) and p.n_lanes == 64
    flat = p.with_hierarchy("flat")
    assert p.red_tree_lat() <= flat.red_tree_lat()
    if L > 1:            # the hierarchy strictly wins once clusters group
        assert p.red_tree_lat() < flat.red_tree_lat()
    # the log-tree term is made of the same per-level wire prices
    assert p.topology.tree_wire_cycles() <= flat.topology.tree_wire_cycles()


# ---------------------------------------------------------------------------
# N-level geometry (pods of clusters of lanes)
# ---------------------------------------------------------------------------

def test_three_level_geometry_and_labels():
    t = Topology.from_levels([("pod", 2, 8.0), ("cluster", 8, 4.0),
                              ("lane", 4, 2.0)])
    assert t.n_levels == 3 and t.shape == (2, 8, 4) and t.n_lanes == 64
    assert t.hierarchy == "three-level"
    assert t.grid == (16, 4)                  # pods fold into n_clusters
    assert t.cluster_axis == ("pod", "cluster") and t.lane_axis == "lane"
    assert t.strides() == (32, 4, 1)
    assert t.coords(37) == (1, 1, 1)
    assert t.wire_labels() == ("pod", "inter", "intra")
    assert t.hop_lat("pod") == 8.0 and t.hop_lat("intra") == 2.0


def test_three_level_link_and_slide_pricing():
    t = Topology.from_levels([("pod", 2, 8.0), ("cluster", 8, 4.0),
                              ("lane", 4, 2.0)])
    assert t.link_level(0) == "intra"         # inside a cluster
    assert t.link_level(3) == "inter"         # cluster boundary
    assert t.link_level(31) == "pod"          # pod boundary
    assert t.link_level(63) == "pod"          # the wrap link
    # slide-by-1's critical lane crosses the pod boundary
    assert t.slide_level(1) == "pod"
    # per-level critical-path decomposition: 5 hops = 1 pod + 1 cluster + 3
    assert t.slide_steps(5) == (1, 1, 3)
    assert t.slide_cost(5) == 8.0 + 4.0 + 3 * 2.0
    assert t.with_hierarchy("flat").slide_cost(5) == 5 * 8.0


def test_three_level_tree_wire_cycles():
    t = Topology.from_levels([("pod", 2, 8.0), ("cluster", 8, 4.0),
                              ("lane", 4, 2.0)])
    # one log-tree per level, each on its own wires
    assert t.tree_wire_cycles() == 1 * 8.0 + (1 + 2 + 4) * 4.0 + (1 + 2) * 2.0
    assert t.with_hierarchy("flat").tree_wire_cycles() == 63 * 8.0


def test_hierarchy_name_must_match_depth():
    assert hier_name(3) == "three-level"
    with pytest.raises(ValueError):
        Topology.from_levels([("pod", 2, 8.0), ("cluster", 8, 4.0),
                              ("lane", 4, 2.0)], hierarchy="two-level")
    with pytest.raises(ValueError):
        Topology(16, 4, hierarchy="three-level")
    # flat always parses, at any depth
    assert parse_topology("2x8x4:flat").hierarchy == "flat"


def test_parse_topology_n_level():
    t = parse_topology("2x8x4")
    assert t.shape == (2, 8, 4) and t.hierarchy == "three-level"
    assert t.axis_names == ("pod", "cluster", "lane")
    assert [l.hop_lat for l in t.levels] == [8.0, 4.0, 2.0]  # doubles outward
    t4 = parse_topology("2x2x2x8")
    assert t4.n_levels == 4 and t4.hierarchy == "four-level"
    with pytest.raises(ValueError):
        parse_topology("2x8x4", level_axes=("a", "b"))       # wrong arity


def test_level_axis_names_must_be_unique():
    with pytest.raises(ValueError):
        Topology.from_levels([("x", 2, 4.0), ("x", 2, 2.0)])


def test_params_compose_three_level_topology():
    p = araxl_params(64, lanes_per_cluster=4, n_pods=2)
    t = p.topology
    assert t.levels == (Level("pod", 2, p.pod_hop),
                        Level("cluster", 8, p.hop_lat),
                        Level("lane", 4, p.intra_hop))
    assert p.n_clusters == 16 and p.clusters_per_pod == 8
    # the hierarchy claim recurses: pods shorten the cluster log-tree
    assert p.red_tree_lat() < araxl_params(64).red_tree_lat()
    assert p.red_tree_lat() < p.with_hierarchy("flat").red_tree_lat()
    with pytest.raises(ValueError):
        araxl_params(64, lanes_per_cluster=4, n_pods=3)      # 3 !| 16


# ---------------------------------------------------------------------------
# Regression: two-level parse/pricing byte-identical to the PR 2 calibration
# ---------------------------------------------------------------------------

def test_two_level_calibration_matches_bench_sim_json():
    """The frozen BENCH_sim.json entries are the PR 2 operating points; the
    enum -> levels refactor must reproduce them bit-for-bit."""
    from repro.analysis.bench import validate_section
    bench = json.loads((ROOT / "BENCH_sim.json").read_text())
    cal = bench["red_tree_lat_64"]
    assert validate_section("red_tree_lat_64", cal) == []
    assert validate_section("fig6_grid_64", bench["fig6_grid_64"]) == []
    p = araxl_params(64)
    assert p.red_tree_lat() == cal["two-level"] == 106.0
    assert p.with_hierarchy("flat").red_tree_lat() == cal["flat"] == 286.0
    for tag, entry in bench["fig6_grid_64"].items():
        C, L = (int(x) for x in tag[1:].split("xL"))
        q = araxl_params(64, lanes_per_cluster=L)
        assert q.topology.grid == (C, L)
        assert q.red_tree_lat() == entry["red_tree_lat"], tag


def test_two_level_parse_is_byte_identical_to_legacy_ctor():
    assert parse_topology("16x4:two-level") == Topology(16, 4)
    assert parse_topology("16x4:flat") == Topology(16, 4, hierarchy="flat")
    d = Topology(16, 4).describe()
    # the PR 2 describe() keys survive (artifact compatibility)
    for key in ("n_clusters", "lanes_per_cluster", "n_lanes", "hierarchy",
                "cluster_axis", "lane_axis", "intra_hop_lat",
                "inter_hop_lat"):
        assert key in d, key
    assert d["n_clusters"] == 16 and d["intra_hop_lat"] == 2.0


# ---------------------------------------------------------------------------
# One Topology shared by emulator and sim
# ---------------------------------------------------------------------------

def test_machine_and_sim_share_topology_single_device():
    from repro.core import make_machine
    p = AraXLParams(n_lanes=1, lanes_per_cluster=1)
    m = make_machine(topology=p.topology)
    assert m.spec.topology == p.topology
    assert m.hierarchy == p.hierarchy == "two-level"


def test_make_machine_rejects_conflicting_grid():
    from repro.core import make_machine
    with pytest.raises(ValueError):
        make_machine(2, 4, topology=Topology(1, 1))
    with pytest.raises(ValueError):
        make_machine()


def test_machine_and_sim_share_topology_8dev_grid():
    """All (C, L) factorisations of the 8-device ring, both hierarchies,
    against numpy oracles — in an 8-fake-device subprocess."""
    out = run_check("repro.testing.check_topology", "8", devices=8)
    assert "check_topology OK" in out
