"""Chaos-harness coverage (`repro.ft.chaos` + `launch.train.run_chaos`):
schedule parse/round-trip/determinism, virtual-clock fault injection and
detection latency, eviction epochs, and two in-process end-to-end runs
(kill-and-rescale; restart-budget exhaustion) on the 8 fake devices."""
import numpy as np
import pytest

from repro.ft import (ChaosEvent, ChaosSchedule, FaultInjector, RescaleError,
                      VirtualClock)
from repro.ft.chaos import CKPT_CRASH, KILL, STRAGGLE


# ---------------------------------------------------------------------------
# schedule format
# ---------------------------------------------------------------------------

def test_schedule_parse_and_roundtrip():
    spec = "kill@5:h0,straggle@1:h1:x2.5:d2,ckpt_crash@5"
    sched = ChaosSchedule.parse(spec)
    assert len(sched.events) == 3
    # events are sorted by (step, kind); to_spec re-parses to itself
    assert sched.events[0] == ChaosEvent(STRAGGLE, 1, 1, 2.5, 2)
    assert sched.events[1] == ChaosEvent(CKPT_CRASH, 5)
    assert sched.events[2] == ChaosEvent(KILL, 5, 0)
    assert ChaosSchedule.parse(sched.to_spec()) == sched
    assert [e.kind for e in sched.events_at(5)] == [CKPT_CRASH, KILL]
    assert sched.events_at(3) == []


def test_schedule_parse_empty_and_whitespace():
    assert ChaosSchedule.parse("") == ChaosSchedule()
    assert ChaosSchedule.parse(" kill@2:h1 , ").events == (
        ChaosEvent(KILL, 2, 1),)


def test_schedule_parse_errors():
    with pytest.raises(ValueError, match="unknown chaos event kind"):
        ChaosSchedule.parse("explode@3:h0")
    with pytest.raises(ValueError, match="needs a :hH host"):
        ChaosSchedule.parse("kill@3")
    with pytest.raises(ValueError, match="unknown chaos event field"):
        ChaosSchedule.parse("kill@3:h0:q9")


def test_schedule_from_seed_deterministic_and_well_formed():
    kw = dict(steps=12, n_hosts=4, n_kills=2, n_straggles=2,
              n_ckpt_crashes=1)
    a = ChaosSchedule.from_seed(7, **kw)
    assert a == ChaosSchedule.from_seed(7, **kw)       # bit-reproducible
    assert ChaosSchedule.parse(a.to_spec()) == a       # spec round-trips
    kills = [e for e in a.events if e.kind == KILL]
    assert len(kills) == 2
    assert len({e.host for e in kills}) == 2           # distinct hosts
    for e in kills:
        assert 12 // 3 <= e.step <= (2 * 12) // 3      # middle window
    for e in a.events:
        if e.kind == STRAGGLE:
            assert 1 <= e.step < 12 // 2               # first half
            assert e.factor == 2.5
    # never kills the whole fleet: at most n_hosts - 1 kills
    b = ChaosSchedule.from_seed(0, steps=12, n_hosts=2, n_kills=5)
    assert len([e for e in b.events if e.kind == KILL]) == 1


# ---------------------------------------------------------------------------
# virtual clock + injector
# ---------------------------------------------------------------------------

def test_virtual_clock():
    c = VirtualClock()
    assert c() == 0.0
    assert c.advance(2.5) == 2.5
    assert c() == 2.5
    with pytest.raises(AssertionError):
        c.advance(-1.0)


def test_injector_kill_detection_latency():
    """A killed host is detected only after ``timeout_s`` of virtual time
    without beats — the steps in between are the lost work the restart
    rolls back."""
    inj = FaultInjector(ChaosSchedule.parse("kill@2:h0"), n_hosts=2,
                        timeout_s=3.5, base_step_s=1.0)
    detected_at = None
    for step in range(8):
        st = inj.tick(step)
        assert st.step_s == 1.0
        if st.dead:
            detected_at = step
            break
    # last beat at t=2 (end of tick 1); gap > 3.5 first at t=6 (tick 5)
    assert detected_at == 5
    assert st.lost == (0,)
    assert inj.failed == {0}
    assert 0 not in inj.alive


def test_injector_straggle_paces_the_spmd_step():
    """The slowest alive host paces everyone (SPMD collective wait), and
    the straggle decays after its duration."""
    inj = FaultInjector(ChaosSchedule.parse("straggle@1:h1:x3:d2"),
                        n_hosts=2, timeout_s=10.0)
    assert inj.tick(0).step_s == 1.0
    assert inj.tick(1).step_s == 3.0
    assert inj.tick(2).step_s == 3.0
    assert inj.tick(3).step_s == 1.0       # duration elapsed
    assert inj.clock() == 8.0


def test_injector_persistent_straggler_flagged_with_quorum():
    """4 hosts, one persistently 3x slower: EWMA crosses threshold x median
    and, after ``patience`` consecutive checks, the status demands
    eviction."""
    inj = FaultInjector(ChaosSchedule.parse("straggle@0:h3:x3:d50"),
                        n_hosts=4, timeout_s=1e9,
                        straggler_threshold=1.5, straggler_patience=3)
    flagged_at = None
    for step in range(20):
        st = inj.tick(step)
        if st.stragglers:
            flagged_at = step
            break
    assert flagged_at is not None
    assert st.stragglers == (3,)
    assert st.lost == (3,)


def test_injector_evict_starts_fresh_epoch():
    inj = FaultInjector(ChaosSchedule.parse("kill@1:h0"), n_hosts=4,
                        timeout_s=3.5)
    status = None
    for step in range(10):
        status = inj.tick(step)
        if status.lost:
            break
    assert status.lost == (0,)
    inj.evict(status.lost)
    assert inj.alive == {1, 2, 3}
    assert inj.failed == {0}
    assert sorted(inj.monitor.hosts) == [1, 2, 3]   # original id space
    # survivors beat from now: nobody is dead in the new epoch
    st = inj.tick(99)
    assert st.dead == ()


def test_injector_ckpt_crash_sets_tear_flag():
    inj = FaultInjector(ChaosSchedule.parse("ckpt_crash@2"), n_hosts=2)
    assert not inj.tick(0).tear_next_save
    assert inj.tick(2).tear_next_save
    assert not inj.tick(3).tear_next_save


# ---------------------------------------------------------------------------
# end-to-end: run_chaos on the 8 fake devices (small model, few steps)
# ---------------------------------------------------------------------------

def test_run_chaos_kill_restart_end_to_end(tmp_path):
    from repro.launch.train import run_chaos
    from repro.testing.x64 import x64_mode

    with x64_mode(False):
        out = run_chaos(steps=8, chaos_spec="kill@2:h0", n_hosts=2,
                        model_axis=2, global_batch=8, seq_len=32,
                        ckpt_every=4, timeout_s=3.5, base_step_s=1.0,
                        ckpt_dir=str(tmp_path), verbose=False)
    assert out["n_restarts"] == 1
    r = out["restarts"][0]
    assert r["lost_hosts"] == [0]
    assert r["detected_at_step"] == 5          # kill@2 + 3.5s timeout
    assert r["restore_step"] == 4              # ckpt_every=4 save
    assert r["new_mesh_shape"] == [2, 2]
    assert out["final_mesh_shape"] == [2, 2]
    # 6 steps before detection (0-5) + replay 4-7 after restore
    assert out["steps_executed"] == 10
    assert sorted(out["losses_by_step"]) == list(range(8))
    assert len(out["fingerprints"]) == 8
    assert all(np.isfinite(l) for l in out["losses"])


def test_run_chaos_restart_budget_exhaustion(tmp_path):
    from repro.launch.train import run_chaos
    from repro.testing.x64 import x64_mode

    with x64_mode(False), pytest.raises(RuntimeError,
                                        match="restart budget exhausted"):
        run_chaos(steps=8, chaos_spec="kill@2:h0", n_hosts=2,
                  model_axis=2, global_batch=8, seq_len=32,
                  ckpt_every=4, timeout_s=3.5, max_restarts=0,
                  ckpt_dir=str(tmp_path), verbose=False)


def test_run_chaos_killing_every_host_is_rescale_error(tmp_path):
    from repro.launch.train import run_chaos
    from repro.testing.x64 import x64_mode

    # detection sees host 0 first, but by then host 1 is dead too: the
    # survivor-device walk (over injector.failed) finds nothing to run on
    with x64_mode(False), pytest.raises(RescaleError, match="survived"):
        run_chaos(steps=8, chaos_spec="kill@2:h0,kill@3:h1", n_hosts=2,
                  model_axis=2, global_batch=8, seq_len=32,
                  ckpt_every=4, timeout_s=3.5,
                  ckpt_dir=str(tmp_path), verbose=False)


# ---------------------------------------------------------------------------
# multi-process cluster: wire protocol, spec plumbing, real-SIGKILL drill
# ---------------------------------------------------------------------------

def test_cluster_framer_reassembles_arbitrary_chunking():
    """TCP gives no frame boundaries: the framer must reassemble messages
    byte-identically however the stream is re-chunked."""
    from repro.ft.cluster import Framer, encode_msg
    msgs = [{"kind": "beat", "host": h, "n": 10 * h} for h in range(3)]
    msgs.append({"kind": "step", "step": 4, "loss": 6.5, "fp": "ab" * 8})
    wire = b"".join(encode_msg(m) for m in msgs)
    for chunk in (1, 7, len(wire)):
        f, got = Framer(), []
        for i in range(0, len(wire), chunk):
            got.extend(f.feed(wire[i:i + chunk]))
        assert got == msgs, f"chunk={chunk}"


def test_cluster_worker_spec_roundtrip():
    from repro.ft.cluster import ROLE_PRIMARY, WorkerSpec
    spec = WorkerSpec(host=1, n_hosts=4, port=5555, role=ROLE_PRIMARY,
                      devices_per_host=2, model_axis=2, steps=10, seed=3,
                      ckpt_dir="/tmp/x", failed=[2, 3], fence_steps=[4],
                      ckpt_hold_step=8)
    assert WorkerSpec.from_json(spec.to_json()) == spec


def test_cluster_supervisor_rejects_bad_geometry_and_straggles(tmp_path):
    from repro.ft.cluster import ClusterSupervisor
    with pytest.raises(ValueError, match="not divisible"):
        ClusterSupervisor(n_hosts=3, n_devices=8)
    with pytest.raises(ValueError, match="model axis"):
        ClusterSupervisor(n_hosts=8, n_devices=8, model_axis=2)
    # real processes cannot be slowed deterministically: straggle events
    # stay virtual-clock-only
    with pytest.raises(ValueError, match="virtual-clock-only"):
        ClusterSupervisor(chaos_spec="straggle@1:h1:x2.5:d2",
                          ckpt_dir=str(tmp_path), logdir=str(tmp_path))


def test_cluster_ckpt_crash_maps_to_next_save(tmp_path):
    """A ckpt_crash@S tears the first checkpoint written strictly after
    step S (tear-next-save, matching the virtual injector), and is
    consumed once delivered."""
    from repro.ft.cluster import ClusterSupervisor
    sup = ClusterSupervisor(chaos_spec="ckpt_crash@5", ckpt_every=4,
                            ckpt_dir=str(tmp_path), logdir=str(tmp_path))
    sup._pending = list(sup.schedule.events)
    assert sup._next_hold_step() == 8
    sup._consume_ckpt_crash()
    assert sup._next_hold_step() is None


def test_cluster_drill_detects_real_sigkill_via_socket():
    """End-to-end liveness path with no jax in the workers: spawn real
    standby processes, SIGKILL one, and require the supervisor to notice
    via missed socket heartbeats — never before the deadline, and within
    generous slack for a loaded CI box."""
    from repro.ft.cluster import drill
    out = drill(n_workers=2, kill_host=1, timeout_s=0.6,
                beat_interval_s=0.05)
    assert out["dead"] == [1]
    assert 0.5 < out["detect_s"] < 60.0, out
