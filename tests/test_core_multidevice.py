"""Multi-device correctness of the AraXL core (ring, GLSU, ISA, kernels).

Each test spawns a subprocess with 8 fake CPU devices (the main pytest
process keeps 1 device, as mandated)."""
import pytest

from repro.testing.subproc import run_check


@pytest.mark.parametrize("C,L", [(4, 2), (2, 4)])
def test_core_isa_all_modes(C, L):
    out = run_check("repro.testing.check_core", str(C), str(L), devices=8)
    assert "check_core OK" in out
