"""Checkpoint fault-tolerance coverage (`repro.checkpoint.ckpt`):
sync + async round-trips, restore onto a *smaller* mesh via re-derived
shardings, torn-write detection (a corrupted newest step is skipped in
favour of the previous durable one), simulated mid-write crashes, and
retention over valid steps only."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, SimulatedCrash, latest_step,
                              restore_checkpoint, save_checkpoint,
                              tear_checkpoint, valid_steps)
from repro.ft import plan_rescale, rescale_rules
from repro.parallel.sharding import PV, param_shardings


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(4,)).astype(np.float32)),
        "half": jnp.asarray(rng.normal(size=(4, 4))).astype(jnp.bfloat16),
        "step": jnp.asarray(3, jnp.int32),
    }


def _assert_trees_equal(got, want):
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert g.dtype == w.dtype
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# ---------------------------------------------------------------------------
# round trips
# ---------------------------------------------------------------------------

def test_sync_round_trip(tmp_path):
    tree = _tree()
    save_checkpoint(tmp_path, tree, step=7, extra={"data_cursor": 7})
    got, step, extra = restore_checkpoint(tmp_path, tree)
    assert step == 7
    assert extra == {"data_cursor": 7}
    _assert_trees_equal(got, tree)                 # incl. bf16 leaf bitwise


def test_async_round_trip_and_retention(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    trees = {s: _tree(seed=s) for s in (1, 2, 3)}
    for s in (1, 2, 3):
        mgr.save_async(trees[s], step=s)
    mgr.wait()
    assert valid_steps(tmp_path) == [2, 3]         # keep=2 pruned step 1
    got, step, _ = restore_checkpoint(tmp_path, trees[3])
    assert step == 3
    _assert_trees_equal(got, trees[3])


def test_manifest_records_leaf_sizes(tmp_path):
    d = save_checkpoint(tmp_path, _tree(), step=0)
    manifest = json.loads((d / "manifest.json").read_text())
    for i, meta in enumerate(manifest["leaves"]):
        f = d / f"leaf_{i:05d}.npy"
        assert meta["nbytes"] == f.stat().st_size


# ---------------------------------------------------------------------------
# torn writes + simulated crashes
# ---------------------------------------------------------------------------

def test_torn_checkpoint_is_skipped(tmp_path):
    trees = {s: _tree(seed=s) for s in (1, 2)}
    for s in (1, 2):
        save_checkpoint(tmp_path, trees[s], step=s)
    assert latest_step(tmp_path) == 2
    tear_checkpoint(tmp_path, step=2)              # truncate a leaf file
    assert valid_steps(tmp_path) == [1]
    assert latest_step(tmp_path) == 1
    # step=None restores the previous durable step, not the torn one
    got, step, _ = restore_checkpoint(tmp_path, trees[1])
    assert step == 1
    _assert_trees_equal(got, trees[1])
    # asking for the torn step explicitly is a loud error naming survivors
    with pytest.raises(ValueError, match=r"torn.*valid steps: \[1\]"):
        restore_checkpoint(tmp_path, trees[2], step=2)


def test_simulated_crash_leaves_only_tmp(tmp_path):
    save_checkpoint(tmp_path, _tree(seed=1), step=1)
    with pytest.raises(SimulatedCrash):
        save_checkpoint(tmp_path, _tree(seed=2), step=2,
                        crash_after_leaves=1)
    names = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert names == ["step_00000001", "step_00000002.tmp"]
    assert latest_step(tmp_path) == 1              # readers never see .tmp
    # a retried save of the same step succeeds over the stale .tmp
    save_checkpoint(tmp_path, _tree(seed=2), step=2)
    assert latest_step(tmp_path) == 2


def test_gc_keeps_durable_over_newer_torn(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=1)
    for s in (1, 2):
        save_checkpoint(tmp_path, _tree(seed=s), step=s)
    tear_checkpoint(tmp_path, step=2)
    mgr._gc()
    # retention counts valid steps only: the torn 2 must not evict 1,
    # and torn dirs older than the newest durable step are removed
    assert valid_steps(tmp_path) == [1]
    save_checkpoint(tmp_path, _tree(seed=3), step=3)
    mgr._gc()
    assert valid_steps(tmp_path) == [3]
    assert not (pathlib.Path(tmp_path) / "step_00000002").exists()


def test_empty_dir_has_no_latest(tmp_path):
    assert latest_step(tmp_path) is None
    assert valid_steps(tmp_path) == []


# ---------------------------------------------------------------------------
# elastic restore onto a smaller mesh (8 fake devices)
# ---------------------------------------------------------------------------

def test_restore_onto_smaller_mesh(tmp_path):
    from jax.sharding import Mesh

    defs = {"w": PV((16, 8), jnp.float32, ("fsdp", "model")),
            "b": PV((8,), jnp.float32, ("model",))}
    devices = jax.devices()
    big = Mesh(np.array(devices).reshape(4, 2), ("data", "model"))
    from repro.parallel.sharding import default_rules
    big_rules = default_rules(big, batch=8)

    rng = np.random.default_rng(0)
    vals = {k: rng.normal(size=d.shape).astype(np.float32)
            for k, d in defs.items()}
    big_sh = param_shardings(defs, big_rules)
    placed = {k: jax.device_put(vals[k], big_sh[k]) for k in defs}
    save_checkpoint(tmp_path, placed, step=5)

    # host 0 (devices 0-3) dies: re-derive shardings on the survivor mesh
    plan = plan_rescale(old_devices=8, lost_hosts=1, devices_per_host=4,
                        mesh_axes=(4, 2), global_batch=8, restore_step=5)
    mesh, rules = rescale_rules(plan, [0], 4)
    small_sh = param_shardings(defs, rules)
    like = {k: jax.ShapeDtypeStruct(d.shape, d.dtype)
            for k, d in defs.items()}
    got, step, _ = restore_checkpoint(tmp_path, like, shardings=small_sh)

    assert step == 5
    for k in defs:
        np.testing.assert_array_equal(np.asarray(got[k]), vals[k])
        used = {d.id for d in got[k].sharding.device_set}
        assert used <= {4, 5, 6, 7}, f"{k} landed on a dead host: {used}"
    assert dict(got["w"].sharding.mesh.shape) == {"data": 2, "model": 2}


# ---------------------------------------------------------------------------
# crash-atomic writes (real-SIGKILL torn states, not just simulated ones)
# ---------------------------------------------------------------------------

def test_truncated_manifest_is_invalid(tmp_path):
    """A manifest cut mid-byte (power loss after rename, before the data
    hit disk) must fail the validity gate, not crash restore."""
    save_checkpoint(tmp_path, _tree(), step=1)
    save_checkpoint(tmp_path, _tree(1), step=2)
    man = tmp_path / "step_00000002" / "manifest.json"
    man.write_bytes(man.read_bytes()[: len(man.read_bytes()) // 2])
    assert valid_steps(tmp_path) == [1]
    assert latest_step(tmp_path) == 1


def test_renamed_but_unsynced_leaf_is_invalid(tmp_path):
    """Model the rename-durable-but-data-lost window: the leaf file name
    exists (dir entry synced) but its bytes were never flushed, so the
    file is empty.  The byte-size gate must reject the step."""
    save_checkpoint(tmp_path, _tree(), step=1)
    save_checkpoint(tmp_path, _tree(1), step=2)
    (tmp_path / "step_00000002" / "leaf_00000.npy").write_bytes(b"")
    assert valid_steps(tmp_path) == [1]


def test_after_leaf_hook_sees_durable_prefix(tmp_path):
    """``after_leaf(i)`` fires only once leaf ``i`` is published: at each
    callback the staging dir holds exactly leaves 0..i and no manifest —
    the window where a SIGKILL produces a torn (and rejected) step."""
    tree = _tree()
    n = len(jax.tree.leaves(tree))
    seen = []

    def hook(i):
        stage = tmp_path / "step_00000001.tmp"
        leaves = sorted(p.name for p in stage.glob("leaf_*.npy"))
        assert leaves == [f"leaf_{j:05d}.npy" for j in range(i + 1)]
        assert not (stage / "manifest.json").exists()
        assert not list(stage.glob("*.part")), "unpublished temp visible"
        seen.append(i)

    save_checkpoint(tmp_path, tree, step=1, after_leaf=hook)
    assert seen == list(range(n))
    assert valid_steps(tmp_path) == [1]


def test_publish_leaves_no_part_turds(tmp_path):
    """Every file goes through the .part-then-replace protocol; after a
    clean save no temp names survive anywhere under the step dir."""
    save_checkpoint(tmp_path, _tree(), step=3)
    assert not list(tmp_path.rglob("*.part"))
    assert not list(tmp_path.glob("*.tmp"))
    got, step, _ = restore_checkpoint(tmp_path, _tree())
    assert step == 3
    _assert_trees_equal(got, _tree())
