"""Simulator engine unit tests + ISA/trace surface coherence."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import isa_kernels
from repro.core.isa import InstrRecord
from repro.sim import TraceMachine, araxl_params, build_trace, simulate


def test_isa_kernels_run_on_trace_machine():
    """The six paper kernels are written once against the machine surface;
    they must also run data-free on the TraceMachine (duck typing)."""
    v = TraceMachine()
    n = 64
    A, B = np.zeros((2, 3)), np.zeros((3, 4 * n))
    isa_kernels.fmatmul(v, A, B)
    isa_kernels.fdotproduct(v, np.zeros(4 * n), np.zeros(4 * n))
    isa_kernels.jacobi2d(v, np.zeros((4, 4 * n)))
    isa_kernels.fconv2d(v, np.zeros((5, 4 * n)), np.zeros((3, 3)))
    isa_kernels.vexp(v, np.zeros(4 * n))
    isa_kernels.softmax(v, np.zeros((2, 4 * n)))
    ops = {r.op for r in v.trace}
    assert {"vfmacc.vf", "vfredsum", "vfredmax", "vfslide1down",
            "vexp(poly)", "vle64.v", "vse64.v"} <= ops


def test_single_instruction_timing():
    p = araxl_params(64)
    r = simulate([InstrRecord("vfadd", 6400, "fpu", 1.0,
                              {"out": 1, "deps": ()})], p)
    assert r.fpu_busy == 100          # ceil(6400/64)
    assert r.cycles >= 100
    assert r.flops == 6400


def test_dependent_chain_streams():
    """Chained dependent ops overlap (start offset = chain_lat), they do not
    serialize at full duration."""
    p = araxl_params(64)
    recs = [InstrRecord("vfadd", 64 * 100, "fpu", 1.0, {"out": 1, "deps": ()}),
            InstrRecord("vfmul", 64 * 100, "fpu", 1.0, {"out": 2, "deps": (1,)})]
    r = simulate(recs, p)
    assert r.cycles < 2 * 100 + 3 * p.chain_lat + 2 * p.issue_gap + 1


def test_reduction_blocks_consumer():
    """A reduction's scalar result is only available after the log-tree."""
    p = araxl_params(64)
    recs = [InstrRecord("vfredsum", 6400, "redu", 1.0, {"out": 1, "deps": ()}),
            InstrRecord("vfadd", 6400, "fpu", 1.0, {"out": 2, "deps": (1,)})]
    r = simulate(recs, p)
    assert r.cycles >= 2 * 100 + p.red_tree_lat()


@given(st.sampled_from(["fmatmul", "fconv2d", "jacobi2d", "fdotproduct",
                        "exp", "softmax"]),
       st.sampled_from([8, 16, 32, 64]),
       st.sampled_from([64, 128, 256, 512]))
@settings(max_examples=25, deadline=None)
def test_utilization_bounded_and_monotone_properties(kernel, lanes, bpl):
    """Invariants: 0 < util <= 1; adding interface latency never *helps*."""
    p = araxl_params(lanes)
    r = simulate(build_trace(kernel, p, bpl), p)
    assert 0.0 < r.utilization <= 1.0
    cut = p.with_cuts(glsu=4, reqi=1, ringi=1)
    rc = simulate(build_trace(kernel, cut, bpl), cut)
    assert rc.cycles >= r.cycles * 0.999
    assert rc.flops == r.flops
