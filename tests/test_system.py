"""End-to-end behaviour of the paper's system (core machine on 1 device).

The full multi-device behaviour is covered by the subprocess checks
(test_core_multidevice / test_ring_attention / test_moe_multidevice); this
exercises the degenerate 1x1 machine so the public API contract holds on
any device count.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import isa_kernels, make_machine
from repro.sim import TraceMachine, araxl_params, simulate


def test_single_lane_machine_end_to_end():
    v = make_machine(1, 1, vlen_bits=8192, dtype=jnp.float32)
    x = np.arange(64, dtype=np.float32)
    r = v.vle(x)
    np.testing.assert_allclose(np.asarray(v.vse(r)), x)
    np.testing.assert_allclose(float(v.vredsum(r)), x.sum())
    got = np.asarray(v.vse(v.vslide1down(r, fill=0.0)))
    np.testing.assert_allclose(got, np.concatenate([x[1:], [0.0]]))
    S = np.random.default_rng(0).normal(size=(2, 64))
    sm = isa_kernels.softmax(v, S)
    np.testing.assert_allclose(np.asarray(sm).sum(-1), 1.0, rtol=1e-5)


def test_isa_to_sim_pipeline():
    """The same kernel source drives both execution and the cycle model."""
    tv = TraceMachine()
    isa_kernels.fmatmul(tv, np.zeros((4, 8)), np.zeros((8, 64 * 16)))
    p = araxl_params(64)
    res = simulate(tv.trace, p)
    assert res.cycles > 0
    assert 0 < res.utilization <= 1.0
    assert res.flops == 2 * 4 * 8 * 64 * 16      # 2 FLOP per FMA element
