"""Unit + property coverage for the fault-tolerance stack
(`repro.ft.resilience`): heartbeat timeout semantics at the boundary,
straggler EWMA x patience interplay, restart backoff budgets, and the
rescale arithmetic for every lost-host count on 1-8 hosts."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.ft import (HeartbeatMonitor, RescaleError, RestartPolicy,
                      StragglerMitigator, plan_rescale, rescale_rules,
                      survivor_devices)


# ---------------------------------------------------------------------------
# HeartbeatMonitor
# ---------------------------------------------------------------------------

class FakeClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t


def test_heartbeat_timeout_boundary_is_strict():
    """Dead means *strictly* older than timeout: a beat exactly timeout
    seconds ago is still alive (slowness is the straggler path's job)."""
    clock = FakeClock()
    mon = HeartbeatMonitor(n_hosts=2, timeout_s=10.0, clock=clock)
    mon.beat(0, step=1)
    mon.beat(1, step=1)
    clock.t = 10.0
    assert mon.dead_hosts() == []              # == timeout: alive
    assert mon.healthy()
    clock.t = 10.0 + 1e-9
    assert mon.dead_hosts() == [0, 1]          # > timeout: dead
    assert not mon.healthy()


def test_heartbeat_beat_after_death_revives():
    clock = FakeClock()
    mon = HeartbeatMonitor(n_hosts=2, timeout_s=5.0, clock=clock)
    clock.t = 20.0
    assert mon.dead_hosts() == [0, 1]
    mon.beat(0, step=3)                        # zombie reports in
    assert mon.dead_hosts() == [1]
    assert not mon.healthy()
    mon.beat(1, step=3)
    assert mon.healthy()


def test_heartbeat_explicit_host_ids():
    """The survivor fleet after a rescale keeps original host ids."""
    clock = FakeClock()
    mon = HeartbeatMonitor(hosts={1, 3}, timeout_s=5.0, clock=clock)
    assert sorted(mon.hosts) == [1, 3]
    clock.t = 6.0
    assert mon.dead_hosts() == [1, 3]
    with pytest.raises(AssertionError):
        HeartbeatMonitor(n_hosts=2, hosts={0, 1})   # exactly one spelling
    with pytest.raises(AssertionError):
        HeartbeatMonitor()


def test_heartbeat_ewma_tracks_step_time():
    clock = FakeClock()
    mon = HeartbeatMonitor(n_hosts=1, timeout_s=5.0, clock=clock)
    mon.beat(0, step=0, step_s=2.0)
    assert mon.hosts[0].ewma_step_s == 2.0     # first sample seeds the EWMA
    mon.beat(0, step=1, step_s=4.0)
    assert mon.hosts[0].ewma_step_s == pytest.approx(0.9 * 2.0 + 0.1 * 4.0)


# ---------------------------------------------------------------------------
# StragglerMitigator
# ---------------------------------------------------------------------------

def test_straggler_threshold_times_patience_interplay():
    """A host must exceed threshold x median for ``patience`` *consecutive*
    checks; any dip below resets the strike counter to zero."""
    s = StragglerMitigator(threshold=1.5, patience=3)
    slow = {0: 1.0, 1: 1.0, 2: 1.0, 3: 2.0}
    fast = {0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}
    assert s.update(slow) == []                # strike 1
    assert s.update(slow) == []                # strike 2
    assert s.update(fast) == []                # recovered: counter resets
    assert s.update(slow) == []                # strike 1 again
    assert s.update(slow) == []
    assert s.update(slow) == [3]               # patience reached
    assert s.update(slow) == [3]               # still flagged while slow


def test_straggler_threshold_is_strict_and_median_based():
    s = StragglerMitigator(threshold=2.0, patience=1)
    # exactly threshold x median is NOT a straggler (strict >)
    assert s.update({0: 1.0, 1: 1.0, 2: 1.0, 3: 2.0}) == []
    assert s.update({0: 1.0, 1: 1.0, 2: 1.0, 3: 2.0 + 1e-9}) == [3]
    # zero EWMAs (no samples yet) are ignored entirely
    assert s.update({0: 0.0, 1: 0.0}) == []


@given(st.integers(3, 8), st.integers(1, 4))
@settings(max_examples=20)
def test_straggler_patience_property(n_hosts, patience):
    """Exactly ``patience`` consecutive slow checks flag; patience-1 do
    not.  (3+ hosts: see the two-host quirk below.)"""
    s = StragglerMitigator(threshold=1.5, patience=patience)
    ewma = {h: 1.0 for h in range(n_hosts)}
    ewma[0] = 10.0
    for _ in range(patience - 1):
        assert 0 not in s.update(ewma)
    assert 0 in s.update(ewma)


def test_straggler_two_host_fleet_never_evicts():
    """With 2 hosts the upper median IS the slow host's own EWMA, so no
    host can exceed threshold x median: a 2-host fleet tolerates any
    straggle (eviction needs a quorum of fast hosts to define 'normal')."""
    s = StragglerMitigator(threshold=1.5, patience=1)
    for _ in range(5):
        assert s.update({0: 1.0, 1: 100.0}) == []


# ---------------------------------------------------------------------------
# RestartPolicy
# ---------------------------------------------------------------------------

def test_restart_policy_backoff_sequence_and_cap():
    p = RestartPolicy(max_restarts=12, backoff_s=5.0)
    delays = [p.next_delay() for _ in range(9)]
    assert delays[:6] == [5.0, 10.0, 20.0, 40.0, 80.0, 160.0]
    assert delays[6:] == [300.0, 300.0, 300.0]      # capped at 5 min
    assert p.restarts == 9


def test_restart_policy_exhaustion():
    p = RestartPolicy(max_restarts=2, backoff_s=1.0)
    assert p.should_restart()
    p.next_delay()
    assert p.should_restart()
    p.next_delay()
    assert not p.should_restart()               # budget spent
    # next_delay still advances (callers must gate on should_restart)
    assert p.next_delay() == 4.0


def test_restart_policy_custom_cap_and_overflow_safety():
    """The cap is configurable, and the exponent is clamped so a long-
    running supervisor at restart #5000 gets the cap, not OverflowError."""
    p = RestartPolicy(max_restarts=10, backoff_s=0.5, max_backoff_s=10.0)
    assert p.next_delay() == 0.5
    delays = [p.next_delay() for _ in range(7)]
    assert delays == [1.0, 2.0, 4.0, 8.0, 10.0, 10.0, 10.0]
    p.restarts = 5000                          # way past float overflow
    assert p.next_delay() == 10.0


def test_restart_policy_exhaustion_at_exact_budget():
    """should_restart flips False exactly when the budget is spent, not
    one restart early or late."""
    p = RestartPolicy(max_restarts=3, backoff_s=1.0)
    used = 0
    while p.should_restart():
        p.next_delay()
        used += 1
    assert used == 3
    assert p.restarts == 3


def test_restart_policy_zero_budget():
    """max_restarts=0 means fail fast: never restart, and the first
    delay (if a caller ignores the gate) is just the base backoff."""
    p = RestartPolicy(max_restarts=0, backoff_s=1.0)
    assert not p.should_restart()
    assert p.next_delay() == 1.0


# ---------------------------------------------------------------------------
# plan_rescale: device-count arithmetic for every lost-host count, 1-8 hosts
# ---------------------------------------------------------------------------

def test_plan_rescale_exhaustive_1_to_8_hosts():
    gb = 24                                    # divisible by 1, 2, 3, 4, 6
    for n_hosts in range(1, 9):
        for dph in (1, 2, 4):
            old = n_hosts * dph
            model = 2 if old % 2 == 0 else 1
            mesh_axes = (old // model, model)
            for lost in range(0, n_hosts + 1):
                remaining = old - lost * dph
                if remaining < model or remaining <= 0:
                    with pytest.raises(RescaleError):
                        plan_rescale(old, lost, dph, mesh_axes, gb,
                                     restore_step=7)
                    continue
                plan = plan_rescale(old, lost, dph, mesh_axes, gb,
                                    restore_step=7)
                dp = remaining // model
                assert plan.new_mesh_shape == (dp, model)
                assert plan.new_devices == dp * model
                assert plan.new_devices <= remaining
                assert plan.new_mesh_shape[-1] == model      # axis intact
                assert plan.new_global_batch % dp == 0
                assert plan.new_global_batch <= gb
                assert plan.restore_step == 7
                assert plan.old_devices == old


def test_plan_rescale_no_survivors_error_message():
    with pytest.raises(RescaleError, match="no survivors"):
        plan_rescale(old_devices=8, lost_hosts=2, devices_per_host=4,
                     mesh_axes=(4, 2), global_batch=8, restore_step=0)
    with pytest.raises(RescaleError, match="model axis"):
        plan_rescale(old_devices=8, lost_hosts=1, devices_per_host=4,
                     mesh_axes=(1, 8), global_batch=8, restore_step=0)


def test_plan_rescale_batch_shrinks_to_divisible():
    # 8 hosts x 1 device, model=2, lose 2 -> dp=3; gb 8 -> 6
    plan = plan_rescale(old_devices=8, lost_hosts=2, devices_per_host=1,
                        mesh_axes=(4, 2), global_batch=8, restore_step=3)
    assert plan.new_mesh_shape == (3, 2)
    assert plan.new_global_batch == 6
    assert "8->6" in plan.notes


# ---------------------------------------------------------------------------
# rescale -> rules plumbing (8 fake devices)
# ---------------------------------------------------------------------------

def test_survivor_devices_drops_whole_host_blocks():
    devs = list(range(8))                      # stand-in device handles
    assert survivor_devices([0], 4, devs) == [4, 5, 6, 7]
    assert survivor_devices([1], 2, devs) == [0, 1, 4, 5, 6, 7]
    assert survivor_devices([0, 3], 2, devs) == [2, 3, 4, 5]
    assert survivor_devices([], 4, devs) == devs


def test_rescale_rules_rederives_shardings_on_survivor_mesh():
    import jax

    plan = plan_rescale(old_devices=8, lost_hosts=1, devices_per_host=4,
                        mesh_axes=(4, 2), global_batch=8, restore_step=4)
    mesh, rules = rescale_rules(plan, [0], 4)
    assert dict(mesh.shape) == {"data": 2, "model": 2}
    # the survivor mesh is built from host 1's devices, not renumbered
    assert [d.id for d in mesh.devices.flat] == [4, 5, 6, 7]
    assert rules.mesh is mesh
    # logical rules re-derived, not migrated: same table as default_rules
    assert rules.rules["model"] == "model"
    assert rules.rules["batch"] == ("data",)
    spec = rules.spec(("fsdp", "model"))
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_rescale_rules_insufficient_survivors():
    plan = plan_rescale(old_devices=8, lost_hosts=1, devices_per_host=4,
                        mesh_axes=(4, 2), global_batch=8, restore_step=0)
    with pytest.raises(RescaleError, match="survived"):
        rescale_rules(plan, [0, 1], 4)         # plan said 1 lost, 2 died
