"""Two-level vs flat vs XLA-native collectives against numpy oracles,
on 8 fake devices in a subprocess, for both C·L factorizations."""
import pytest

from repro.testing.subproc import run_check


@pytest.mark.parametrize("C,L", [(4, 2), (2, 4)])
def test_two_level_collectives_match_oracle(C, L):
    out = run_check("repro.testing.check_collectives", str(C), str(L),
                    devices=8)
    assert "check_collectives OK" in out
    # every variant row must have validated against its oracle
    rows = [l for l in out.splitlines() if l.startswith("coll/")]
    assert len(rows) >= 11 and all(r.endswith(",ok") for r in rows), rows
