"""Ring attention (sequence-parallel RINGI) correctness, in a subprocess."""
from repro.testing.subproc import run_check


def test_ring_attention_matches_reference():
    out = run_check("repro.testing.check_ring_attention", "8", devices=8)
    assert "check_ring_attention OK" in out
