"""HLO -> topology-level mapper + per-level collective pricing.

Synthetic-HLO units pin the replica-group parser (iota and explicit forms,
-start/-done pairs, multi-axis groups, collective-permute pairs) and the
per-level byte attribution; the fixture tests replay a *recorded* smoke
dry-run (tests/data/) and assert the flat-vs-hierarchical ``collective_s``
pricing reproduces bit for bit from the stored wire bytes — the launch
layer's analogue of the frozen ``red_tree_lat_64`` sim calibration.
"""
import json
import math
import pathlib

import pytest

from repro.roofline.analysis import (HW, collective_bytes,
                                     collective_level_bytes,
                                     group_level_extents, level_wire_seconds,
                                     parse_collectives, wire_seconds)
from repro.topology import Level, Topology

DATA = pathlib.Path(__file__).parent / "data"

#: the production three-level machine (2 pods x 16 clusters x 16 lanes)
TOPO512 = Topology.from_levels([("pod", 2, 8.0), ("data", 16, 4.0),
                                ("model", 16, 2.0)])


def _topo_from_describe(d: dict) -> Topology:
    return Topology.from_levels(
        [Level(tuple(l["axis"]) if isinstance(l["axis"], list) else l["axis"],
               l["size"], l["hop_lat"], l["wire_bw"]) for l in d["levels"]],
        hierarchy=d["hierarchy"])


# ---------------------------------------------------------------------------
# Parser: replica group forms
# ---------------------------------------------------------------------------

def test_parse_iota_groups_contiguous():
    hlo = ("  ag = bf16[512]{0} all-gather(bf16[32]{0} p), "
           "replica_groups=[32,16]<=[512], dimensions={0}")
    (c,) = parse_collectives(hlo)
    assert c["kind"] == "all-gather" and c["group"] == 16
    assert c["members"] == tuple(range(16))
    assert c["bytes"] == 512 * 2


def test_parse_iota_groups_transposed():
    hlo = ("  ar = f32[128]{0} all-reduce(f32[128]{0} q), "
           "replica_groups=[16,32]<=[32,16]T(1,0)")
    (c,) = parse_collectives(hlo)
    # transpose: the first group strides by 16 — the (pod, data) ring
    assert c["group"] == 32
    assert c["members"] == tuple(range(0, 512, 16))


def test_parse_explicit_groups_and_pairs():
    hlo = """
  rs = f32[64]{0} reduce-scatter(f32[256]{0} s), replica_groups={{0,1,2,3},{4,5,6,7}}
  cp = f32[64]{0} collective-permute(f32[64]{0} r), source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
"""
    rs, cp = parse_collectives(hlo)
    assert rs["members"] == (0, 1, 2, 3) and rs["group"] == 4
    assert cp["pairs"] == ((0, 1), (1, 2), (2, 3), (3, 0))


def test_parse_start_done_counted_once():
    hlo = """
  ags = bf16[512]{0} all-gather-start(bf16[32]{0} p), replica_groups=[32,16]<=[512], dimensions={0}
  agd = bf16[512]{0} all-gather-done(bf16[512]{0} ags)
"""
    colls = parse_collectives(hlo)
    assert len(colls) == 1 and colls[0]["kind"] == "all-gather"


# ---------------------------------------------------------------------------
# Level extents
# ---------------------------------------------------------------------------

def test_group_extents_single_axis():
    # model-axis group: 16 contiguous ids inside one cluster
    assert group_level_extents(tuple(range(16)), TOPO512) == (1, 1, 16)
    # data-axis group: stride 16 inside one pod
    assert group_level_extents(tuple(range(0, 256, 16)), TOPO512) \
        == (1, 16, 1)
    # pod-axis group: stride 256
    assert group_level_extents((0, 256), TOPO512) == (2, 1, 1)


def test_group_extents_multi_axis():
    # (pod, data) joint group — the fsdp/batch ring of the 2x16x16 mesh
    assert group_level_extents(tuple(range(0, 512, 16)), TOPO512) \
        == (2, 16, 1)
    # everything
    assert group_level_extents(tuple(range(512)), TOPO512) == (2, 16, 16)


def test_degenerate_inputs_fall_back_conservatively():
    # duplicate ids (malformed HLO): flat ring at the outermost level,
    # never a crash
    assert group_level_extents((0, 0), TOPO512) == (2, 1, 1)
    # permute pairs outside the topology (mesh mismatch): charged to the
    # outermost (long) wires, mirroring the grouped-collective fallback
    hlo = ("  cp = f32[64]{0} collective-permute(f32[64]{0} r), "
           "source_target_pairs={{600,601},{0,1}}")
    lv = collective_level_bytes(parse_collectives(hlo), TOPO512)
    assert lv["pod"] == pytest.approx(256 / 2)
    assert lv["intra"] == pytest.approx(256 / 2)


def test_group_extents_non_aligned_falls_back_outermost():
    # not an axis-aligned subgrid: 3 ids spanning data; falls back to a
    # flat ring over the whole group at the outermost spanned level
    ext = group_level_extents((0, 16, 32), TOPO512)
    assert ext == (1, 3, 1)            # still a subgrid: 3 data coords
    ext = group_level_extents((0, 16, 17), TOPO512)   # 2 data x ragged lane
    assert ext == (1, 3, 1)


# ---------------------------------------------------------------------------
# Per-level byte attribution
# ---------------------------------------------------------------------------

def test_level_bytes_conserved_and_attributed():
    hlo = """
  ag = bf16[512]{0} all-gather(bf16[32]{0} p), replica_groups=[32,16]<=[512], dimensions={0}
  ar = f32[128]{0} all-reduce(f32[128]{0} q), replica_groups=[16,32]<=[32,16]T(1,0)
  rs = f32[64]{0} reduce-scatter(f32[256]{0} s), replica_groups={{0,1,2,3}}
"""
    colls = parse_collectives(hlo)
    lv = collective_level_bytes(colls, TOPO512)
    # ring-schedule attribution conserves total wire bytes vs flat
    assert lv["total"] == pytest.approx(collective_bytes(colls)["total"])
    # the model-only all-gather and the 4-wide reduce-scatter stay intra
    assert lv["intra"] == pytest.approx(15 / 16 * 1024 + 3 / 4 * 256)
    # the (pod, data) all-reduce: pod superchunks first, then each pod's
    # data ring on half-sized shards: 2*(1/2)*512 + 2*(15/16)/2*512
    assert lv["pod"] == pytest.approx(512.0)
    assert lv["inter"] == pytest.approx(480.0)


def test_permute_attribution_by_pair_coords():
    hlo = ("  cp = f32[64]{0} collective-permute(f32[64]{0} r), "
           "source_target_pairs={{0,16},{16,32},{256,0},{0,1}}")
    (c,) = parse_collectives(hlo)
    lv = collective_level_bytes([c], TOPO512)
    # 2/4 pairs cross data, 1/4 crosses pod, 1/4 stays in-cluster
    assert lv["inter"] == pytest.approx(256 * 2 / 4)
    assert lv["pod"] == pytest.approx(256 / 4)
    assert lv["intra"] == pytest.approx(256 / 4)


def test_flat_hierarchy_prices_outermost():
    hlo = ("  ag = bf16[512]{0} all-gather(bf16[32]{0} p), "
           "replica_groups=[32,16]<=[512], dimensions={0}")
    colls = parse_collectives(hlo)
    flat = TOPO512.with_hierarchy("flat")
    lv = collective_level_bytes(colls, flat)
    assert lv["inter"] == lv["intra"] == 0.0
    assert lv["pod"] == pytest.approx(collective_bytes(colls)["total"])


def test_single_level_topology_bit_identical_to_flat_hw():
    """The degenerate case: one level prices exactly like wire_seconds()."""
    one = Topology.from_levels([("model", 512, 2.0)])
    assert one.wire_bw("intra") == HW["ici_bw"]
    hlo = ("  ar = f32[4096]{0} all-reduce(f32[4096]{0} q), "
           "replica_groups=[1,512]<=[512]")
    colls = parse_collectives(hlo)
    lv = collective_level_bytes(colls, one)
    assert lv["total"] == collective_bytes(colls)["total"]
    assert level_wire_seconds(lv, one)["total"] == \
        wire_seconds(collective_bytes(colls)["total"])


# ---------------------------------------------------------------------------
# Recorded dry-run regression (flat vs hierarchical pricing, pinned)
# ---------------------------------------------------------------------------

def test_recorded_collectives_price_bit_identically():
    fix = json.loads((DATA / "roofline_collectives_2x2x2.json").read_text())
    topo = _topo_from_describe(fix["topology"])
    colls = fix["colls"]
    for c in colls:                     # JSON round-trip: lists -> tuples
        if "members" in c:
            c["members"] = tuple(c["members"])
        if "pairs" in c:
            c["pairs"] = tuple((s, d) for s, d in c["pairs"])
    flat = collective_bytes(colls)
    assert flat["total"] == fix["flat_bytes_total"]
    assert wire_seconds(flat["total"]) == fix["flat_s"]
    lv = collective_level_bytes(colls, topo)
    for k, v in fix["level_bytes"].items():
        assert lv[k] == v, (k, lv[k], v)
    secs = level_wire_seconds(lv, topo)
    for k, v in fix["level_s"].items():
        assert secs[k] == v, (k, secs[k], v)
    # hierarchical pricing must genuinely differ from the flat single-class
    # price on this three-level machine (cheap intra wires dominate)
    assert secs["total"] != fix["flat_s"]


def test_bench_perf_pod_ring_ablation():
    """The BENCH_sim.json launch-strategy numbers (full llama3-8b train_4k
    on the 2x16x16 multi-pod cell) must keep the PR's headline property:
    hierarchical gradient sync prices strictly less pod-ring traffic than
    joint-axis fsdp_pure."""
    from repro.analysis.bench import validate_section
    bench = json.loads(
        (pathlib.Path(__file__).parents[1] / "BENCH_sim.json").read_text())
    assert validate_section("perf", bench["perf"]) == []
    cell = bench["perf"]["llama3-8b__train_4k__pod2x16x16"]
    for strat in ("baseline", "fsdp_pure", "fsdp_hier"):
        # this multi-pod cell prices exactly the three-level wire classes
        assert set(cell[strat]["collective_s_by_level"]) == \
            {"pod", "inter", "intra"}, strat
    hier, pure = cell["fsdp_hier"], cell["fsdp_pure"]
    assert hier["wire_bytes_by_level"]["pod"] < \
        pure["wire_bytes_by_level"]["pod"]
    assert hier["collective_s_by_level"]["pod"] < \
        pure["collective_s_by_level"]["pod"]
    assert hier["collective_s"] < pure["collective_s"]


def test_recorded_dryrun_artifact_breakdown_consistent():
    rec = json.loads((DATA / "dryrun_smoke_topo2x2x2.json").read_text())
    topo = _topo_from_describe(rec["topology"])
    r = rec["roofline"]
    by = r["collective_s_by_level"]
    assert set(by) == set(topo.wire_labels())
    assert r["collective_s"] == pytest.approx(sum(by.values()), rel=1e-12)
    # flat single-class reference pricing is the historical wire_seconds()
    assert r["collective_s_flat_hw"] == \
        wire_seconds(rec["per_device"]["wire_bytes"])
    # re-pricing the stored per-level bytes reproduces the stored seconds
    secs = level_wire_seconds(rec["per_device"]["wire_bytes_by_level"], topo)
    for k in topo.wire_labels():
        assert secs[k] == by[k], (k, secs[k], by[k])
