"""Kernel-autotuner tests: candidate legality under the S3 VRF budget,
block-clamp behaviour on arbitrary shapes, cache-round-trip determinism,
the model-vs-measured rank-agreement gate (interpret kernels on the CPU
emulator), and tuned-config consumption through ops into the model seams.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops, ref
from repro.kernels import flash_attention as fa_mod
from repro.kernels import matmul as mm_mod
from repro.kernels.vrf import VREG_GROUP_BYTES, VRF_BYTES, clamp_div

CASES = [
    ("matmul", (128, 128, 128)),
    ("flash_attention", (1, 2, 1, 128, 128, 64)),
    ("rmsnorm", (64, 2048)),
    ("reduction", (65536,)),
    ("stencil", (64, 256)),
]


# ---------------------------------------------------------------------------
# candidate enumeration respects the S3 VRF budget
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel,shape", CASES)
def test_candidates_respect_vrf_budget(kernel, shape):
    cands = autotune.enumerate_candidates(kernel, shape)
    assert cands
    for cfg in cands:
        bufs = autotune.candidate_buffers(kernel, shape, "float32", cfg)
        assert max(b for _, b in bufs) <= VREG_GROUP_BYTES, (cfg, bufs)
        assert sum(b for _, b in bufs) <= VRF_BYTES, (cfg, bufs)
        assert autotune.grid_steps(kernel, shape, cfg) >= 1


def test_model_top_candidate_passes_s3():
    """The model's preferred tiling must trace through analysis rule S3
    clean — the enumerator's budget mirror is checked against the real
    jaxpr walker, not just its own arithmetic."""
    from repro.analysis.jaxpr_check import check_pallas_budget
    from repro.sim import araxl_params
    p = araxl_params(64)
    M, K, N = 128, 128, 128
    cands = autotune.enumerate_candidates("matmul", (M, K, N))
    cfg = autotune.rank_candidates("matmul", (M, K, N), "float32",
                                   cands)[0][0]
    a = jnp.zeros((M, K), jnp.float32)
    b = jnp.zeros((K, N), jnp.float32)
    closed = jax.make_jaxpr(
        lambda a, b: mm_mod.matmul(a, b, interpret=True, **cfg))(a, b)
    assert check_pallas_budget(closed, p, "entry:autotuned-matmul") == []


# ---------------------------------------------------------------------------
# clamp idiom: arbitrary shapes are always legal
# ---------------------------------------------------------------------------

def test_clamp_div_halves_to_divisor():
    assert clamp_div(128, 96) == 96    # capped to the dim, which divides
    assert clamp_div(128, 192) == 64   # halved until it divides
    assert clamp_div(8, 8) == 8
    assert clamp_div(16, 7) == 7
    assert clamp_div(8, 12) == 4       # 8 does not divide 12 -> halve


@pytest.mark.parametrize("M,K,N", [(96, 96, 96), (192, 72, 48), (24, 56, 40)])
def test_matmul_clamps_arbitrary_shapes(M, K, N):
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(K, N)), jnp.float32)
    out = mm_mod.matmul(a, b, interpret=True)      # default 128-blocks clamp
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.matmul(a, b)),
                               rtol=1e-5, atol=1e-5)


def test_matmul_clamp_blocks_fit_budget():
    bm, bn, bk = mm_mod.clamp_blocks(4096, 4096, 4096, 512, 512, 512, 4)
    for buf in (bm * bk * 4, bk * bn * 4, bm * bn * 4):
        assert buf <= VREG_GROUP_BYTES
    assert 4096 % bm == 0 and 4096 % bn == 0 and 4096 % bk == 0


@pytest.mark.parametrize("S,Sk", [(96, 96), (192, 48)])
def test_flash_attention_clamps_arbitrary_shapes(S, Sk):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 2, S, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 1, Sk, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 1, Sk, 32)), jnp.float32)
    out = fa_mod.flash_attention(q, k, v, interpret=True)  # default 128s
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.attention(q, k, v)),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# timing dispersion satellite
# ---------------------------------------------------------------------------

def test_timing_sample_exposes_dispersion():
    from repro.testing import timing
    s = timing.measure_us(lambda x: x + 1, jnp.ones((8,)), reps=5, warmup=1)
    assert isinstance(s, timing.Sample)
    assert s.reps == 5 and s.median_us > 0 and s.iqr_us >= 0
    med = timing.median_time_us(lambda x: x + 1, jnp.ones((8,)),
                                reps=3, warmup=0)
    assert isinstance(med, float) and med > 0


# ---------------------------------------------------------------------------
# cache round-trip is deterministic
# ---------------------------------------------------------------------------

def test_cache_round_trip_deterministic(tmp_path, monkeypatch):
    calls = {"n": 0}
    real = autotune.measure_candidate

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(autotune, "measure_candidate", counting)
    path = tmp_path / "cache.json"
    with autotune.tuned(path, top_k=2, reps=2, warmup=0) as ctx:
        r1 = autotune.autotune("rmsnorm", (32, 512), ctx=ctx)
        n1 = calls["n"]
        assert n1 > 0
        r2 = autotune.autotune("rmsnorm", (32, 512), ctx=ctx)
    assert calls["n"] == n1, "cached signature re-measured"
    assert r2["winner"] == r1["winner"]
    # a fresh context over the same cache file restores the same winner,
    # still without measuring
    with autotune.tuned(path, top_k=2, reps=2, warmup=0) as ctx2:
        r3 = autotune.autotune("rmsnorm", (32, 512), ctx=ctx2)
    assert calls["n"] == n1
    assert r3["winner"] == r1["winner"]
    on_disk = json.loads(path.read_text())
    assert on_disk["schema"] == 1 and len(on_disk["entries"]) == 1


# ---------------------------------------------------------------------------
# rank agreement: the acceptance gate on the CI host
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel,shape,min_block", [
    ("matmul", (128, 128, 128), 64),
    ("rmsnorm", (64, 1024), None),
    ("reduction", (65536,), None),
])
def test_model_rank_agreement(tmp_path, kernel, shape, min_block):
    """The model's top-k shortlist must contain the measured winner when
    *every* candidate is measured (interpret kernels, CPU emulator)."""
    with autotune.tuned(tmp_path / "c.json", top_k=3, reps=3,
                        warmup=1) as ctx:
        rec = autotune.autotune(kernel, shape, ctx=ctx, measure_all=True,
                                min_block=min_block)
    assert rec["agreement_at_k"], rec
    assert rec["model_rank_of_winner"] < rec["top_k"]


def test_recorded_artifact_agrees(tmp_path):
    """The committed BENCH_kernels.json must itself report shortlist
    agreement for every signature (re-record if the host changed)."""
    from repro.analysis.bench import load_kernels_bench
    import pathlib
    doc = load_kernels_bench(pathlib.Path(__file__).resolve().parents[1])
    assert doc is not None, "run `python -m benchmarks.run kernels` first"
    for sig, rec in doc["records"].items():
        assert rec["agreement_at_k"], sig


# ---------------------------------------------------------------------------
# ops consume tuned configs; seams stay bit-identical
# ---------------------------------------------------------------------------

def test_ops_consume_tuned_configs(tmp_path, monkeypatch):
    seen = {}
    real = ops._rms.rmsnorm

    def spy(x, g, *, bm=8, eps=1e-6, interpret=False):
        seen["bm"] = bm
        return real(x, g, bm=bm, eps=eps, interpret=interpret)

    monkeypatch.setattr(ops._rms, "rmsnorm", spy)
    x = jnp.ones((16, 128), jnp.float32)
    g = jnp.full((128,), 2.0, jnp.float32)
    with autotune.tuned(tmp_path / "cache.json") as ctx:
        sig = autotune.signature("rmsnorm", (16, 128), "float32",
                                 ctx.topology_tag)
        ctx.table[sig] = {"winner": {"bm": 2}}
        out = ops.rmsnorm(x, g, use_pallas=True)
        assert seen["bm"] == 2, "tuned config not consumed"
        # explicit caller arg still wins over the tuned table
        ops.rmsnorm(x, g, use_pallas=True, bm=4)
        assert seen["bm"] == 4
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.rmsnorm(x, g)),
                               rtol=1e-6, atol=1e-6)


def test_dense_ref_is_bit_identical_to_matmul_operator():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(4, 8, 16)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(16, 12)), jnp.float32)
    assert np.array_equal(np.asarray(ops.dense(x, w)), np.asarray(x @ w))


def test_layers_bit_identical_tuned_vs_untuned(tmp_path):
    """forward_train through models/layers with a rigged tuned table (a
    different attention q-chunk than the default) must match the untuned
    path bit for bit — blocking is a schedule, never a value change."""
    from repro.configs import get_smoke_config
    from repro.models import lm
    from repro.parallel import default_rules, init_params

    rules = default_rules(None)
    cfg = get_smoke_config("llama3-8b")
    params = init_params(lm.model_defs(cfg), jax.random.key(0))
    rng = np.random.default_rng(0)
    B, S = 2, 32
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)

    base = jax.jit(lambda p, t: lm.forward_train(p, t, cfg, rules, None)
                   )(params, tokens)
    with autotune.tuned(tmp_path / "cache.json") as ctx:
        dt = str(jnp.zeros((), cfg.dtype).dtype)
        sig = autotune.signature(
            "flash_attention",
            (1, cfg.n_heads, cfg.n_heads, S, S, cfg.head_dim), dt,
            ctx.topology_tag)
        ctx.table[sig] = {"winner": {"bq": 8, "bk": 32}}
        assert ops.attention_q_chunk(S, S, cfg.n_heads, cfg.head_dim,
                                     dt) == 8
        tuned_loss = jax.jit(
            lambda p, t: lm.forward_train(p, t, cfg, rules, None)
        )(params, tokens)
    assert np.array_equal(np.asarray(base), np.asarray(tuned_loss))
