"""Bit-exact data replay (`repro.data.pipeline`): the stream is a pure
function of (seed, step, global row id), so a restarted — or *rescaled* —
job replays byte-identical batches from any checkpointed cursor."""
import dataclasses

import numpy as np

from repro.data import DataConfig, Pipeline, SyntheticCorpus, global_batch


CFG = DataConfig(vocab_size=256, seq_len=32, global_batch=8, seed=0)


def _drain(pipe, n):
    try:
        return [next(pipe) for _ in range(n)]
    finally:
        pipe.close()


def test_restart_replays_bit_identically():
    """Consume k steps, 'crash', rebuild from a mid-epoch cursor: the
    replayed batches are byte-equal to the first run's."""
    first = _drain(Pipeline(CFG), 6)
    resumed = Pipeline(CFG, start_step=3)
    assert resumed.cursor == 3
    replay = _drain(resumed, 3)
    for i, b in enumerate(replay):
        np.testing.assert_array_equal(b, first[3 + i])


def test_cursor_tracks_consumption_not_prefetch():
    """Prefetched-but-unconsumed batches must not advance the cursor —
    persisting it mid-flight and resuming there never skips data."""
    pipe = Pipeline(CFG)                # worker prefetches ahead immediately
    assert pipe.cursor == 0
    next(pipe)
    next(pipe)
    assert pipe.cursor == 2             # 2 consumed, regardless of prefetch
    resumed = Pipeline(CFG, start_step=pipe.cursor)
    a = _drain(pipe, 1)[0]
    b = _drain(resumed, 1)[0]
    np.testing.assert_array_equal(a, b)


def test_replay_identical_across_mesh_size_change():
    """Concatenating every host's shard (host order) equals the 1-host
    global batch byte-for-byte, for any host count dividing the batch —
    the property that lets a kill-and-rescale restart (8 -> 4 devices,
    2 -> 1 hosts) replay the token stream the dead fleet would have seen."""
    for step in (0, 5, 11):
        want = global_batch(CFG, step)
        assert want.shape == (CFG.global_batch, CFG.seq_len)
        for n_hosts in (1, 2, 4, 8):
            shards = [SyntheticCorpus(dataclasses.replace(
                          CFG, n_hosts=n_hosts, host_id=h)).batch(step)
                      for h in range(n_hosts)]
            np.testing.assert_array_equal(np.concatenate(shards), want)


def test_distinct_steps_and_rows_differ():
    """Sanity that purity is not constancy: different (step, row) cells
    produce different tokens (overwhelmingly likely at seq_len=32)."""
    b0, b1 = global_batch(CFG, 0), global_batch(CFG, 1)
    assert not np.array_equal(b0, b1)
    assert not np.array_equal(b0[0], b0[1])


def test_same_config_streams_are_deterministic():
    a = _drain(Pipeline(CFG), 4)
    b = _drain(Pipeline(CFG), 4)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_pipeline_desync_is_loud():
    """A cursor/queue mismatch is an assertion, not silent skew."""
    import pytest

    pipe = Pipeline(CFG)
    next(pipe)
    pipe.cursor = 40                    # corrupt the cursor deliberately
    with pytest.raises(AssertionError, match="desync"):
        next(pipe)
    pipe.close()
