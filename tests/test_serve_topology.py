"""Pod-local KV serving: placement + prefix affinity + bit-identity on a
2x2x2 mesh of 8 fake devices (subprocess check), plus single-process units
for the rule derivation."""
from repro.parallel.sharding import ShardingRules
from repro.serve import pod_local_cache_rules, prefix_key
from repro.testing.subproc import run_check
from repro.topology import Topology
import numpy as np


def test_serve_topology_multidevice():
    out = run_check("repro.testing.check_serve_topology", devices=8)
    assert "check_serve_topology OK" in out


def test_pod_local_cache_rules_strip_outer_level():
    topo = Topology.from_levels([("pod", 2, 8.0), ("data", 2, 4.0),
                                 ("model", 2, 2.0)])
    rules = ShardingRules(None, None)
    # mesh-less rules pass through untouched
    assert pod_local_cache_rules(rules, topo) is rules

    class FakeMesh:                      # only identity is inspected here
        pass

    mesh = FakeMesh()
    src = ShardingRules(mesh, {
        "batch": ("pod", "data"),
        "kv": "model",
        "cache_seq": "pod",
        "act_seq": None,
    })
    got = pod_local_cache_rules(src, topo)
    assert got.rules["batch"] == "data"       # pod stripped, singleton kept
    assert got.rules["kv"] == "model"         # inner mapping untouched
    assert got.rules["cache_seq"] is None     # pod-only mapping removed
    assert got.rules["act_seq"] is None


def test_prefix_key_buckets_prompt_head():
    a = np.arange(32, dtype=np.int32)
    b = np.concatenate([np.arange(16, dtype=np.int32),
                        np.full(8, 7, np.int32)])
    assert prefix_key(a) == prefix_key(b)       # same 16-token head
    assert prefix_key(a) != prefix_key(a + 1)
