"""MoE EP (psum and a2a) vs local-dispatch oracle, on 8 fake devices."""
from repro.testing.subproc import run_check


def test_moe_ep_variants_match_oracle():
    out = run_check("repro.testing.check_moe", "2", "4", devices=8)
    assert "check_moe OK" in out
