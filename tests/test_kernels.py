"""Per-kernel interpret-mode sweeps against the pure-jnp oracles.

Every Pallas kernel is validated over a shape x dtype grid plus a
hypothesis-driven randomized sweep (paper-kernel semantics on top in
tests/test_core_multidevice.py and the ISA layer).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")

RNG = np.random.default_rng(42)


def rand(shape, dtype):
    x = RNG.normal(size=shape).astype(np.float32)
    return jnp.asarray(x, dtype)


TOL = {jnp.float32: dict(rtol=1e-4, atol=1e-4),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 384),
                                   (128, 256, 512)])
def test_matmul(shape, dtype):
    M, N, K = shape
    a, b = rand((M, K), dtype), rand((K, N), dtype)
    got = ops.matmul(a, b, use_pallas=True)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", [jnp.float32])
@pytest.mark.parametrize("hw", [(16, 256), (8, 512), (24, 128)])
def test_jacobi2d(hw, dtype):
    x = rand(hw, dtype)
    got = ops.jacobi2d(x, use_pallas=True, bh=8, bw=128)
    want = ref.jacobi2d(jnp.pad(x, 1))
    np.testing.assert_allclose(got, want, **TOL[dtype])


@pytest.mark.parametrize("f", [(7, 7), (3, 3)])
def test_fconv2d(f):
    x = rand((16 + f[0] - 1, 256 + f[1] - 1), jnp.float32)
    filt = rand(f, jnp.float32)
    got = ops.fconv2d(x, filt, use_pallas=True, bh=8, bw=128)
    want = ref.fconv2d(x, filt)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n", [16384, 8 * 2048 * 3])
def test_dotprod(n):
    a, b = rand((n,), jnp.float32), rand((n,), jnp.float32)
    got = ops.dotprod(a, b, use_pallas=True)
    np.testing.assert_allclose(float(got), float(ref.dotprod(a, b)),
                               rtol=1e-4)


def test_expv_polynomial_accuracy():
    x = jnp.asarray(RNG.uniform(-20, 20, size=16384), jnp.float32)
    got = ops.expv(x, use_pallas=True)
    np.testing.assert_allclose(got, np.exp(np.asarray(x)), rtol=3e-6)


@pytest.mark.parametrize("rw", [(8, 512), (32, 1024), (16, 128)])
def test_softmax_rows(rw):
    x = rand(rw, jnp.float32) * 4
    got = ops.softmax_rows(x, use_pallas=True)
    want = ref.softmax_rows(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got).sum(-1), 1.0, rtol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("cfg", [
    dict(B=1, Hq=4, Hkv=2, S=256, D=64, causal=True, window=None),
    dict(B=2, Hq=4, Hkv=4, S=128, D=64, causal=False, window=None),
    dict(B=1, Hq=8, Hkv=2, S=256, D=32, causal=True, window=128),
])
def test_flash_attention(cfg, dtype):
    B, Hq, Hkv, S, D = cfg["B"], cfg["Hq"], cfg["Hkv"], cfg["S"], cfg["D"]
    q = rand((B, Hq, S, D), dtype)
    k = rand((B, Hkv, S, D), dtype)
    v = rand((B, Hkv, S, D), dtype)
    got = ops.attention(q, k, v, causal=cfg["causal"], window=cfg["window"],
                        use_pallas=True, bq=64, bk=64)
    want = ref.attention(q, k, v, causal=cfg["causal"], window=cfg["window"])
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **TOL[dtype])


@pytest.mark.parametrize("shape", [(8, 512), (32, 4096), (16, 3072)])
def test_rmsnorm(shape):
    x = rand(shape, jnp.float32)
    g = rand((shape[-1],), jnp.float32)
    got = ops.rmsnorm(x, g, use_pallas=True)
    np.testing.assert_allclose(got, ref.rmsnorm(x, g), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# hypothesis sweeps (randomized shapes within tiling envelopes)
# ---------------------------------------------------------------------------

@given(m=st.integers(1, 3), n=st.integers(1, 3), k=st.integers(1, 4))
@settings(max_examples=8, deadline=None)
def test_matmul_shape_sweep(m, n, k):
    a = rand((m * 128, k * 128), jnp.float32)
    b = rand((k * 128, n * 128), jnp.float32)
    got = ops.matmul(a, b, use_pallas=True)
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-4, atol=1e-3)


@given(s=st.sampled_from([64, 128, 192]), hq=st.sampled_from([2, 4]),
       g=st.sampled_from([1, 2]), causal=st.booleans())
@settings(max_examples=8, deadline=None)
def test_attention_shape_sweep(s, hq, g, causal):
    hkv = hq // g
    q = rand((1, hq, s, 32), jnp.float32)
    k = rand((1, hkv, s, 32), jnp.float32)
    v = rand((1, hkv, s, 32), jnp.float32)
    got = ops.attention(q, k, v, causal=causal, use_pallas=True, bq=64, bk=64)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)
