"""Docs can't rot: every fenced ``python`` block in docs/*.md must execute.

Blocks are concatenated per document (so later blocks may build on earlier
ones) and run in a subprocess under the tier-1 environment — offline, CPU,
8 fake devices, repo root as cwd, ``src`` on PYTHONPATH via
``repro.substrate``-routed imports only.  No network, no pip.
"""
from __future__ import annotations

import os
import pathlib
import re
import subprocess
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
DOCS = sorted((ROOT / "docs").glob("*.md"))

_FENCE = re.compile(r"^```python\n(.*?)^```", re.DOTALL | re.MULTILINE)


def doc_blocks(path: pathlib.Path) -> list[str]:
    return [m.group(1) for m in _FENCE.finditer(path.read_text())]


def test_docs_exist_and_have_executable_blocks():
    names = {p.name for p in DOCS}
    assert {"ARCHITECTURE.md", "TOPOLOGY.md"} <= names, names
    for required in ("ARCHITECTURE.md", "TOPOLOGY.md"):
        assert doc_blocks(ROOT / "docs" / required), \
            f"{required} has no fenced python blocks"


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_doc_blocks_execute(doc, tmp_path):
    blocks = doc_blocks(doc)
    if not blocks:
        pytest.skip(f"{doc.name}: no python blocks")
    script = tmp_path / f"{doc.stem}_blocks.py"
    parts = []
    for i, block in enumerate(blocks):
        parts.append(f"# --- {doc.name} block {i + 1} ---\n{block}")
    script.write_text("\n".join(parts))

    env = dict(os.environ)
    # append (don't clobber) any pre-set flags, matching scripts/ci.sh
    extra = env.get("XLA_FLAGS", "")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8"
                        + (" " + extra if extra else ""))
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, str(script)], cwd=ROOT, env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"{doc.name} code blocks failed (rc={proc.returncode})\n"
        f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
