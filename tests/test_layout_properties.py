"""Property tests (hypothesis) for the AraXL byte-mapping invariants.

These are pure index-map properties (single device): the paper's layout
equations must form a bijection memory <-> (row, cluster, lane), slides must
compose, and the GLSU host oracle must invert itself.
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.layout import (coords_to_element, element_to_coords,
                               mem_to_striped_host, striped_to_mem_host)

geom = st.sampled_from([(2, 2), (4, 2), (2, 4), (8, 4), (16, 4), (4, 16)])


@given(geom, st.integers(0, 10_000))
def test_byte_map_bijection(cl, i):
    C, L = cl
    b, c, l = element_to_coords(i, C, L)
    assert 0 <= c < C and 0 <= l < L
    assert coords_to_element(b, c, l, C, L) == i


@given(geom, st.integers(1, 64))
@settings(max_examples=40)
def test_glsu_host_roundtrip(cl, rows):
    C, L = cl
    x = np.random.default_rng(0).normal(size=rows * C * L)
    reg = mem_to_striped_host(x, C, L)
    # paper map: element i sits at (i//(C*L), (i//L)%C, i%L)
    for i in {0, 1, L - 1, L, C * L - 1, min(C * L, len(x) - 1), len(x) - 1}:
        b, c, l = element_to_coords(i, C, L)
        assert reg[b, c, l] == x[i]
    np.testing.assert_array_equal(striped_to_mem_host(reg), x)


@given(geom, st.integers(2, 32))
@settings(max_examples=30)
def test_consecutive_elements_are_ring_neighbours(cl, rows):
    """The property RINGI relies on: elements i and i+1 sit either on the same
    ring position (never, with striping) or on adjacent ring positions, where
    ring position p = c*L + l — so slide-by-1 is a 1-hop exchange."""
    C, L = cl
    n = C * L
    for i in range(min(rows * n - 1, 4 * n)):
        _, c0, l0 = element_to_coords(i, C, L)
        _, c1, l1 = element_to_coords(i + 1, C, L)
        p0, p1 = c0 * L + l0, c1 * L + l1
        assert (p1 - p0) % n == 1
