"""Validation of the cycle-approximate model against the paper's claims.

This is the reproduction gate: every assertion cites the paper section it
checks.  Residuals between the calibrated model and the paper are recorded
in EXPERIMENTS.md §Sim-reproduction.
"""
import math

import pytest

from repro.sim import (ara2_params, araxl_params, build_trace, simulate)
from repro.sim import paper, ppa
from repro.sim.kernels import KERNEL_BUILDERS, max_perf_flop_per_cycle


def util(kernel, params, bpl, **kw):
    r = simulate(build_trace(kernel, params, bpl, **kw), params)
    return r.utilization


def fpc(kernel, params, bpl, **kw):
    r = simulate(build_trace(kernel, params, bpl, **kw), params)
    return r.flop_per_cycle


def scale_vs_ara2_8(kernel, bpl):
    a64 = fpc(kernel, araxl_params(64), bpl)
    a8 = fpc(kernel, ara2_params(8), bpl)
    return a64 / a8


# ---------------------------------------------------------------------------
# §IV-B — performance scalability (Fig. 6)
# ---------------------------------------------------------------------------

def test_fmatmul_64l_long_vector_utilization():
    """'fmatmul ... up to 99% utilization' at 64 lanes, long vectors."""
    assert util("fmatmul", araxl_params(64), 512) >= paper.FMATMUL_UTIL_64L_LONG


def test_fconv2d_64l_long_vector_utilization():
    assert util("fconv2d", araxl_params(64), 512) >= paper.FCONV2D_UTIL_64L_LONG


@pytest.mark.parametrize("kernel", ["fmatmul", "fconv2d", "jacobi2d", "exp"])
def test_compute_bound_kernels_scale_linearly(kernel):
    """'linear performance scaling from 8 to 64 lanes' for the
    compute-bound kernels in the long-vector regime."""
    for lanes in (16, 32, 64):
        s = fpc(kernel, araxl_params(lanes), 512) / \
            fpc(kernel, araxl_params(8), 512)
        assert s == pytest.approx(lanes / 8, rel=0.06), (kernel, lanes, s)


def test_softmax_scaling_factor():
    """'softmax ... performance scaling factor of 7.3x on a 64-lane AraXL'."""
    s = scale_vs_ara2_8("softmax", 512)
    assert s == pytest.approx(paper.SOFTMAX_SCALE_64L, rel=0.05), s


def test_fdotproduct_scaling_factor():
    """'... and 6.1x' for the memory-bound fdotproduct."""
    s = scale_vs_ara2_8("fdotproduct", 512)
    assert s == pytest.approx(paper.FDOT_SCALE_64L, rel=0.06), s


def test_fdotproduct_long_vector_mitigation():
    """'close-to-linear performance scaling of 7.6x with a 16384 B/lane dot
    product, stripmined over 16 loop iterations' — longer vectors amortize
    the inter-lane/inter-cluster reduction stages."""
    p = araxl_params(64)
    tr = build_trace("fdotproduct", p, 16384)
    n_strips = sum(1 for r in tr if r.op.startswith("vfredsum"))
    assert n_strips == 16                     # the paper's 16 iterations
    s = scale_vs_ara2_8("fdotproduct", 16384)
    assert s >= paper.FDOT_SCALE_64L_16KIB - 0.3
    # and it must clearly beat the 512 B/lane operating point
    assert s > scale_vs_ara2_8("fdotproduct", 512) + 1.0


def test_two_level_red_tree_strictly_cheaper_than_flat_at_64():
    """§III-B.4: the hierarchical interconnect (log2(L) short hops + log2(C)
    ring hops) must beat the flattened 64-lane ring's log-tree outright —
    this is the physical-scalability claim the whole design rests on."""
    p = araxl_params(64)
    assert p.hierarchy == "two-level"         # the calibrated default
    assert p.red_tree_lat() < p.with_hierarchy("flat").red_tree_lat()


@pytest.mark.parametrize("kernel", ["softmax", "fdotproduct"])
def test_reduction_kernels_scale_better_under_the_hierarchy(kernel):
    """The fig6 ablation: at 64 lanes the reduction-bound kernels scale
    strictly better on the two-level interconnect than on the flat ring
    (and only the two-level numbers sit in the paper's bands)."""
    a8 = fpc(kernel, ara2_params(8), 512)
    s_two = fpc(kernel, araxl_params(64), 512) / a8
    s_flat = fpc(kernel, araxl_params(64, hierarchy="flat"), 512) / a8
    assert s_two > s_flat + 0.5, (s_two, s_flat)
    band = {"softmax": paper.SOFTMAX_SCALE_64L,
            "fdotproduct": paper.FDOT_SCALE_64L}[kernel]
    assert s_flat < band * 0.94               # the flat ring misses the paper


def test_compute_bound_kernels_insensitive_to_hierarchy():
    """fmatmul/exp stream through the FPUs; the interconnect model must not
    move them (no reductions, no slides)."""
    for kernel in ("fmatmul", "exp"):
        u_two = util(kernel, araxl_params(64), 512)
        u_flat = util(kernel, araxl_params(64, hierarchy="flat"), 512)
        assert u_two == pytest.approx(u_flat, abs=0.005), kernel


def test_reduction_latency_is_size_independent():
    """The mechanism behind the softmax/fdot gap: tree latency depends on the
    configuration, not the problem size."""
    p = araxl_params(64)
    assert p.red_tree_lat() == araxl_params(64).red_tree_lat()
    assert araxl_params(64).red_tree_lat() > araxl_params(8).red_tree_lat()


def test_medium_vectors_lose_utilization():
    """§IV-B: 'in the medium vector length regime (64 B/lane) ... lower FPU
    utilization', and AraXL-64 is hit at least as hard as Ara2-8."""
    for kernel in KERNEL_BUILDERS:
        u_med = util(kernel, araxl_params(64), 64)
        u_long = util(kernel, araxl_params(64), 512)
        assert u_med < u_long, kernel


# ---------------------------------------------------------------------------
# §IV-C — latency tolerance (Fig. 7)
# ---------------------------------------------------------------------------

def _drop(kernel, bpl, **cuts):
    p0 = araxl_params(64)
    p1 = p0.with_cuts(**cuts)
    return util(kernel, p0, bpl) - util(kernel, p1, bpl)


def test_glsu_cut_tolerance():
    """+4 GLSU registers (+8 cycles): 'maximum utilization drop in the
    long-vector regime is a mere 1.5%' (we allow 2.5% model band); 'longer
    vectors face virtually no performance drop'."""
    for kernel in KERNEL_BUILDERS:
        assert _drop(kernel, 128, glsu=4) <= 0.025, kernel
        assert _drop(kernel, 512, glsu=4) <= 0.011, kernel


def test_reqi_cut_tolerance():
    """+1 REQI register (+2 cycles/ack): a visible drop for fconv2d at
    128 B/lane (paper: 5%), 'completely amortized at 512 B/lane'."""
    d128 = _drop("fconv2d", 128, reqi=1)
    assert 0.01 <= d128 <= 0.09, d128
    assert _drop("fconv2d", 512, reqi=1) <= 0.005
    assert _drop("jacobi2d", 512, reqi=1) <= 0.005


def test_ringi_cut_tolerance():
    """+1 RINGI register (+1 cycle/hop): 'up to 1.4% drop' for long vectors
    (slide/reduction kernels; 2.2% model band at 512 B/lane)."""
    for kernel in KERNEL_BUILDERS:
        assert _drop(kernel, 512, ringi=1) <= 0.022, kernel


def test_overall_latency_tolerance_long_vectors():
    """'less than 2% utilization drop in the long-vector regime' across all
    three interfaces for the compute-bound kernels."""
    for kernel in ("fmatmul", "fconv2d", "jacobi2d", "exp", "softmax"):
        for cuts in (dict(glsu=4), dict(reqi=1), dict(ringi=1)):
            assert _drop(kernel, 512, **cuts) <= paper.OVERALL_LONG_VECTOR_DROP, \
                (kernel, cuts)


# ---------------------------------------------------------------------------
# §IV-D — PPA (Tables II/III)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("lanes", [16, 32, 64])
def test_area_model_vs_table_ii(lanes):
    got = ppa.area_breakdown_kge(araxl_params(lanes))
    want = paper.TABLE_II_KGE[lanes]
    assert got["total"] == pytest.approx(want["total"], rel=0.03)
    assert got["clusters"] == pytest.approx(want["clusters"], rel=0.01)
    assert got["glsu"] == pytest.approx(want["glsu"], rel=0.11)


def test_area_scales_linearly():
    """'2x the area with twice the lanes' — the headline scaling claim."""
    a16 = ppa.area_breakdown_kge(araxl_params(16))["total"]
    a32 = ppa.area_breakdown_kge(araxl_params(32))["total"]
    a64 = ppa.area_breakdown_kge(araxl_params(64))["total"]
    assert a32 / a16 == pytest.approx(1.93, abs=0.1)
    assert a64 / a32 == pytest.approx(1.97, abs=0.1)
    # 'only 3.8x the area of a 16-lane instance' (abstract)
    assert a64 / a16 == pytest.approx(3.8, abs=0.15)


@pytest.mark.parametrize("lanes", [16, 32, 64])
def test_interfaces_are_cheap(lanes):
    """'The GLSU, RINGI, and REQI account for only 3% of the total area.'"""
    assert ppa.interface_area_fraction(araxl_params(lanes)) <= 0.035


@pytest.mark.parametrize("lanes", [16, 32, 64])
def test_table_iii_ppa(lanes):
    freq, perf, eeff, aeff = paper.TABLE_III[lanes]
    p = araxl_params(lanes)
    assert p.freq_ghz == pytest.approx(freq)
    u = util("fmatmul", p, 512)
    assert ppa.peak_gflops(p, u) == pytest.approx(perf, rel=0.035)
    assert ppa.energy_eff_gflops_per_w(p, u) == pytest.approx(eeff, rel=0.04)
    assert ppa.area_eff_gflops_per_mm2(p, u) == pytest.approx(aeff, rel=0.05)


def test_abstract_headline():
    """146 GFLOPs peak, 40.1 GFLOPs/W, 1.15 GHz for the 64-lane instance."""
    p = araxl_params(64)
    u = util("fmatmul", p, 512)
    assert ppa.peak_gflops(p, u) >= 145.0
    assert ppa.energy_eff_gflops_per_w(p, u) == pytest.approx(40.1, rel=0.04)


# ---------------------------------------------------------------------------
# Model-internal sanity
# ---------------------------------------------------------------------------

def test_flops_never_exceed_table_i_peak():
    for kernel in KERNEL_BUILDERS:
        for lanes in (8, 64):
            p = araxl_params(lanes)
            r = simulate(build_trace(kernel, p, 512), p)
            assert r.flop_per_cycle <= max_perf_flop_per_cycle(kernel, lanes) * 1.001, \
                (kernel, lanes, r.flop_per_cycle)
