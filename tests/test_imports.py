"""Import sweep: every module under src/repro must import on the pinned
toolchain (this is the test that would have caught the jax.shard_map /
jax.lax.axis_size drift at seed)."""
import importlib
import pathlib

import pytest

import repro

_ROOT = pathlib.Path(repro.__path__[0])


def _all_modules():
    """Every module under src/repro, from the filesystem (pkgutil would skip
    the namespace subpackages that have no __init__.py, e.g. repro.testing)."""
    mods = {"repro"}
    for p in _ROOT.rglob("*.py"):
        parts = ("repro",) + p.relative_to(_ROOT).with_suffix("").parts
        if "__pycache__" in parts:
            continue
        if parts[-1] == "__init__":
            parts = parts[:-1]
        mods.add(".".join(parts))
    return sorted(mods)


MODULES = _all_modules()


def test_sweep_finds_the_tree():
    # the sweep must actually cover the package (guards against an empty walk)
    assert "repro.substrate" in MODULES
    assert "repro.core.ring" in MODULES
    assert "repro.testing.hypothesis_compat" in MODULES   # namespace package
    assert "repro.launch.train" in MODULES
    assert len(MODULES) > 50, MODULES


@pytest.mark.parametrize("name", MODULES)
def test_module_imports(name):
    importlib.import_module(name)


def test_imports_leave_x64_flag_alone():
    """No module — the check_* suite especially — may flip jax_enable_x64 at
    import time: the alphabetical sweep order used to decide the flag for
    every later test (float64 leaks masked or revealed by import order).
    Checks scope the flag with repro.testing.x64.x64_mode instead.

    Runs in a fresh subprocess: in this process the parametrized sweep above
    has already cached every module in sys.modules, so a re-import here
    would be a no-op and could never catch a reintroduced import-time flip.
    """
    import os
    import subprocess
    import sys
    code = (
        "import importlib, jax\n"
        "before = bool(jax.config.jax_enable_x64)\n"
        f"for name in {MODULES!r}:\n"
        "    importlib.import_module(name)\n"
        "    assert bool(jax.config.jax_enable_x64) == before, \\\n"
        "        f'importing {name} flipped jax_enable_x64'\n"
        "print('x64-clean')\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(_ROOT.parent) + os.pathsep + \
        env.get("PYTHONPATH", "")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0 and "x64-clean" in proc.stdout, \
        proc.stdout + proc.stderr
