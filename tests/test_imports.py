"""Import sweep: every module under src/repro must import on the pinned
toolchain (this is the test that would have caught the jax.shard_map /
jax.lax.axis_size drift at seed)."""
import importlib
import pathlib

import pytest

import repro

_ROOT = pathlib.Path(repro.__path__[0])


def _all_modules():
    """Every module under src/repro, from the filesystem (pkgutil would skip
    the namespace subpackages that have no __init__.py, e.g. repro.testing)."""
    mods = {"repro"}
    for p in _ROOT.rglob("*.py"):
        parts = ("repro",) + p.relative_to(_ROOT).with_suffix("").parts
        if "__pycache__" in parts:
            continue
        if parts[-1] == "__init__":
            parts = parts[:-1]
        mods.add(".".join(parts))
    return sorted(mods)


MODULES = _all_modules()


def test_sweep_finds_the_tree():
    # the sweep must actually cover the package (guards against an empty walk)
    assert "repro.substrate" in MODULES
    assert "repro.core.ring" in MODULES
    assert "repro.testing.hypothesis_compat" in MODULES   # namespace package
    assert "repro.launch.train" in MODULES
    assert len(MODULES) > 50, MODULES


@pytest.mark.parametrize("name", MODULES)
def test_module_imports(name):
    importlib.import_module(name)
